"""Per-cell perf probe for the hillclimb loop:

  PYTHONPATH=src python -m benchmarks.perf_cell --arch mixtral-8x7b \
      --shape train_4k [--bytes] [--flops] [--coll]

Lowers one cell on the single-pod mesh and prints the roofline terms plus a
trip-count-scaled opcode breakdown of the dominant resource.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse

from repro.launch import hlo_cost
from repro.launch.dryrun import fmt_row, lower_cell
from repro.launch.mesh import make_production_mesh


def probe(arch: str, shape: str, *, show=("bytes",), top: int = 14,
          **lower_kw):
    mesh = make_production_mesh()
    res, compiled = lower_cell(arch, shape, mesh, verbose=False,
                               return_compiled=True, **lower_kw)
    print(fmt_row(res))
    if not res.ok:
        return res
    text = compiled.as_text()
    if "bytes" in show:
        print("  -- HBM bytes breakdown (per device) --")
        for k, v in hlo_cost.bytes_breakdown(text, top):
            print(f"  {v / 2**30:10.2f} GiB  {k}")
    if "flops" in show:
        print("  -- FLOPs breakdown (per device) --")
        for k, v in hlo_cost.flop_breakdown(text, top):
            print(f"  {v / 1e9:10.2f} GF   {k}")
    if "coll" in show:
        print("  -- collectives (per device) --")
        for k, v in res.coll_breakdown.items():
            if v and k != "n_ops":
                print(f"  {v / 2**30 / 256:10.2f} GiB  {k}")
            elif k == "n_ops":
                print(f"  {v:10d}      {k}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--bytes", action="store_true")
    ap.add_argument("--flops", action="store_true")
    ap.add_argument("--coll", action="store_true")
    args = ap.parse_args()
    show = [s for s in ("bytes", "flops", "coll")
            if getattr(args, s)] or ["bytes"]
    probe(args.arch, args.shape, show=show)


if __name__ == "__main__":
    main()
