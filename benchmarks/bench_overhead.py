"""§3.1.4 scheduling-overhead claim: the static-key max-heap is O(k log n)
per round vs the naive full-recompute O(n) pop — measured wall time across
queue depths."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, save_json
from repro.core.policies import NaiveAgingQueue, make_policy
from repro.core.request import Request


def bench_queue(n: int, k: int, reps: int = 5):
    """n waiting requests; k pops + re-inserts per round (one round)."""
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, 4096, n)
    arrivals = rng.random(n) * 100

    def mk_reqs():
        return [Request(prompt_len=int(p), max_new_tokens=1, arrival_time=float(a))
                for p, a in zip(prompts, arrivals)]

    heap_t = []
    for _ in range(reps):
        reqs = mk_reqs()
        q = make_policy("aging", alpha=1.0, beta=-0.1)
        for r in reqs:
            q.add(r)
        t0 = time.perf_counter()
        popped = [q.pop() for _ in range(k)]
        for r in popped:
            r.prefill_done = min(r.prompt_len - 1, r.prefill_done + 64)
            q.update(r)
        heap_t.append(time.perf_counter() - t0)

    naive_t = []
    for _ in range(reps):
        reqs = mk_reqs()
        q = NaiveAgingQueue(1.0, -0.1)
        for r in reqs:
            q.add(r)
        t0 = time.perf_counter()
        popped = [q.pop(now=200.0) for _ in range(k)]
        for r in popped:
            r.prefill_done = min(r.prompt_len - 1, r.prefill_done + 64)
            q.update(r)
        naive_t.append(time.perf_counter() - t0)

    return min(heap_t) * 1e6, min(naive_t) * 1e6   # us per round


def main(quick: bool = False):
    rows = []
    out = {}
    sizes = (100, 1000, 10_000) if quick else (100, 1000, 10_000, 100_000)
    for n in sizes:
        k = 8
        h, nv = bench_queue(n, k)
        out[n] = {"heap_us": h, "naive_us": nv}
        rows.append([f"{n:,}", k, f"{h:,.1f}", f"{nv:,.1f}", f"{nv / h:,.1f}x"])
    print(fmt_table(
        "Scheduling overhead per round — O(k log n) heap vs naive recompute",
        ["Queue n", "k", "Heap (us)", "Naive (us)", "Speedup"], rows,
    ))
    # heap cost grows ~log n: ratio between largest and smallest n
    ns = sorted(out)
    growth = out[ns[-1]]["heap_us"] / out[ns[0]]["heap_us"]
    print(f"  heap per-round cost grew {growth:.1f}x for a "
          f"{ns[-1] // ns[0]}x deeper queue (log-like), naive grew "
          f"{out[ns[-1]]['naive_us'] / out[ns[0]]['naive_us']:.1f}x (linear)")
    save_json("bench_overhead.json", {str(k): v for k, v in out.items()})
    return out


if __name__ == "__main__":
    main()
