"""§3.1.4 scheduling-overhead claim: the static-key max-heap is O(k log n)
per round vs the naive full-recompute O(n) pop — measured wall time across
queue depths.  Also measures the full scheduler round's Python overhead
(schedule + on_batch_done, no execution) across decode-population sizes —
the cost that sits inside the serve loop's host bubble every round."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, save_json
from repro.core.policies import NaiveAgingQueue, make_policy
from repro.core.request import Request
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig


def bench_queue(n: int, k: int, reps: int = 5):
    """n waiting requests; k pops + re-inserts per round (one round)."""
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, 4096, n)
    arrivals = rng.random(n) * 100

    def mk_reqs():
        return [Request(prompt_len=int(p), max_new_tokens=1, arrival_time=float(a))
                for p, a in zip(prompts, arrivals)]

    heap_t = []
    for _ in range(reps):
        reqs = mk_reqs()
        q = make_policy("aging", alpha=1.0, beta=-0.1)
        for r in reqs:
            q.add(r)
        t0 = time.perf_counter()
        popped = [q.pop() for _ in range(k)]
        for r in popped:
            r.prefill_done = min(r.prompt_len - 1, r.prefill_done + 64)
            q.update(r)
        heap_t.append(time.perf_counter() - t0)

    naive_t = []
    for _ in range(reps):
        reqs = mk_reqs()
        q = NaiveAgingQueue(1.0, -0.1)
        for r in reqs:
            q.add(r)
        t0 = time.perf_counter()
        popped = [q.pop(now=200.0) for _ in range(k)]
        for r in popped:
            r.prefill_done = min(r.prompt_len - 1, r.prefill_done + 64)
            q.update(r)
        naive_t.append(time.perf_counter() - t0)

    return min(heap_t) * 1e6, min(naive_t) * 1e6   # us per round


def bench_scheduler_round(n_decoding: int, rounds: int = 50, reps: int = 3):
    """Per-round schedule() + on_batch_done() wall time with ``n_decoding``
    ongoing decode requests (the steady-state serving population; budget and
    max_seqs scale with it, as in a large-batch decode regime).  No
    execution — this is pure scheduler bookkeeping, i.e. host-bubble time."""
    best = float("inf")
    for _ in range(reps):
        sched = ChunkedPrefillScheduler(SchedulerConfig(
            policy="fcfs", token_budget=n_decoding + 64,
            max_seqs=n_decoding + 64,
        ))
        reqs = [
            Request(prompt_len=1, max_new_tokens=10**9, arrival_time=float(i))
            for i in range(n_decoding)
        ]
        for r in reqs:
            sched.submit(r)
        # one round drains every 1-token prefill: population is all-decoding
        b = sched.schedule(0.0)
        sched.on_batch_done(b, 0.0)
        assert len(sched.decoding) == n_decoding
        t0 = time.perf_counter()
        for i in range(rounds):
            b = sched.schedule(float(i))
            sched.on_batch_done(b, float(i))
        best = min(best, (time.perf_counter() - t0) / rounds)
    return best * 1e6    # us per round


def main(quick: bool = False):
    rows = []
    out = {}
    sizes = (100, 1000, 10_000) if quick else (100, 1000, 10_000, 100_000)
    for n in sizes:
        k = 8
        h, nv = bench_queue(n, k)
        out[n] = {"heap_us": h, "naive_us": nv}
        rows.append([f"{n:,}", k, f"{h:,.1f}", f"{nv:,.1f}", f"{nv / h:,.1f}x"])
    print(fmt_table(
        "Scheduling overhead per round — O(k log n) heap vs naive recompute",
        ["Queue n", "k", "Heap (us)", "Naive (us)", "Speedup"], rows,
    ))
    # heap cost grows ~log n: ratio between largest and smallest n
    ns = sorted(out)
    growth = out[ns[-1]]["heap_us"] / out[ns[0]]["heap_us"]
    print(f"  heap per-round cost grew {growth:.1f}x for a "
          f"{ns[-1] // ns[0]}x deeper queue (log-like), naive grew "
          f"{out[ns[-1]]['naive_us'] / out[ns[0]]['naive_us']:.1f}x (linear)")
    round_rows = []
    round_out = {}
    round_sizes = (1_000, 10_000) if quick else (1_000, 10_000, 100_000)
    for n in round_sizes:
        us = bench_scheduler_round(n, rounds=20 if n >= 100_000 else 50)
        round_out[n] = us
        round_rows.append([f"{n:,}", f"{us:,.1f}"])
    print(fmt_table(
        "Scheduler round overhead — schedule()+on_batch_done() vs decode population",
        ["Decoding n", "Round (us)"], round_rows,
    ))
    save_json("bench_overhead.json", {
        "queue": {str(k): v for k, v in out.items()},
        "scheduler_round_us": {str(k): v for k, v in round_out.items()},
    })
    return out


if __name__ == "__main__":
    main()
