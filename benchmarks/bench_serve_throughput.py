"""Serving hot-path throughput: synchronous vs pipelined round loop.

Measures, on one fixed seeded workload through the REAL JAX engine:
  * output tokens/s and total (prefill+decode) tokens/s,
  * per-round host-bubble time — the gap between the device finishing round
    N and the host dispatching round N+1.  The synchronous loop pays
    scheduling, aging/VTC bookkeeping, KV booking, staging AND the blocking
    token readback inside that gap; the pipelined loop overlaps all of the
    scheduling work with round N's execution and drains the readback as an
    async copy one round late, so only staging+dispatch remain.

Grid: {sync, pipelined} x {dense, paged} (pure-jnp oracle math), plus — with
``--pallas`` — a ``pages_per_tile`` sweep through the paged Pallas kernels
(interpret mode on CPU: correctness/plumbing, not kernel speed; the same
program compiles to Mosaic on TPU).

Writes ``BENCH_throughput.json`` at the repo root (the perf-trajectory
anchor: every future PR can compare against these numbers) and prints the
gate: pipelined mean host-bubble < sync mean host-bubble, identical greedy
outputs.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import fmt_table
from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_throughput.json")


def _workload(quick: bool, model_cfg):
    # arrivals all at t=0: admission is round-independent, so the sync and
    # pipelined loops see the SAME round structure and the output-identity
    # gate is exact (round durations differ between the loops; arrival-timed
    # admission would couple scheduling to them)
    spec = WorkloadSpec(
        n_requests=8 if quick else 24,
        inter_arrival_s=0.0,
        max_context=64 if quick else 128,
        max_new_tokens=8 if quick else 24,
        seed=12,
    )
    reqs = sharegpt_like(spec)
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=12)
    return reqs


def run_config(name: str, *, pipelined: bool, paged: bool, quick: bool,
               use_pallas: bool = False, pages_per_tile: int = 1,
               reps: int = 2):
    """Best-of-``reps`` (by wall time, like bench_overhead): a shared CI box
    can stall any single run; outputs must be identical across reps anyway."""
    best = None
    for _ in range(reps):
        r = _run_once(name, pipelined=pipelined, paged=paged, quick=quick,
                      use_pallas=use_pallas, pages_per_tile=pages_per_tile)
        if best is not None:
            assert r["outputs"] == best["outputs"], f"{name}: nondeterministic"
        if best is None or r["wall_s"] < best["wall_s"]:
            best = r
    return best


def _run_once(name: str, *, pipelined: bool, paged: bool, quick: bool,
              use_pallas: bool = False, pages_per_tile: int = 1):
    model_cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(model_cfg, EngineConfig(
        n_slots=8, max_context=256, paged_kv=paged, pipelined=pipelined,
        use_pallas=use_pallas, pages_per_tile=pages_per_tile,
        chunk_buckets=(1, 16, 32, 64),
    ))
    eng.warmup()      # steady-state: bubbles/walls must not include jit
    reqs = _workload(quick, model_cfg)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=8)
    )
    t0 = time.perf_counter()
    res = serve(reqs, sched, eng)
    wall_s = time.perf_counter() - t0
    out_tokens = sum(r.generated for r in reqs)
    total_tokens = sum(r.prompt_len + r.generated for r in reqs)
    bubbles = np.asarray(res.host_bubble_ms or [0.0])
    return {
        "name": name,
        "pipelined": pipelined,
        "paged": paged,
        "use_pallas": use_pallas,
        "pages_per_tile": pages_per_tile,
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall_s,
        "out_tok_s": out_tokens / wall_s,
        "total_tok_s": total_tokens / wall_s,
        "bubble_ms_mean": float(bubbles.mean()),
        "bubble_ms_p50": float(np.percentile(bubbles, 50)),
        "bubble_ms_p99": float(np.percentile(bubbles, 99)),
        # keyed by workload POSITION: req_ids are globally allocated and
        # differ between runs of the same seeded workload
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (tiny workload)")
    ap.add_argument("--pallas", action="store_true",
                    help="also sweep pages_per_tile through the paged Pallas "
                         "kernels (interpret mode on CPU)")
    ap.add_argument("--reps", type=int, default=2,
                    help="best-of-N runs per config (noise robustness)")
    args = ap.parse_args(argv)

    grid = [
        ("sync/dense", False, False),
        ("sync/paged", False, True),
        ("pipelined/dense", True, False),
        ("pipelined/paged", True, True),
    ]
    results = [
        run_config(name, pipelined=p, paged=g, quick=args.quick,
                   reps=args.reps)
        for name, p, g in grid
    ]
    if args.pallas:
        for ppt in (1, 2, 4):
            results.append(run_config(
                f"pipelined/paged/pallas/ppt={ppt}", pipelined=True,
                paged=True, quick=args.quick, use_pallas=True,
                pages_per_tile=ppt, reps=args.reps,
            ))

    rows = [
        [r["name"], r["finished"], r["rounds"], f"{r['out_tok_s']:.1f}",
         f"{r['total_tok_s']:.1f}", f"{r['bubble_ms_mean']:.3f}",
         f"{r['bubble_ms_p99']:.3f}"]
        for r in results
    ]
    print(fmt_table(
        "Serve throughput — sync vs pipelined round loop (real JAX engine)",
        ["config", "done", "rounds", "out tok/s", "tot tok/s",
         "bubble mean ms", "bubble p99 ms"],
        rows,
    ))

    by = {r["name"]: r for r in results}
    # gates: same greedy outputs, smaller host bubble, more tokens/s
    for layout in ("dense", "paged"):
        s, p = by[f"sync/{layout}"], by[f"pipelined/{layout}"]
        identical = s["outputs"] == p["outputs"]
        gain = p["out_tok_s"] / s["out_tok_s"] - 1.0
        shrink = 1.0 - p["bubble_ms_mean"] / max(s["bubble_ms_mean"], 1e-9)
        print(f"  {layout}: outputs identical={identical}  "
              f"bubble {s['bubble_ms_mean']:.3f} -> {p['bubble_ms_mean']:.3f} ms "
              f"({shrink:+.1%})  throughput {gain:+.1%}")
        assert identical, f"{layout}: pipelined outputs diverged from sync"

    payload = {
        "workload": {"quick": args.quick, "seed": 12},
        "results": [{k: v for k, v in r.items() if k != "outputs"}
                    for r in results],
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"  wrote {os.path.normpath(ROOT_JSON)}")
    return results


if __name__ == "__main__":
    main()
