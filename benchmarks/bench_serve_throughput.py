"""Serving hot-path throughput: synchronous vs pipelined round loop.

Measures, on one fixed seeded workload through the REAL JAX engine:
  * output tokens/s and total (prefill+decode) tokens/s,
  * per-round host-bubble time — the gap between the device finishing round
    N and the host dispatching round N+1.  The synchronous loop pays
    scheduling, aging/VTC bookkeeping, KV booking, staging AND the blocking
    token readback inside that gap; the pipelined loop overlaps all of the
    scheduling work with round N's execution and drains the readback as an
    async copy one round late, so only staging+dispatch remain.

Grid: {sync, pipelined} x {dense, paged} (pure-jnp oracle math), plus — with
``--pallas`` — a ``pages_per_tile`` sweep through the paged Pallas kernels
(interpret mode on CPU: correctness/plumbing, not kernel speed; the same
program compiles to Mosaic on TPU).

Writes ``BENCH_throughput.json`` at the repo root (the perf-trajectory
anchor: every future PR can compare against these numbers; one section per
workload mode — ``quick`` and ``full``) and prints the gate: pipelined mean
host-bubble < sync mean host-bubble, identical greedy outputs.

``--check-regression`` additionally compares the fresh numbers against the
COMMITTED baseline (loaded before the fresh write, which happens even on
failure so the CI artifact carries the regressing numbers) and fails on a
>25% throughput or host-bubble regression.  Comparisons are
machine-normalized: each config's metric is taken RELATIVE to the geometric
mean over all configs shared with the baseline, so a CI box that is
uniformly 2x slower than the box that committed the baseline still passes
and a lucky draw on any single config is damped by the grid, while a
regression localized to the pipelined loop, the paged layout, or the
kernels fails.  Suspect configs get ONE re-measurement (more reps) before
the gate fails — transient load spikes on shared boxes don't reproduce, a
real regression does.  ``BENCH_INJECT_BUBBLE_MS=<ms>`` injects an
artificial per-round stall into the PIPELINED configs — the knob used to
prove the gate actually fails when the hot path regresses.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import fmt_table
from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_throughput.json")


def _workload(quick: bool, model_cfg):
    # arrivals all at t=0: admission is round-independent, so the sync and
    # pipelined loops see the SAME round structure and the output-identity
    # gate is exact (round durations differ between the loops; arrival-timed
    # admission would couple scheduling to them)
    # quick must still produce enough rounds (>= MIN_ROUNDS_FOR_BUBBLE_GATE)
    # for per-round ratios to be stable: at ~12 rounds the pipelined:sync
    # throughput ratio itself swings >25% run-to-run and the regression gate
    # is pure noise
    spec = WorkloadSpec(
        n_requests=12 if quick else 24,
        inter_arrival_s=0.0,
        max_context=96 if quick else 128,
        max_new_tokens=16 if quick else 24,
        seed=12,
    )
    reqs = sharegpt_like(spec)
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=12)
    return reqs


def run_config(name: str, *, pipelined: bool, paged: bool, quick: bool,
               use_pallas: bool = False, pages_per_tile: int = 1,
               kv_layout: str = "split", buffering_depth: int = 1,
               reps: int = 2):
    """Best-of-``reps`` (by wall time, like bench_overhead): a shared CI box
    can stall any single run; outputs must be identical across reps anyway."""
    best = None
    for _ in range(reps):
        r = _run_once(name, pipelined=pipelined, paged=paged, quick=quick,
                      use_pallas=use_pallas, pages_per_tile=pages_per_tile,
                      kv_layout=kv_layout, buffering_depth=buffering_depth)
        if best is not None:
            assert r["outputs"] == best["outputs"], f"{name}: nondeterministic"
        if best is None or r["wall_s"] < best["wall_s"]:
            best = r
    return best


def _run_once(name: str, *, pipelined: bool, paged: bool, quick: bool,
              use_pallas: bool = False, pages_per_tile: int = 1,
              kv_layout: str = "split", buffering_depth: int = 1):
    model_cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(model_cfg, EngineConfig(
        n_slots=8, max_context=256, paged_kv=paged, pipelined=pipelined,
        use_pallas=use_pallas, pages_per_tile=pages_per_tile,
        kv_layout=kv_layout, buffering_depth=buffering_depth,
        chunk_buckets=(1, 16, 32, 64),
    ))
    eng.warmup()      # steady-state: bubbles/walls must not include jit
    inject_ms = float(os.environ.get("BENCH_INJECT_BUBBLE_MS", "0"))
    if inject_ms > 0 and pipelined:
        # regression-gate self-test: stall the pipelined hot path per round
        real_dispatch = eng.dispatch

        def slow_dispatch(batch):
            time.sleep(inject_ms / 1e3)
            return real_dispatch(batch)

        eng.dispatch = slow_dispatch
    reqs = _workload(quick, model_cfg)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=8)
    )
    t0 = time.perf_counter()
    res = serve(reqs, sched, eng)
    wall_s = time.perf_counter() - t0
    out_tokens = sum(r.generated for r in reqs)
    total_tokens = sum(r.prompt_len + r.generated for r in reqs)
    bubbles = np.asarray(res.host_bubble_ms or [0.0])
    return {
        "name": name,
        "pipelined": pipelined,
        "paged": paged,
        "use_pallas": use_pallas,
        "pages_per_tile": pages_per_tile,
        "kv_layout": kv_layout,
        "buffering_depth": buffering_depth,
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall_s,
        "out_tok_s": out_tokens / wall_s,
        "total_tok_s": total_tokens / wall_s,
        "bubble_ms_mean": float(bubbles.mean()),
        "bubble_ms_p50": float(np.percentile(bubbles, 50)),
        "bubble_ms_p99": float(np.percentile(bubbles, 99)),
        # keyed by workload POSITION: req_ids are globally allocated and
        # differ between runs of the same seeded workload
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }


REGRESSION_TOL = 0.25                  # fail beyond 25% relative drift
# the host-bubble gate needs enough rounds to average out scheduling jitter:
# measured on quick runs (~38 rounds) the per-config bubble-mean RATIO still
# swings ±50% run-to-run (1-2 ms means are OS-scheduling noise), while the
# throughput ratio holds within ~±16%.  So quick runs gate on throughput
# only (the injected-slowdown self-test trips that gate regardless) and the
# bubble ratio is gated on full-scale runs
MIN_ROUNDS_FOR_BUBBLE_GATE = 60


def _load_sections() -> dict:
    """BENCH_throughput.json as a ``{mode_key: payload}`` dict, migrating
    the pre-PR-5 single-section schema (treated as ``full``).  Shared by
    the baseline read and the preserve-other-section write."""
    try:
        with open(ROOT_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    if "results" in data:              # legacy flat schema
        data = {"full": data}
    return data


def _load_baseline(mode_key: str):
    """The committed baseline section for this workload mode (``quick`` /
    ``full``), or None when no comparable baseline exists."""
    return _load_sections().get(mode_key)


def _geomean(xs) -> float:
    arr = np.asarray([max(x, 1e-9) for x in xs], np.float64)
    return float(np.exp(np.log(arr).mean()))


def check_regression(results, baseline) -> list:
    """Compare fresh results to the committed baseline, machine-normalized:
    each config's throughput / host-bubble is expressed relative to the
    GEOMETRIC MEAN over all configs shared with the baseline, so uniform
    machine-speed differences cancel and a lucky/unlucky draw on any single
    config (including a would-be reference) is damped by the whole grid —
    only drift localized to a config (pipelined loop, paged layout, kernels)
    trips the gate.  Returns a list of failure strings naming the suspect
    configs."""
    fresh = {r["name"]: r for r in results}
    base = {r["name"]: r for r in baseline["results"]}
    shared = sorted(set(fresh) & set(base))
    if len(shared) < 2:
        return []                       # nothing comparable
    f_ref = _geomean(fresh[n]["out_tok_s"] for n in shared)
    b_ref = _geomean(base[n]["out_tok_s"] for n in shared)
    f_ref_bb = _geomean(fresh[n]["bubble_ms_mean"] for n in shared)
    b_ref_bb = _geomean(base[n]["bubble_ms_mean"] for n in shared)
    failures = []
    for name in shared:
        f, b = fresh[name], base[name]
        # throughput, relative to the grid (higher is better)
        f_tp = f["out_tok_s"] / f_ref
        b_tp = b["out_tok_s"] / b_ref
        if f_tp < b_tp * (1.0 - REGRESSION_TOL):
            failures.append(
                f"{name}: relative throughput {f_tp:.3f} < baseline "
                f"{b_tp:.3f} - {REGRESSION_TOL:.0%}"
            )
        # host bubble, relative to the grid (lower is better); only on runs
        # long enough for the per-round mean to be stable
        if min(f["rounds"], b["rounds"]) >= MIN_ROUNDS_FOR_BUBBLE_GATE:
            f_bb = f["bubble_ms_mean"] / f_ref_bb
            b_bb = b["bubble_ms_mean"] / b_ref_bb
            if f_bb > b_bb * (1.0 + REGRESSION_TOL):
                failures.append(
                    f"{name}: relative host bubble {f_bb:.3f} > baseline "
                    f"{b_bb:.3f} + {REGRESSION_TOL:.0%}"
                )
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (tiny workload)")
    ap.add_argument("--pallas", action="store_true",
                    help="also sweep pages_per_tile through the paged Pallas "
                         "kernels (interpret mode on CPU)")
    ap.add_argument("--sweep-buffering", action="store_true",
                    help="also sweep {split,fused} KV layout x DMA buffering "
                         "depth {1,2} through the pipelined paged engine "
                         "(with --pallas: through the Pallas kernels)")
    ap.add_argument("--reps", type=int, default=2,
                    help="best-of-N runs per config (noise robustness)")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail (exit 1) when throughput or host bubble "
                         f"regresses >{REGRESSION_TOL:.0%} vs the committed "
                         "BENCH_throughput.json baseline (machine-normalized "
                         "against the geometric mean over shared configs; "
                         "suspects get one re-measurement before failing)")
    args = ap.parse_args(argv)

    cfg_by_name = {
        "sync/dense": dict(pipelined=False, paged=False),
        "sync/paged": dict(pipelined=False, paged=True),
        "pipelined/dense": dict(pipelined=True, paged=False),
        "pipelined/paged": dict(pipelined=True, paged=True),
    }
    if args.pallas:
        for ppt in (1, 2, 4):
            cfg_by_name[f"pipelined/paged/pallas/ppt={ppt}"] = dict(
                pipelined=True, paged=True, use_pallas=True,
                pages_per_tile=ppt,
            )
    if args.sweep_buffering:
        for layout in ("split", "fused"):
            for depth in (1, 2):
                cfg_by_name[f"pipelined/paged/{layout}/depth={depth}"] = dict(
                    pipelined=True, paged=True, use_pallas=args.pallas,
                    kv_layout=layout, buffering_depth=depth,
                )
    results = [
        run_config(name, quick=args.quick, reps=args.reps, **kw)
        for name, kw in cfg_by_name.items()
    ]

    rows = [
        [r["name"], r["finished"], r["rounds"], f"{r['out_tok_s']:.1f}",
         f"{r['total_tok_s']:.1f}", f"{r['bubble_ms_mean']:.3f}",
         f"{r['bubble_ms_p99']:.3f}"]
        for r in results
    ]
    print(fmt_table(
        "Serve throughput — sync vs pipelined round loop (real JAX engine)",
        ["config", "done", "rounds", "out tok/s", "tot tok/s",
         "bubble mean ms", "bubble p99 ms"],
        rows,
    ))

    by = {r["name"]: r for r in results}
    # gates: same greedy outputs, smaller host bubble, more tokens/s
    for layout in ("dense", "paged"):
        s, p = by[f"sync/{layout}"], by[f"pipelined/{layout}"]
        identical = s["outputs"] == p["outputs"]
        gain = p["out_tok_s"] / s["out_tok_s"] - 1.0
        shrink = 1.0 - p["bubble_ms_mean"] / max(s["bubble_ms_mean"], 1e-9)
        print(f"  {layout}: outputs identical={identical}  "
              f"bubble {s['bubble_ms_mean']:.3f} -> {p['bubble_ms_mean']:.3f} ms "
              f"({shrink:+.1%})  throughput {gain:+.1%}")
        assert identical, f"{layout}: pipelined outputs diverged from sync"

    buffering = fused_layout = None
    if args.sweep_buffering:
        def sweep(layout, depth):
            return by[f"pipelined/paged/{layout}/depth={depth}"]
        # the knobs are pure data movement: greedy outputs must not budge
        # across any (layout, depth) cell vs the plain pipelined/paged run
        for layout in ("split", "fused"):
            for depth in (1, 2):
                assert sweep(layout, depth)["outputs"] == \
                    by["pipelined/paged"]["outputs"], (
                        f"{layout}/depth={depth}: outputs diverged")
        buffering = {}
        for layout in ("split", "fused"):
            d1, d2 = sweep(layout, 1), sweep(layout, 2)
            ratio = d2["out_tok_s"] / d1["out_tok_s"]
            buffering[layout] = {
                "depth1_out_tok_s": d1["out_tok_s"],
                "depth2_out_tok_s": d2["out_tok_s"],
                "depth2_vs_depth1": ratio,
            }
            print(f"  buffering {layout}: depth 1 -> 2 throughput "
                  f"x{ratio:.3f}")
            # wall-clock gate on full runs only (repo convention: quick runs
            # are too short for stable ratios); interpret mode can't show a
            # real overlap win, so depth 2 must merely not REGRESS
            if not args.quick:
                assert ratio >= 1.0 - REGRESSION_TOL, (
                    f"{layout}: depth-2 throughput regressed x{ratio:.3f}")
        fused_layout = {
            f"depth={d}": sweep("fused", d)["out_tok_s"]
            / sweep("split", d)["out_tok_s"]
            for d in (1, 2)
        }
        for k, v in fused_layout.items():
            print(f"  fused vs split ({k}): throughput x{v:.3f}")

    mode_key = "quick" if args.quick else "full"
    stripped = [{k: v for k, v in r.items() if k != "outputs"}
                for r in results]

    # load the committed baseline BEFORE overwriting it, but write the fresh
    # numbers unconditionally: on a gate failure the uploaded CI artifact
    # must carry the regressing measurements, not the stale baseline
    baseline = _load_baseline(mode_key) if args.check_regression else None

    def write_results():
        data = _load_sections()        # preserve the other mode's section
        data[mode_key] = {
            "workload": {"quick": args.quick, "seed": 12},
            "results": stripped,
        }
        if buffering is not None:
            # layout/depth summary ratios: the sweep's per-config rows are in
            # "results" (and under the --check-regression gate by name)
            data[mode_key]["buffering"] = buffering
            data[mode_key]["fused_layout"] = fused_layout
        with open(ROOT_JSON, "w") as f:
            json.dump(data, f, indent=1)
        print(f"  wrote {os.path.normpath(ROOT_JSON)} [{mode_key}]")

    write_results()
    if args.check_regression:
        if baseline is None:
            print(f"  no committed {mode_key!r} baseline to compare against")
        else:
            failures = check_regression(stripped, baseline)
            if failures:
                # one re-measurement before failing: a transient load spike
                # on a shared box mimics a localized regression; a REAL
                # regression reproduces in the second sample too.  Suspects
                # re-run with more reps and keep their better (faster-wall)
                # sample, same best-of semantics as the first pass.
                suspects = sorted({m.split(":")[0] for m in failures})
                print(f"  gate tripped; re-measuring suspects: {suspects}")
                for nm in suspects:
                    r2 = run_config(nm, quick=args.quick,
                                    reps=args.reps + 1, **cfg_by_name[nm])
                    for i, r in enumerate(stripped):
                        if r["name"] == nm and r2["wall_s"] < r["wall_s"]:
                            stripped[i] = {k: v for k, v in r2.items()
                                           if k != "outputs"}
                write_results()
                failures = check_regression(stripped, baseline)
            for msg in failures:
                print(f"  REGRESSION: {msg}")
            if failures:
                raise SystemExit(1)
            print(f"  regression gate passed vs committed {mode_key!r} "
                  f"baseline (tolerance {REGRESSION_TOL:.0%}, normalized to "
                  "the shared-config geometric mean)")
    return results


if __name__ == "__main__":
    main()
