"""Run every benchmark: ``PYTHONPATH=src python -m benchmarks.run [--quick]``.

One module per paper table/figure (DESIGN.md §9):
  bench_aging        Table 4 + Fig 4 + §4.3.1 decomposition + starvation stress
  bench_sensitivity  Figs 5/6
  bench_multireplica Table 5 + fault-tolerance scenarios
  bench_predictor    Table 8
  bench_lprs         Table 9
  bench_apc          Table 10
  bench_overhead     §3.1.4 O(k log n) claim
  roofline           §Roofline report from the dry-run records
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (
    bench_aging, bench_apc, bench_lprs, bench_multireplica, bench_overhead,
    bench_predictor, bench_sensitivity, roofline,
)

MODULES = [
    ("Aging (Table 4, Fig 4)", bench_aging),
    ("Sensitivity (Figs 5/6)", bench_sensitivity),
    ("Multi-replica (Table 5)", bench_multireplica),
    ("Predictor (Table 8)", bench_predictor),
    ("LPRS (Table 9)", bench_lprs),
    ("APC (Table 10)", bench_apc),
    ("Scheduler overhead", bench_overhead),
    ("Roofline", roofline),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced request counts / epochs")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args(argv)

    failures = 0
    for name, mod in MODULES:
        if args.only and args.only.lower() not in name.lower():
            continue
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
            print(f"  [{name}] done in {time.time() - t0:.1f}s")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"  [{name}] FAILED")
    print(f"\n{'=' * 72}")
    print(f"benchmarks complete: {len(MODULES) - failures}/{len(MODULES)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
