"""Paper Table 10: APC ablation — LPRS with and without Active Prefill
Control under decode-dominated high contention.

Construction (§3.3's own setting): a pinned population of long-running
decode requests holds the per-round decode cost just under the LPRS target
T*, so every waiting prefill is offered only fragment chunks; a stream of
arriving prefill-heavy requests (49:1 short:long, the paper's mix) then
queues behind that decode floor.  Without APC the residual budget shatters
into 1-token micro-chunks across the queue (budget dilution +
micro-progress); with APC the cap + minimum-effective-chunk rules keep a
small number of meaningful prefills advancing."""
from __future__ import annotations

import numpy as np

from benchmarks.bench_lprs import train_predictor
from benchmarks.common import BASE, calibrate_round_ms, fmt_table, save_json, scaled
from repro.core.apc import APCConfig
from repro.core.lprs import LPRSConfig
from repro.core.request import Request
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.simulator import ServingSimulator
from repro.engine.workload import apc_heterogeneous

T_STAR = 105.0
BUDGET = 1024
MAX_SEQS = 512


def decode_floor_population(cfg_cost, k, *, headroom_ms=8.0):
    """How many pinned decoders put the decode-only round at T* - headroom
    (mid-run context ~500 tokens)."""
    per_dec = (cfg_cost.c_decode_ms + cfg_cost.c_seq_ms
               + cfg_cost.c_ctx_ms * 500.0) * 1.0
    fixed = cfg_cost.c0_ms
    n = int((T_STAR - headroom_ms - fixed) / per_dec)
    return max(8, n)


def run_once(apc, k, n_arrivals, seed=11):
    cm = scaled(BASE, k)
    n_dec = decode_floor_population(cm, k)
    pred = run_once.pred

    sched = ChunkedPrefillScheduler(
        SchedulerConfig(
            policy="fcfs", token_budget=BUDGET, max_seqs=MAX_SEQS,
            lprs=LPRSConfig(target_latency_ms=T_STAR, search_delta=64,
                            lambda_under=1.0, lambda_over=3.0),
            apc=apc,
        ),
        predictor=pred,
    )
    # pinned decode floor: 2-token prompts, effectively infinite generations
    pinned = [
        Request(prompt_len=2, max_new_tokens=10**6, arrival_time=-1.0)
        for _ in range(n_dec)
    ]
    # arriving prefill cohort: the paper's 49:1 short:long heterogeneous mix
    cohort = apc_heterogeneous(n_requests=n_arrivals, base_interval_s=0.05,
                               max_new_tokens=16, seed=seed)
    sim = ServingSimulator(sched, CostModel(cm), max_rounds=60_000)
    sim.run(pinned + cohort)

    st = sched.stats
    done = [r for r in cohort if r.finish_time is not None]
    pf = np.asarray([r.prefill_e2e() * 1e3 for r in cohort
                     if r.prefill_e2e() is not None])
    e2e = np.asarray([r.e2e_latency() * 1e3 for r in done])
    return {
        "completed": f"{len(done)}/{len(cohort)}",
        "mean_req_e2e_ms": float(e2e.mean()) if len(e2e) else float("inf"),
        "mean_prefill_e2e_ms": float(pf.mean()) if len(pf) else float("inf"),
        "p90_req_e2e_ms": float(np.percentile(e2e, 90)) if len(e2e) else float("inf"),
        "p90_prefill_e2e_ms": float(np.percentile(pf, 90)) if len(pf) else float("inf"),
        "avg_sched_prefill_seqs": st.avg_prefill_seqs_per_round,
        "avg_prefill_chunk": st.avg_tokens_per_prefill_seq,
        "blocked_by_cap": st.apc.blocked_by_cap,
        "blocked_by_min_chunk": st.apc.blocked_by_min_chunk,
        "warm_starts": st.apc.warm_starts,
        "n_decode_floor": decode_floor_population(cm, k),
    }


def main(quick: bool = False):
    k = calibrate_round_ms(T_STAR, BUDGET)
    run_once.pred = train_predictor(k, quick)
    n = 150 if quick else 500

    out = {}
    for label, apc in (("APC Off", None),
                       ("APC On", APCConfig(c_max=2, l_min=64))):
        out[label] = run_once(apc, k, n)

    rows = []
    keys = [
        ("Cohort completed", "completed"),
        ("Mean Request E2E (ms)", "mean_req_e2e_ms"),
        ("Mean Prefill E2E (ms)", "mean_prefill_e2e_ms"),
        ("P90 Request E2E (ms)", "p90_req_e2e_ms"),
        ("P90 Prefill E2E (ms)", "p90_prefill_e2e_ms"),
        ("Avg Scheduled Prefill Seqs", "avg_sched_prefill_seqs"),
        ("Avg Prefill Chunk Size", "avg_prefill_chunk"),
        ("Blocked by Activity Cap", "blocked_by_cap"),
        ("Blocked by Min Effective Chunk", "blocked_by_min_chunk"),
        ("Warm starts", "warm_starts"),
    ]
    for name, key in keys:
        off, on = out["APC Off"][key], out["APC On"][key]
        chg = (f"{100 * (on - off) / off:+.2f}%"
               if isinstance(off, float) and np.isfinite(off) and off else "-")
        fmt = (lambda v: f"{v:,.2f}") if isinstance(off, float) else str
        rows.append([name, fmt(off), fmt(on), chg])
    print(fmt_table(
        f"Table 10 — APC ablation (decode floor ~{out['APC On']['n_decode_floor']}"
        f" seqs at T*={T_STAR:.0f} ms)",
        ["Metric", "APC Off", "APC On", "Change"], rows,
    ))
    print("  paper: mean E2E -22.26%, seqs/round 5.32->0.46, chunk 0.78->6.29,"
          " interventions 4960/1541")
    save_json("bench_apc.json", out)
    return out


if __name__ == "__main__":
    main()
