"""KV memory subsystem benchmark: prefix-cache reuse + chunk-granular
admission vs the eager-allocation baseline.

Experiment A — prefix caching on a ``shared_prefix()`` workload (every prompt
is one of a few shared system prompts plus a unique suffix, the chat/RAG
pattern).  With the hash-based block cache enabled, repeats of a prefix skip
the matched prefill compute, so block-level hit rate is high and mean/P99
TTFT strictly improve over the identical workload with caching disabled.

Experiment B — head-of-line blocking under memory pressure.  A few huge
prompts arrive just before a stream of short interactive requests, on a pool
sized so one long prompt occupies most of it.  The legacy policy (whole-
prompt block allocation at admission, ``break`` when it doesn't fit) wedges
every short request behind the second long prompt; chunk-granular allocation
admits everyone, feeds long prompts whatever blocks are free each round, and
preempts youngest-first when decode needs room.

Acceptance gates (printed as PASS/FAIL at the end):
  A1. block cache hit rate > 0 with caching on
  A2. mean TTFT (cache on) < mean TTFT (cache off); P99 reported alongside
  B1. short-request mean TTFT (chunk-granular) < (eager baseline)
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import fmt_table, save_json
from repro.core.request import Request
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.simulator import run_policy
from repro.engine.workload import shared_prefix

# the paper's overload regime is irrelevant here: use a moderately loaded
# engine so TTFT differences isolate the memory subsystem, not queue depth
COST = CostModelConfig(noise_std=0.0)
ALPHA, BETA = 1.0, -0.01


def sched_cfg(budget: int = 512, max_seqs: int = 64) -> SchedulerConfig:
    return SchedulerConfig(policy="aging", alpha=ALPHA, beta=BETA,
                           token_budget=budget, max_seqs=max_seqs)


def pool(n_blocks: int, cache: bool) -> KVBlockPool:
    return KVBlockPool(KVPoolConfig(
        n_blocks=n_blocks, block_size=16, bytes_per_token=1024,
        enable_prefix_cache=cache,
    ))


# ---------------------------------------------------------------------------
# A: shared-prefix workload, caching on vs off
# ---------------------------------------------------------------------------


def run_prefix_experiment(n_requests: int, seed: int):
    def wl():
        return shared_prefix(
            n_requests=n_requests, n_prefixes=4, prefix_len=256,
            suffix_range=(16, 64), max_new_tokens=32,
            inter_arrival_s=0.03, seed=seed,
        )

    out = {}
    for label, cache in (("cache off", False), ("cache on", True)):
        res = run_policy(wl(), sched_cfg(), cost_model=CostModel(COST),
                         kv_pool=pool(4096, cache))
        out[label] = {
            "mean_ttft": res.report.ttft["mean"],
            "p99_ttft": res.report.ttft["p99"],
            "mean_e2e": res.report.e2e["mean"],
            "hit_rate": res.memory.cache_hit_rate,
            "hit_tokens": res.memory.cache_hit_tokens,
            "finished": res.report.n_finished,
        }
    return out


# ---------------------------------------------------------------------------
# A': real-engine smoke on the PAGED path (zero-copy prefix restores)
# ---------------------------------------------------------------------------


def run_engine_paged_smoke(n_requests: int, seed: int):
    """Experiment A on the real JAXEngine with the paged block-table KV
    layout: prefix hits restore by pointing block tables at still-resident
    pages (no payload copy).  Tiny model on CPU — gate is correctness +
    positive hit rate, not absolute latency."""
    from repro.configs import tiny_config
    from repro.engine.engine import EngineConfig, JAXEngine, serve
    from repro.engine.workload import shared_prefix as _shared
    from repro.engine.kv_cache import KVBlockPool, KVPoolConfig

    model_cfg = tiny_config("qwen1.5-0.5b")
    out = {}
    for label, cache in (("cache off", False), ("cache on", True)):
        engine = JAXEngine(model_cfg, EngineConfig(n_slots=8, max_context=256))
        reqs = _shared(n_requests=n_requests, n_prefixes=2, prefix_len=64,
                       suffix_range=(8, 24), max_new_tokens=8,
                       inter_arrival_s=0.02, vocab_size=model_cfg.vocab_size,
                       seed=seed)
        res = serve(
            reqs,
            ChunkedPrefillScheduler(sched_cfg(budget=128, max_seqs=8)),
            engine,
            kv_pool=KVBlockPool(KVPoolConfig(
                n_blocks=512, block_size=16, bytes_per_token=64,
                enable_prefix_cache=cache,
            )),
        )
        out[label] = {
            "finished": res.report.n_finished,
            "hit_rate": res.memory.cache_hit_rate,
            "hit_tokens": res.memory.cache_hit_tokens,
            "mean_ttft": res.report.ttft["mean"],
        }
    return out


# ---------------------------------------------------------------------------
# B: long-prompt adversary, eager vs chunk-granular allocation
# ---------------------------------------------------------------------------


def adversarial_workload(n_short: int, seed: int) -> List[Request]:
    """3 huge prompts just ahead of a stream of short interactive requests;
    one huge prompt needs ~60% of the pool's blocks."""
    rng = np.random.default_rng(seed)
    reqs = [Request(prompt_len=600, max_new_tokens=12, arrival_time=0.001 * i)
            for i in range(3)]
    reqs += [
        Request(prompt_len=int(rng.integers(24, 48)), max_new_tokens=8,
                arrival_time=0.01 + 0.004 * i)
        for i in range(n_short)
    ]
    return reqs


def run_hol_experiment(n_short: int, seed: int):
    out = {}
    for label, legacy in (("eager (legacy)", True), ("chunk-granular", False)):
        res = run_policy(
            adversarial_workload(n_short, seed),
            sched_cfg(budget=256, max_seqs=64),
            cost_model=CostModel(COST),
            kv_pool=pool(64, cache=False),
            legacy_eager_kv=legacy,
        )
        shorts = [r for r in res.requests if r.prompt_len < 600]
        ttfts = [r.ttft() for r in shorts if r.ttft() is not None]
        out[label] = {
            "short_mean_ttft": float(np.mean(ttfts)),
            "short_p99_ttft": float(np.percentile(ttfts, 99)),
            "finished": res.report.n_finished,
            "preemptions": res.scheduler_stats.preemptions,
            "kv_deferrals": res.scheduler_stats.kv_deferrals,
        }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny settings for CI smoke")
    ap.add_argument("--engine", action="store_true",
                    help="also run the real-engine smoke on the paged "
                         "block-table KV path (zero-copy prefix restores)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    n_req = 60 if args.quick else 300
    n_short = 20 if args.quick else 60

    prefix = run_prefix_experiment(n_req, args.seed)
    hol = run_hol_experiment(n_short, args.seed)
    engine_smoke = (
        run_engine_paged_smoke(12 if args.quick else 32, args.seed)
        if args.engine else None
    )

    rows = [
        [label,
         f"{r['hit_rate']:.1%}",
         f"{r['hit_tokens']:.0f}",
         f"{r['mean_ttft'] * 1e3:.1f}ms",
         f"{r['p99_ttft'] * 1e3:.1f}ms",
         f"{r['mean_e2e'] * 1e3:.1f}ms"]
        for label, r in prefix.items()
    ]
    print(fmt_table(
        f"Prefix cache — shared-prefix workload ({n_req} reqs, 4 prefixes x 256 tok)",
        ["Config", "Hit rate", "Hit tokens", "Mean TTFT", "P99 TTFT", "Mean E2E"],
        rows,
    ))

    rows = [
        [label,
         f"{r['short_mean_ttft'] * 1e3:.1f}ms",
         f"{r['short_p99_ttft'] * 1e3:.1f}ms",
         f"{r['preemptions']}",
         f"{r['kv_deferrals']}"]
        for label, r in hol.items()
    ]
    print()
    print(fmt_table(
        f"HoL blocking — 3 x 600-tok prompts vs {n_short} short reqs, 64-block pool",
        ["Admission", "Short mean TTFT", "Short P99 TTFT", "Preempt", "Defer"],
        rows,
    ))

    if engine_smoke is not None:
        rows = [
            [label, f"{r['finished']}", f"{r['hit_rate']:.1%}",
             f"{r['hit_tokens']:.0f}", f"{r['mean_ttft'] * 1e3:.1f}ms"]
            for label, r in engine_smoke.items()
        ]
        print()
        print(fmt_table(
            "Real engine (paged KV, zero-copy prefix restore)",
            ["Config", "Finished", "Hit rate", "Hit tokens", "Mean TTFT"],
            rows,
        ))

    # -- acceptance gates ----------------------------------------------------
    on, off = prefix["cache on"], prefix["cache off"]
    gate_a1 = on["hit_rate"] > 0
    gate_a2 = on["mean_ttft"] < off["mean_ttft"]
    gate_b1 = (hol["chunk-granular"]["short_mean_ttft"]
               < hol["eager (legacy)"]["short_mean_ttft"])
    gate_c1 = True
    if engine_smoke is not None:
        gate_c1 = (engine_smoke["cache on"]["hit_rate"] > 0
                   and all(r["finished"] == engine_smoke["cache off"]["finished"]
                           for r in engine_smoke.values()))
    print(f"\n  gate A1 [{'PASS' if gate_a1 else 'FAIL'}] "
          f"block cache hit rate {on['hit_rate']:.1%} > 0")
    print(f"  gate A2 [{'PASS' if gate_a2 else 'FAIL'}] "
          f"mean TTFT {off['mean_ttft'] * 1e3:.1f}ms -> "
          f"{on['mean_ttft'] * 1e3:.1f}ms with caching")
    print(f"  gate B1 [{'PASS' if gate_b1 else 'FAIL'}] short mean TTFT "
          f"{hol['eager (legacy)']['short_mean_ttft'] * 1e3:.1f}ms (eager) -> "
          f"{hol['chunk-granular']['short_mean_ttft'] * 1e3:.1f}ms (chunked)")
    if engine_smoke is not None:
        print(f"  gate C1 [{'PASS' if gate_c1 else 'FAIL'}] paged engine: "
              f"hit rate {engine_smoke['cache on']['hit_rate']:.1%} > 0, "
              f"all requests finished")

    save_json("bench_prefix_cache.json", {
        "seed": args.seed, "prefix": prefix, "hol": hol,
        "engine_paged": engine_smoke,
        "gates": {"hit_rate_positive": bool(gate_a1),
                  "ttft_improves_with_cache": bool(gate_a2),
                  "chunked_beats_eager_hol": bool(gate_b1),
                  "paged_engine_smoke": bool(gate_c1)},
    })
    return prefix, hol


if __name__ == "__main__":
    main()
