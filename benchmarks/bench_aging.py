"""Paper Table 4 + Fig 4: Aging vs FCFS under the 200-request mixed
workload, chunk sizes 256/512/1024, plus the latency decomposition
(§4.3.1 pt.3: the gain is queueing, not execution) and a beyond-paper
starvation stress (Aging vs SJF under sustained arrivals)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BASE,
    calibrate_multiplier,
    fmt_table,
    paper_workload,
    pct_change,
    save_json,
    scaled,
)
from repro.core.scheduler import SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.metrics import cdf_points
from repro.engine.simulator import run_policy
from repro.engine.workload import WorkloadSpec, sharegpt_like

ALPHA, BETA = 1.0, -0.1
MAX_SEQS = 48


def run_table4(n: int = 200, seed: int = 0):
    k = calibrate_multiplier(n=n, seed=seed)
    rows = []
    raw = {}
    for chunk in (256, 512, 1024):
        for policy in ("fcfs", "aging"):
            res = run_policy(
                paper_workload(n, seed),
                SchedulerConfig(policy=policy, alpha=ALPHA, beta=BETA,
                                token_budget=chunk, max_seqs=MAX_SEQS),
                cost_model=CostModel(scaled(BASE, k)),
            )
            r = res.report
            raw[f"{chunk}/{policy}"] = r.row()
            rows.append([
                chunk, policy.upper(),
                f"{r.e2e['mean']:.2f}s", f"{r.e2e['p95']:.2f}s",
                f"{r.ttft['mean']:.2f}s", f"{r.ttft['p95']:.2f}s",
            ])
    print(fmt_table(
        "Table 4 — Aging vs FCFS, 200-request mixed workload",
        ["Chunk", "Policy", "Mean E2E", "P95 E2E", "Mean TTFT", "P95 TTFT"],
        rows,
    ))
    for chunk in (256, 512, 1024):
        f, a = raw[f"{chunk}/fcfs"], raw[f"{chunk}/aging"]
        print(f"  chunk {chunk}: mean E2E {pct_change(a['mean_e2e'], f['mean_e2e'])}, "
              f"mean TTFT {pct_change(a['mean_ttft'], f['mean_ttft'])} "
              f"(paper: -10.24%, -11.27% at 256; shrinking toward 1024)")
    return raw


def run_decomposition(n: int = 200, seed: int = 0):
    """§4.3.1 pt 3: decompose E2E into queueing wait vs execution."""
    k = calibrate_multiplier(n=n, seed=seed)
    out = {}
    for policy in ("fcfs", "aging"):
        reqs = paper_workload(n, seed)
        run_policy(
            reqs,
            SchedulerConfig(policy=policy, alpha=ALPHA, beta=BETA,
                            token_budget=256, max_seqs=MAX_SEQS),
            cost_model=CostModel(scaled(BASE, k)),
        )
        # execution time of a request ~ time from first chunk to finish is
        # entangled with batching; use prefill-wait = prefill_e2e as queueing
        # proxy and (e2e - ttft) as post-first-token service
        wait = np.mean([r.prefill_e2e() for r in reqs])
        exec_ = np.mean([r.e2e_latency() - r.ttft() for r in reqs])
        out[policy] = (wait, exec_)
        print(f"  {policy:6s}: mean scheduling wait {wait:7.2f}s | "
              f"post-TTFT service {exec_:7.2f}s")
    dw = pct_change(out["aging"][0], out["fcfs"][0])
    de = pct_change(out["aging"][1], out["fcfs"][1])
    print(f"  -> queueing wait {dw}, service {de} "
          f"(paper: all gain from queueing; execution unchanged)")
    return out


def run_cdf(n: int = 200, seed: int = 0):
    """Fig 4: E2E CDF, Aging left of FCFS for most of the mass."""
    k = calibrate_multiplier(n=n, seed=seed)
    cdfs = {}
    for policy in ("fcfs", "aging"):
        reqs = paper_workload(n, seed)
        run_policy(
            reqs,
            SchedulerConfig(policy=policy, alpha=ALPHA, beta=BETA,
                            token_budget=256, max_seqs=MAX_SEQS),
            cost_model=CostModel(scaled(BASE, k)),
        )
        cdfs[policy] = cdf_points([r.e2e_latency() for r in reqs], n=21)
    print("\n  E2E CDF (s at quantile):")
    print("  q     " + "".join(f"{q:7.2f}" for _, q in cdfs["fcfs"][::4]))
    for p in ("fcfs", "aging"):
        print(f"  {p:6s}" + "".join(f"{v:7.1f}" for v, _ in cdfs[p][::4]))
    frac_left = np.mean([
        a[0] <= f[0] + 1e-9 for a, f in zip(cdfs["aging"], cdfs["fcfs"])
    ])
    print(f"  Aging CDF left-of-or-equal FCFS at {frac_left:.0%} of quantiles")
    return cdfs


def run_starvation_stress(seed: int = 0):
    """Beyond-paper: sustained arrivals — SJF starves long prompts, Aging
    bounds their tail (the paper's starvation argument, §3.1.1, measured)."""
    k = calibrate_multiplier(seed=seed)
    reqs_spec = WorkloadSpec(n_requests=400, inter_arrival_s=0.1,
                             max_context=512, max_new_tokens=128, seed=seed)
    rows = []
    for policy, beta in (("sjf", -0.01), ("aging", BETA), ("fcfs", -0.01)):
        reqs = sharegpt_like(reqs_spec)
        run_policy(
            reqs,
            SchedulerConfig(policy=policy, alpha=ALPHA, beta=beta,
                            token_budget=256, max_seqs=MAX_SEQS),
            cost_model=CostModel(scaled(BASE, k)),
        )
        long_reqs = [r for r in reqs if r.prompt_len >= 180]
        ttfts = sorted(r.ttft() for r in long_reqs)
        p99 = ttfts[int(0.99 * (len(ttfts) - 1))]
        rows.append([policy.upper(), len(long_reqs), f"{np.mean(ttfts):.1f}s",
                     f"{p99:.1f}s", f"{ttfts[-1]:.1f}s"])
    print(fmt_table(
        "Starvation stress — long-prompt (>=180 tok) TTFT under sustained load",
        ["Policy", "N_long", "Mean TTFT", "P99 TTFT", "Max TTFT"], rows,
    ))
    return rows


def main(quick: bool = False):
    n = 100 if quick else 200
    t4 = run_table4(n)
    run_decomposition(n)
    run_cdf(n)
    run_starvation_stress()
    save_json("bench_aging.json", {"table4": t4})
    return t4


if __name__ == "__main__":
    main()
