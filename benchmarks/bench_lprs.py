"""Paper Table 9: LPRS (target-latency chunking) vs static token budget
under high-concurrency (0.1 s) and regular (1.0 s) arrivals."""
from __future__ import annotations

import numpy as np

from benchmarks.bench_predictor import collect_profile
from benchmarks.common import (
    BASE, calibrate_round_ms, fmt_table, save_json, scaled,
)
from repro.core.lprs import LPRSConfig
from repro.core.predictor import LatencyPredictor, PredictorConfig, bucket_and_downsample
from repro.core.scheduler import SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.simulator import run_policy
from repro.engine.workload import uniform_arrivals

MAX_SEQS = 64
BUDGET = 1024


def pcts(xs, keys=(50, 80, 90, 99)):
    arr = np.asarray([x for x in xs if x is not None], np.float64)
    return {p: float(np.percentile(arr, p)) for p in keys}


def train_predictor(k: float, quick: bool) -> LatencyPredictor:
    X, y = collect_profile(k, 4000 if quick else 12_000, seed=7)
    keep, w = bucket_and_downsample(X[:, 12])
    pred = LatencyPredictor(
        PredictorConfig(epochs=50 if quick else 150, dropout=0.0)
    )
    pred.fit(X[keep], y[keep], sample_weights=w)
    return pred


def run_one(policy_label, interval, k, predictor, target_ms, n=1000, seed=3,
            want_rounds=False):
    # paper regime: prefill-heavy prompts (multi-round at budget 1024),
    # short generations; high-concurrency = busy but stable (~80% util)
    import math

    def sampler(rng):
        return int(np.clip(round(rng.lognormal(math.log(420.0), 0.8)),
                           16, 3968))

    reqs = uniform_arrivals(n, interval, prompt_sampler=sampler,
                            max_seq_len=4096, max_new_tokens=32, seed=seed)
    lprs = None
    if policy_label == "lprs":
        lprs = LPRSConfig(target_latency_ms=target_ms, search_delta=128,
                          lambda_under=1.0, lambda_over=3.0)
    res = run_policy(
        reqs,
        SchedulerConfig(policy="fcfs", token_budget=BUDGET, max_seqs=MAX_SEQS,
                        lprs=lprs),
        cost_model=CostModel(scaled(BASE, k)),
        predictor=predictor if lprs else None,
        collect_samples=want_rounds,
    )
    pf = pcts([r.prefill_e2e() * 1e3 for r in reqs])
    full = pcts([r.e2e_latency() * 1e3 for r in reqs])
    rounds = None
    if want_rounds and res.samples:
        feats, lats = res.samples
        rounds = lats[feats[:, 0] > 0]      # rounds that carry prefill work
    return pf, full, rounds


def main(quick: bool = False):
    # §4.4 regime: the engine's full-budget round costs ~105 ms (paper's T*)
    k = calibrate_round_ms(105.0, BUDGET)
    pred = train_predictor(k, quick)
    target_ms = 105.0

    n = 300 if quick else 1000
    out = {}
    for label, interval in (("high 0.1s", 0.1), ("regular 1.0s", 1.0)):
        rows = []
        ctl_rows = []
        for policy in ("lprs", "budget"):
            pf, full, rounds = run_one(policy, interval, k, pred, target_ms,
                                       n=n, want_rounds=True)
            out[f"{label}/{policy}"] = {"prefill": pf, "full": full}
            rows.append([
                policy.upper(),
                *(f"{pf[p]:.1f}" for p in (50, 80, 90, 99)),
                *(f"{full[p]:.1f}" for p in (50, 80, 90, 99)),
            ])
            if rounds is not None:
                over = float(np.mean(rounds > 1.2 * target_ms))
                dev = float(np.mean(np.abs(rounds - target_ms)))
                ctl_rows.append([
                    policy.upper(), f"{np.percentile(rounds, 50):.1f}",
                    f"{np.percentile(rounds, 99):.1f}", f"{dev:.1f}",
                    f"{over:.1%}",
                ])
        print(fmt_table(
            f"Table 9 — LPRS (T*={target_ms:.0f} ms) vs token budget "
            f"({BUDGET}) | {label} arrivals — latency ms",
            ["Policy", "pf P50", "pf P80", "pf P90", "pf P99",
             "req P50", "req P80", "req P90", "req P99"], rows,
        ))
        print(fmt_table(
            f"Round-time controllability (LPRS's direct objective) | {label}",
            ["Policy", "round P50", "round P99", "mean |dev from T*|",
             ">1.2 T*"], ctl_rows,
        ))
    hi_l = out["high 0.1s/lprs"]["full"][99]
    hi_b = out["high 0.1s/budget"]["full"][99]
    print(f"  high concurrency P99 request: LPRS {hi_l:.1f} vs budget "
          f"{hi_b:.1f} ms ({100 * (hi_l - hi_b) / hi_b:+.1f}%) "
          f"— paper: 952.56 vs 986.93 (-3.5%).")
    print("  NOTE (EXPERIMENTS.md §Repro): in a linear-deterministic cost "
          "simulator max-fill is throughput-optimal, so LPRS's E2E tail win "
          "does not transfer; its round-time control target does (above).")
    save_json("bench_lprs.json", {
        k2: {kk: vv for kk, vv in v.items()} for k2, v in out.items()
    })
    return out


if __name__ == "__main__":
    main()
