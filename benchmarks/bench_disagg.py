"""Disaggregated prefill/decode serving vs colocated, under a prefill burst.

The scenario disaggregation exists for: a population of ongoing decodes (the
ITL-sensitive traffic) gets hit by a burst of long-prompt requests.  On ONE
colocated engine the burst's chunked prefills enter every round the decodes
run in, and its KV allocations evict mid-decode requests under pool
pressure — both show up as inter-token-latency spikes on the decode
population.  Split into a prefill pool and a decode pool (same total KV
capacity, KV handed off at prefill completion), the decode replica's rounds
and block pool never see a prefill, so the decode population's tail ITL is
shielded from the burst.

Gates:
  * ALWAYS (deterministic, any machine): greedy outputs bit-identical
    colocated vs disaggregated; the decode pool scheduled ZERO prefill
    tokens (every handoff resumed decode-only, nothing was re-prefilled);
    every request crossed the link exactly once.
  * FULL RUNS ONLY (wall-clock): P99 inter-token latency of the decode
    population strictly lower disaggregated than colocated.  Quick/CI runs
    print the same numbers without asserting them — single-process
    round-interleaving makes tiny-run tails noisy.

Writes a ``disagg_quick`` / ``disagg_full`` section into
``BENCH_throughput.json`` (schema shared with bench_serve_throughput; other
sections are preserved).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.bench_serve_throughput import ROOT_JSON, _load_sections
from benchmarks.common import fmt_table
from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.disagg import DisaggConfig, build_disagg, serve_disagg
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like


def _workload(quick: bool, model_cfg):
    """Decode population (small prompts, long decodes, t=0) + prefill burst
    (long prompts, short decodes) arriving while the population decodes."""
    if quick:
        n_dec, n_burst, gen_dec, burst_at = 4, 10, 24, 0.5
        ctx_dec, ctx_burst = 64, 192
    else:
        n_dec, n_burst, gen_dec, burst_at = 8, 20, 48, 1.0
        ctx_dec, ctx_burst = 96, 224
    decoders = sharegpt_like(WorkloadSpec(
        n_requests=n_dec, inter_arrival_s=0.0, max_context=ctx_dec,
        max_new_tokens=gen_dec, seed=7,
    ))
    burst = sharegpt_like(WorkloadSpec(
        n_requests=n_burst, inter_arrival_s=0.01, max_context=ctx_burst,
        max_new_tokens=8, seed=8,
    ))
    for r in burst:
        r.arrival_time += burst_at
    reqs = decoders + burst
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=7)
    return reqs, n_dec


def _itl_gaps(reqs, n_dec):
    """Inter-token latencies (s) of the decode population: consecutive gaps
    of each request's host-visibility timestamps."""
    gaps = []
    for r in reqs[:n_dec]:
        ts = r.token_times
        gaps.extend(b - a for a, b in zip(ts, ts[1:]))
    return np.asarray(gaps if gaps else [0.0])


def _engine_cfg():
    return EngineConfig(n_slots=8, max_context=256, paged_kv=True,
                        pipelined=True, preemption_mode="swap", seed=7,
                        chunk_buckets=(1, 16, 32, 64))


def _sched_cfg():
    return SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=8)


def run_colocated(quick: bool, n_blocks: int):
    model_cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(model_cfg, _engine_cfg())
    eng.warmup()
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(_sched_cfg())
    reqs, n_dec = _workload(quick, model_cfg)
    t0 = time.perf_counter()
    res = serve(reqs, sched, eng, kv_pool=pool)
    wall = time.perf_counter() - t0
    pool.check_invariants()
    gaps = _itl_gaps(reqs, n_dec)
    return {
        "name": "colocated",
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall,
        "itl_p99_ms": float(np.percentile(gaps, 99) * 1e3),
        "itl_p50_ms": float(np.percentile(gaps, 50) * 1e3),
        "preemptions": sched.stats.preemptions,
        "handoffs": 0,
        "prefill_tokens": sched.stats.scheduled_prefill_tokens,
        "decode_prefill_tokens": None,     # no decode pool to keep clean
        "bytes_moved": 0,
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }


def run_disagg(quick: bool, n_blocks_per_replica: int):
    model_cfg = tiny_config("qwen1.5-0.5b")
    router = build_disagg(
        model_cfg,
        cfg=DisaggConfig(n_prefill=1, n_decode=1),
        engine_cfg=_engine_cfg(),
        sched_cfg=_sched_cfg(),
        n_blocks=n_blocks_per_replica, block_size=16,
        warmup=True,
    )
    reqs, n_dec = _workload(quick, model_cfg)
    t0 = time.perf_counter()
    res = serve_disagg(reqs, router)
    wall = time.perf_counter() - t0
    router.check_invariants()
    gaps = _itl_gaps(reqs, n_dec)
    return {
        "name": "disagg-1P+1D",
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall,
        "itl_p99_ms": float(np.percentile(gaps, 99) * 1e3),
        "itl_p50_ms": float(np.percentile(gaps, 50) * 1e3),
        "preemptions": sum(rs.sched.stats.preemptions for rs in router.replicas),
        "handoffs": res.handoffs,
        "prefill_tokens": sum(
            rs.sched.stats.scheduled_prefill_tokens for rs in router.replicas),
        "decode_prefill_tokens": sum(
            rs.sched.stats.scheduled_prefill_tokens for rs in router.decode),
        "bytes_moved": res.bytes_moved,
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings: deterministic gates only")
    args = ap.parse_args(argv)

    # the colocated engine gets the SAME total KV capacity the two disagg
    # replicas split between them
    n_per_replica = 48 if args.quick else 64
    colo = run_colocated(args.quick, n_blocks=2 * n_per_replica)
    disagg = run_disagg(args.quick, n_blocks_per_replica=n_per_replica)
    results = [colo, disagg]

    rows = [
        [r["name"], r["finished"], r["rounds"], f"{r['wall_s']:.2f}",
         f"{r['itl_p50_ms']:.1f}", f"{r['itl_p99_ms']:.1f}",
         r["preemptions"], r["handoffs"],
         "-" if r["decode_prefill_tokens"] is None
         else r["decode_prefill_tokens"]]
        for r in results
    ]
    print(fmt_table(
        "Disaggregated vs colocated under a prefill-heavy burst",
        ["config", "done", "rounds", "wall s", "itl p50 ms", "itl p99 ms",
         "preempts", "handoffs", "dec-pool prefill toks"],
        rows,
    ))

    n_total = len(colo["outputs"])
    # -- deterministic gates (every run) ------------------------------------
    assert colo["finished"] == disagg["finished"] == n_total
    assert colo["outputs"] == disagg["outputs"], (
        "disaggregated greedy outputs diverged from colocated")
    assert disagg["decode_prefill_tokens"] == 0, (
        f"decode pool re-prefilled {disagg['decode_prefill_tokens']} tokens")
    assert disagg["handoffs"] == n_total
    print(f"  outputs identical={True}  decode-pool re-prefilled tokens=0  "
          f"handoffs={disagg['handoffs']}/{n_total}")

    # -- wall-clock gate (full runs only) -----------------------------------
    shield = 1.0 - disagg["itl_p99_ms"] / max(colo["itl_p99_ms"], 1e-9)
    print(f"  decode-population ITL p99: colocated {colo['itl_p99_ms']:.1f} ms"
          f" -> disagg {disagg['itl_p99_ms']:.1f} ms ({shield:+.1%})")
    if not args.quick:
        assert disagg["itl_p99_ms"] < colo["itl_p99_ms"], (
            "disaggregation did not shield the decode population's tail ITL")

    mode_key = "disagg_quick" if args.quick else "disagg_full"
    stripped = [{k: v for k, v in r.items() if k != "outputs"}
                for r in results]
    data = _load_sections()            # preserve the other benches' sections
    data[mode_key] = {
        "workload": {"quick": args.quick, "seed": 7},
        "results": stripped,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(data, f, indent=1)
    print(f"  wrote BENCH_throughput.json [{mode_key}]")
    return results


if __name__ == "__main__":
    main()
