"""Paper Table 5: Aging under multi-GPU (2-replica) execution — the
centralized-scheduler design scaled out, plus the fault-tolerance story the
paper's future-work asks for (replica failure mid-run; elastic add)."""
from __future__ import annotations


from benchmarks.common import (
    BASE, calibrate_multiplier, fmt_table, paper_workload, save_json, scaled,
)
from repro.core.scheduler import SchedulerConfig
from repro.engine.router import Router, RouterConfig


def run_table5(n: int = 200, seed: int = 0):
    k = calibrate_multiplier(n=n, seed=seed)
    cost = scaled(BASE, k / 2.0)     # per-replica: 5090-class, ~2x faster
    rows = []
    out = {}
    for chunk, max_seqs in ((256, 10), (256, 32), (512, 32)):
        for policy in ("fcfs", "aging"):
            r = Router(RouterConfig(
                scheduler=SchedulerConfig(policy=policy, alpha=1.0, beta=-0.1,
                                          token_budget=chunk, max_seqs=max_seqs),
                cost=cost,
            ), n_replicas=2)
            rep = r.run(paper_workload(n, seed))
            out[f"{chunk}/{max_seqs}/{policy}"] = rep.row()
            rows.append([
                chunk, max_seqs, policy.upper(),
                f"{rep.e2e['mean']:.2f}", f"{rep.e2e['p95']:.2f}",
                f"{rep.ttft['mean']:.2f}", f"{rep.ttft['p95']:.2f}",
            ])
    print(fmt_table(
        "Table 5 — two-replica execution (centralized per-replica scheduling)",
        ["Chunk", "MaxSeqs", "Policy", "E2E mean", "E2E p95",
         "TTFT mean", "TTFT p95"], rows,
    ))
    print("  paper: small/constrained configs can favor FCFS; chunk 512 + "
          "seqs 32 favors Aging")
    return out


def run_fault_tolerance(seed: int = 0):
    """Beyond Table 5: kill a replica mid-run + elastic replacement."""
    k = calibrate_multiplier(seed=seed)
    cost = scaled(BASE, k / 2.0)
    rows = []
    for label, faults in (
        ("healthy", {}),
        ("kill@20s", {20.0: lambda rt: rt.kill_replica(0)}),
        ("kill@20s+add@30s", {20.0: lambda rt: rt.kill_replica(0),
                              30.0: lambda rt: rt.add_replica()}),
    ):
        r = Router(RouterConfig(
            scheduler=SchedulerConfig(policy="aging", alpha=1.0, beta=-0.1,
                                      token_budget=512, max_seqs=32),
            cost=cost,
        ), n_replicas=2)
        rep = r.run(paper_workload(200, seed), fault_at=dict(faults))
        fin = sum(1 for q in r.journal.values() if q.state.value == "finished")
        rows.append([label, f"{fin}/200", f"{rep.e2e['mean']:.2f}s",
                     f"{rep.e2e['p99']:.2f}s",
                     sum(1 for e in r.events if "replayed" in e)])
    print(fmt_table(
        "Fault tolerance — replica failure + elastic replacement (Aging)",
        ["Scenario", "Completed", "Mean E2E", "P99 E2E", "Replays"], rows,
    ))
    return rows


def main(quick: bool = False):
    n = 100 if quick else 200
    t5 = run_table5(n)
    run_fault_tolerance()
    save_json("bench_multireplica.json", {"table5": t5})
    return t5


if __name__ == "__main__":
    main()
