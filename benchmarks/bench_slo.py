"""SLO serving tier: protected-tenant latency targets under a saturating
bursty neighbor.

Scenario: a latency-critical "hot" tenant (2 rps, tight TTFT SLO) shares the
engine with a high-weight "bulk" tenant whose bursty arrivals (30 rps in
on-windows) saturate the round budget.  The FCFS baseline queues hot behind
every burst — its P99 TTFT lands well past the 0.3 s target whenever a burst
is draining.  With ``SchedulerConfig.slo`` set, the closed loop
(deadline-aware LPRS targets, queue urgency, SLO-weighted victim selection,
APC protection, load shedding of infeasible deadlines) pulls hot back inside
the target: urgency promotion reorders hot past the backlog a round early
(``slack_safety=1.5``), and the bulk work that could never meet its own —
loose — deadline is shed instead of burning budget.

Cost model: the same overhead-dominated round as ``bench_fairness`` but with
``noise_std=0`` — every run is bit-deterministic, so the quick gates can be
EXACT (trace identity, zero violations, shed-count reconciliation) and run
in CI.

Gates:
  quick (deterministic, CI `slo` job):
    q1. all-flags-off SLOConfig is trace-identical to slo=None
    q2. protected tenant: ZERO SLO violations with the tier on
    q3. shed accounting exact: report.shed == scheduler.stats.sheds
        == requests with shed_reason, split admission/deadline
  full (BENCH_throughput.json "slo_full" section + regression check):
    f1. protected P99 TTFT <= ttft_slo_s with the tier on
    f2. the baseline (slo=None) VIOLATES the same target (the tier is
        doing the work, not the workload being easy)
    f3. vs the committed section: protected P99 TTFT and overall
        attainment within tolerance
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from benchmarks.common import fmt_table, save_json
from repro.core.scheduler import SchedulerConfig
from repro.core.slo import SLOConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.simulator import run_policy
from repro.engine.workload import TenantTraffic, multi_tenant
from repro.tenancy import FairnessConfig, TenantSpec

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_throughput.json")

COST = CostModelConfig(
    c0_ms=60.0, c_prefill_ms=0.05, c_attn_ms=1e-6,
    c_decode_ms=0.15, c_ctx_ms=1e-5, c_seq_ms=0.08, noise_std=0.0,
)

HOT_TTFT_SLO_S = 0.3
BULK_TTFT_SLO_S = 2.0
REGRESSION_TOL = 0.25

SPECS = (
    TenantSpec("hot", ttft_slo_s=HOT_TTFT_SLO_S, e2e_slo_s=8.0),
    TenantSpec("bulk", weight=4.0, ttft_slo_s=BULK_TTFT_SLO_S),
)

SLO_OFF = SLOConfig(deadline_lprs=False, queue_urgency=False,
                    victim_weighting=False, apc_protect=False, shed=False)

# urgency-promote one round early: the tracker treats deadlines as 1.5x as
# expensive to hit, absorbing the ~86 ms round granularity that otherwise
# turns "just in time" into "one round late"
SLO_ON = SLOConfig(slack_safety=1.5)


def tenant_mix():
    return [
        TenantTraffic("hot", "light", rps=2.0, prompt_mean=96.0,
                      prompt_sigma=0.35, max_new_tokens=16),
        TenantTraffic("bulk", "bursty", rps=30.0, prompt_mean=256.0,
                      max_new_tokens=24, burst_period_s=5.0, burst_duty=0.2),
    ]


def scheduler_cfg(slo: Optional[SLOConfig]) -> SchedulerConfig:
    # FCFS baseline: a hot request arriving mid-burst queues behind the whole
    # backlog (the aging policy escalates it within a few rounds on its own,
    # which hides exactly the failure mode the SLO tier exists to fix)
    return SchedulerConfig(
        policy="fcfs", token_budget=512, max_seqs=16,
        fairness=FairnessConfig(tenants=SPECS, admission=False),
        slo=slo,
    )


def trace(reqs):
    return [(r.tenant, tuple(r.chunks), r.prefill_done, r.generated,
             r.first_token_time, r.finish_time) for r in reqs]


def run_one(slo, *, seed, duration_s):
    reqs = multi_tenant(tenant_mix(), duration_s=duration_s, seed=seed)
    res = run_policy(reqs, scheduler_cfg(slo), cost_model=CostModel(COST))
    hot = res.slo.per_tenant["hot"]
    bulk = res.slo.per_tenant["bulk"]
    hot_ttfts = sorted(
        r.first_token_time - r.arrival_time
        for r in reqs if r.tenant == "hot" and r.first_token_time is not None
    )
    p99 = hot_ttfts[max(int(0.99 * len(hot_ttfts)) - 1, 0)] if hot_ttfts else float("nan")
    return {
        "reqs": reqs,
        "res": res,
        "hot": hot,
        "bulk": bulk,
        "hot_p99_ttft_s": p99,
        "row": {
            "hot_p99_ttft_s": p99,
            "hot_attained": hot.attained, "hot_violated": hot.violated,
            "hot_shed": hot.shed,
            "bulk_attained": bulk.attained, "bulk_violated": bulk.violated,
            "bulk_shed": bulk.shed,
            "attainment": res.slo.attainment,
            "shed_total": res.slo.shed,
            "rounds": res.rounds,
        },
    }


def _load_sections() -> dict:
    try:
        with open(ROOT_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    if "results" in data:              # legacy flat schema
        data = {"full": data}
    return data


def main(seed: int = 3, duration_s: float = 30.0, quick: bool = False,
         check_regression: bool = False):
    if quick:
        duration_s = 6.0
    base = run_one(None, seed=seed, duration_s=duration_s)
    off = run_one(SLO_OFF, seed=seed, duration_s=duration_s)
    on = run_one(SLO_ON, seed=seed, duration_s=duration_s)

    rows = []
    for label, r in (("slo=None", base), ("slo off-flags", off), ("slo ON", on)):
        rows.append([
            label, f"{r['hot_p99_ttft_s']:.3f}s",
            f"{r['hot'].violated}", f"{r['hot'].shed}",
            f"{r['bulk'].violated}", f"{r['bulk'].shed}",
            f"{r['res'].slo.attainment:.3f}", f"{r['res'].rounds}",
        ])
    print(fmt_table(
        f"SLO tier — hot (2 rps, TTFT SLO {HOT_TTFT_SLO_S}s) vs bulk "
        f"(weight 4, 30 rps bursty, TTFT SLO {BULK_TTFT_SLO_S}s), "
        f"{duration_s:.0f}s seed {seed}",
        ["Config", "hot P99 TTFT", "hot viol", "hot shed",
         "bulk viol", "bulk shed", "attainment", "rounds"],
        rows,
    ))

    # -- quick gates (exact, deterministic) ----------------------------------
    gates = {}
    gates["q1_off_trace_identical"] = trace(base["reqs"]) == trace(off["reqs"])
    gates["q2_hot_zero_violations"] = on["hot"].violated == 0
    sched_stats = on["res"].scheduler_stats
    shed_reqs = [r for r in on["reqs"] if r.shed_reason is not None]
    gates["q3_shed_accounting_exact"] = (
        on["res"].slo.shed == sched_stats.sheds == len(shed_reqs)
    )
    by_reason = {
        "admission": sum(1 for r in shed_reqs if r.shed_reason == "admission"),
        "deadline": sum(1 for r in shed_reqs if r.shed_reason == "deadline"),
    }
    print(f"\n  sheds by reason: {by_reason}  "
          f"(scheduler counter {sched_stats.sheds})")
    for g, ok in gates.items():
        print(f"  gate {g} [{'PASS' if ok else 'FAIL'}]")

    # -- full gates ----------------------------------------------------------
    if not quick:
        gates["f1_hot_p99_within_slo"] = (
            on["hot_p99_ttft_s"] <= HOT_TTFT_SLO_S
        )
        gates["f2_baseline_violates"] = (
            base["hot_p99_ttft_s"] > HOT_TTFT_SLO_S
        )
        print(f"  gate f1 [{'PASS' if gates['f1_hot_p99_within_slo'] else 'FAIL'}] "
              f"hot P99 TTFT on: {on['hot_p99_ttft_s']:.3f}s <= {HOT_TTFT_SLO_S}s")
        print(f"  gate f2 [{'PASS' if gates['f2_baseline_violates'] else 'FAIL'}] "
              f"hot P99 TTFT base: {base['hot_p99_ttft_s']:.3f}s > {HOT_TTFT_SLO_S}s")

    # -- BENCH_throughput.json section + regression --------------------------
    mode_key = "slo_quick" if quick else "slo_full"
    payload = {
        "workload": {"seed": seed, "duration_s": duration_s, "quick": quick},
        "slo": {"hot_ttft_s": HOT_TTFT_SLO_S, "bulk_ttft_s": BULK_TTFT_SLO_S},
        "base": base["row"], "off": off["row"], "on": on["row"],
        "gates": {k: bool(v) for k, v in gates.items()},
    }
    baseline = _load_sections().get(mode_key) if check_regression else None
    data = _load_sections()            # preserve the other sections
    data[mode_key] = payload
    with open(ROOT_JSON, "w") as f:
        json.dump(data, f, indent=1)
    print(f"\n  wrote {os.path.normpath(ROOT_JSON)} [{mode_key}]")

    failures = [g for g, ok in gates.items() if not ok]
    if check_regression:
        if baseline is None:
            print(f"  no committed {mode_key!r} baseline to compare against")
        else:
            old = baseline["on"]
            checks = [
                ("hot_p99_ttft_s", on["hot_p99_ttft_s"],
                 old["hot_p99_ttft_s"], 1.0 + REGRESSION_TOL),
            ]
            for name, new_v, old_v, lim in checks:
                if old_v > 0 and new_v > old_v * lim:
                    failures.append(f"regression:{name} {new_v:.3f} vs "
                                    f"{old_v:.3f} (>{lim:.2f}x)")
            old_att = old.get("attainment", 0.0)
            new_att = on["res"].slo.attainment
            if new_att < old_att - REGRESSION_TOL:
                failures.append(
                    f"regression:attainment {new_att:.3f} vs {old_att:.3f}")

    save_json("bench_slo.json", payload)
    if failures:
        print(f"\n  FAILED gates: {failures}")
        raise SystemExit(1)
    print("\n  all gates PASS")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--quick", action="store_true",
                    help="6 s horizon + exact deterministic gates only")
    ap.add_argument("--check-regression", action="store_true",
                    help="compare against the committed BENCH_throughput.json "
                         "section")
    args = ap.parse_args()
    main(seed=args.seed, duration_s=args.duration, quick=args.quick,
         check_regression=args.check_regression)
