"""Paper Figs 5/6: Aging sensitivity to chunk size and waiting-time weight.

Fig 6's 'weight base' maps to the alpha/|beta| ratio: a larger waiting-time
weight (alpha up relative to |beta|) pulls the policy toward pure time-based
ordering (FCFS-like) and erodes the short-request benefit — the paper's
100-vs-500 observation."""
from __future__ import annotations

from benchmarks.common import (
    BASE, calibrate_multiplier, fmt_table, paper_workload, save_json, scaled,
)
from repro.core.scheduler import SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.simulator import run_policy

MAX_SEQS = 48


def run_chunk_sensitivity(n: int = 200, seed: int = 0):
    k = calibrate_multiplier(n=n, seed=seed)
    rows = []
    out = {}
    for chunk in (128, 256, 512, 1024):
        res = run_policy(
            paper_workload(n, seed),
            SchedulerConfig(policy="aging", alpha=1.0, beta=-0.1,
                            token_budget=chunk, max_seqs=MAX_SEQS),
            cost_model=CostModel(scaled(BASE, k)),
        )
        r = res.report
        out[chunk] = r.row()
        rows.append([chunk, f"{r.e2e['mean']:.2f}s", f"{r.ttft['mean']:.2f}s",
                     f"{r.ttft['p95']:.2f}s"])
    print(fmt_table(
        "Fig 5 — Aging sensitivity to chunk size",
        ["Chunk", "Mean E2E", "Mean TTFT", "P95 TTFT"], rows,
    ))
    return out


def run_weight_sensitivity(n: int = 200, seed: int = 0):
    """Sweep alpha/|beta|: small ratio = SJF-like, large = FCFS-like."""
    k = calibrate_multiplier(n=n, seed=seed)
    rows = []
    out = {}
    # 'weight base' w: alpha = w scaled so only the RATIO matters
    for w, (alpha, beta) in {
        "10 (work-dominant)": (1.0, -10.0),
        "100 (paper best)": (1.0, -0.1),
        "500 (wait-dominant)": (5.0, -0.1),
        "5000 (FCFS-like)": (50.0, -0.1),
    }.items():
        res = run_policy(
            paper_workload(n, seed),
            SchedulerConfig(policy="aging", alpha=alpha, beta=beta,
                            token_budget=512, max_seqs=MAX_SEQS),
            cost_model=CostModel(scaled(BASE, k)),
        )
        r = res.report
        out[w] = r.row()
        rows.append([w, f"{r.e2e['mean']:.2f}s", f"{r.ttft['mean']:.2f}s",
                     f"{r.ttft['p95']:.2f}s"])
    print(fmt_table(
        "Fig 6 — Aging sensitivity to the waiting-time weight (alpha/|beta|)",
        ["Weight base", "Mean E2E", "Mean TTFT", "P95 TTFT"], rows,
    ))
    print("  paper: larger waiting weight does not improve latency here — it"
          " weakens the remaining-work term (closer to arrival ordering)")
    return out


def main(quick: bool = False):
    n = 100 if quick else 200
    a = run_chunk_sensitivity(n)
    b = run_weight_sensitivity(n)
    save_json("bench_sensitivity.json", {"chunk": {str(k): v for k, v in a.items()},
                                         "weight": b})
    return a, b


if __name__ == "__main__":
    main()
