"""Roofline report (deliverable g): reads the dry-run JSON records and
renders the per-(arch x shape x mesh) table with the three terms, dominant
bottleneck, useful-FLOPs ratio, and the "what would move the dominant term"
note.  Re-run the dry-run to refresh:

  PYTHONPATH=src python -m repro.launch.dryrun --quiet \
      --json benchmarks/results/dryrun_singlepod.json
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import RESULTS_DIR, fmt_table

NOTES = {
    ("memory", "train"): "flash-block fusion + wider microbatch amortizes "
                         "weight streaming; remat policy tuning",
    ("memory", "prefill"): "larger flash KV blocks; fuse norm/rope chains "
                           "(Pallas kernel on TPU)",
    ("memory", "decode"): "KV cache dtype (int8/fp8) or multi-token decode "
                          "amortizes weight+cache streaming",
    ("collective", "train"): "sequence-parallel reduce-scatter/all-gather "
                             "decomposition of the TP all-reduces; overlap "
                             "with FFN compute",
    ("collective", "prefill"): "same TP-AR decomposition; 2D-sharded weight "
                               "gather overlap across layers",
    ("collective", "decode"): "shrink per-layer gathers by head-local "
                              "layouts; batch multiple decode steps",
    ("compute", "train"): "already MXU-bound: raise useful-FLOPs ratio "
                          "(reduce remat recompute, MoE dispatch overhead)",
    ("compute", "prefill"): "reduce masked-tile waste in causal flash loop",
    ("compute", "decode"): "compute-bound decode indicates dispatch "
                           "overhead, not math - fuse gather/unembed",
}


def shape_kind(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(shape, "decode")


def load(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def render(records: List[Dict], title: str) -> None:
    rows = []
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
        if not r["ok"]:
            rows.append([r["arch"], r["shape"], "FAIL", "", "", "", "", "", ""])
            continue
        rows.append([
            r["arch"], r["shape"],
            f"{r['compute_term_s'] * 1e3:,.1f}",
            f"{r['memory_term_s'] * 1e3:,.1f}",
            f"{r['collective_term_s'] * 1e3:,.1f}",
            r["dominant"],
            f"{r['useful_flops_ratio']:.3f}",
            f"{r['roofline_fraction']:.4f}",
            f"{r['peak_memory_mb'] / 1024:,.1f}",
        ])
    print(fmt_table(
        title,
        ["arch", "shape", "comp ms", "mem ms", "coll ms", "dominant",
         "useful", "roofline", "GB/dev"],
        rows,
    ))


def main(quick: bool = False):
    for name, label in (
        ("dryrun_singlepod.json", "Roofline — single-pod 16x16 (256 chips)"),
        ("dryrun_multipod.json", "Dry-run — multi-pod 2x16x16 (512 chips)"),
    ):
        path = os.path.join(RESULTS_DIR, name)
        if not os.path.exists(path):
            print(f"  [roofline] missing {path}; run the dry-run first")
            continue
        recs = load(path)
        render(recs, label)
        n_ok = sum(1 for r in recs if r["ok"])
        print(f"  {n_ok}/{len(recs)} cells OK")
        if "single" in name:
            for kind in ("train", "prefill", "decode"):
                sub = [r for r in recs if r["ok"] and shape_kind(r["shape"]) == kind]
                if not sub:
                    continue
                doms = {}
                for r in sub:
                    doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
                print(f"  {kind}: dominant terms {doms}")
            print("\n  Iteration levers by (dominant term, phase):")
            for (dom, kind), note in NOTES.items():
                print(f"   - {dom}/{kind}: {note}")


if __name__ == "__main__":
    main()
