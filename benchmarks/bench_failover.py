"""Replica failure mid-burst: kill one of two decode replicas, lose nothing.

The fault-tolerance scenario the robustness layer exists for: a 1-prefill +
2-decode fleet is serving a two-wave burst when one decode replica dies
mid-handoff (a seeded ``replica_step_crash`` that repeats until the health
machine declares the replica DEAD).  The router must evacuate the dead
replica — host-staged handoffs re-place decode-resumable on the survivor
with ZERO re-prefilled tokens, in-flight work unwinds and retries — and the
fleet must finish the full workload.

Gates:
  * ALWAYS (deterministic, any machine): every request terminates exactly
    once with nothing shed; exactly one replica died and at least one
    request recovered decode-resumable; the surviving decode pool scheduled
    ZERO prefill tokens (no recovery re-prefilled); greedy outputs are
    bit-identical to a fault-free run of the same fleet; block refcounts,
    swap staging and handoff byte ledgers all close.
  * FULL RUNS ONLY (wall-clock): the fault run's decode-population P99
    inter-token latency stays within 10x of the fault-free run — losing a
    replica degrades the tail, it must not wedge it.  Quick/CI runs print
    the same numbers without asserting them.

Writes a ``failover_quick`` / ``failover_full`` section into
``BENCH_throughput.json`` (schema shared with bench_serve_throughput; other
sections are preserved).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.bench_serve_throughput import ROOT_JSON, _load_sections
from benchmarks.common import fmt_table
from repro.configs import tiny_config
from repro.core.scheduler import SchedulerConfig
from repro.disagg import DisaggConfig, build_disagg, serve_disagg
from repro.engine.engine import EngineConfig
from repro.engine.workload import shared_prefix
from repro.robustness import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    HealthConfig,
    RobustnessConfig,
)


def _workload(quick: bool):
    """Two waves of shared-prefix requests: the second wave arrives while
    the first wave's handoffs are in flight, so the kill lands mid-burst."""
    n = 12 if quick else 24
    new_tokens = 10 if quick else 16
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=5)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
    return reqs


def _build_fleet(robustness=None, *, n_blocks=64):
    cfg = tiny_config("qwen1.5-0.5b")
    return build_disagg(
        cfg,
        cfg=DisaggConfig(n_prefill=1, n_decode=2, robustness=robustness),
        engine_cfg=EngineConfig(n_slots=6, max_context=128, paged_kv=True,
                                pipelined=True, preemption_mode="swap",
                                nan_guard=robustness is not None, seed=3),
        sched_cfg=SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6),
        n_blocks=n_blocks, block_size=16,
        warmup=True,
    )


def _itl_p99_ms(reqs):
    gaps = []
    for r in reqs:
        ts = r.token_times
        gaps.extend(b - a for a, b in zip(ts, ts[1:]))
    return float(np.percentile(np.asarray(gaps if gaps else [0.0]), 99) * 1e3)


def _run(quick: bool, robustness):
    reqs = _workload(quick)
    router = _build_fleet(robustness)
    t0 = time.perf_counter()
    res = serve_disagg(reqs, router)
    wall = time.perf_counter() - t0
    router.check_invariants()
    for rs in router.replicas:
        assert not rs.engine.slot_of, (rs.name, rs.engine.slot_of)
    rob = res.robustness
    row = {
        "name": "fault-free" if robustness is None else "kill decode0",
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall,
        "itl_p99_ms": _itl_p99_ms(reqs),
        "handoffs": res.handoffs,
        "decode_prefill_tokens": sum(
            rs.sched.stats.scheduled_prefill_tokens for rs in router.decode),
        "replicas_died": 0 if rob is None else rob.replicas_died,
        "recovered_resumable": 0 if rob is None else rob.recovered_resumable,
        "requeued_reprefill": 0 if rob is None else rob.requeued_reprefill,
        "shed": 0 if rob is None else rob.shed_replica_failure,
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }
    return row, reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings: deterministic gates only")
    args = ap.parse_args(argv)

    base, _ = _run(args.quick, None)

    # decode0 crashes every time it reaches its 3rd round; with dead_after=1
    # the first crash marks it DEAD and the router evacuates it while first-
    # wave handoffs are host-staged — the deterministic zero-re-prefill case.
    plan = FaultPlan(specs=(FaultSpec(site="replica_step_crash", nth=3,
                                      replica="decode0", repeat=True),))
    rcfg = RobustnessConfig(health=HealthConfig(dead_after=1),
                            injector=FaultInjector(plan))
    fault, _ = _run(args.quick, rcfg)
    results = [base, fault]

    rows = [
        [r["name"], r["finished"], r["rounds"], f"{r['wall_s']:.2f}",
         f"{r['itl_p99_ms']:.1f}", r["handoffs"], r["decode_prefill_tokens"],
         r["replicas_died"], r["recovered_resumable"],
         r["requeued_reprefill"], r["shed"]]
        for r in results
    ]
    print(fmt_table(
        "Killing 1 of 2 decode replicas mid-burst",
        ["run", "done", "rounds", "wall s", "itl p99 ms", "handoffs",
         "dec-pool prefill toks", "died", "resumable", "re-prefill", "shed"],
        rows,
    ))

    n_total = len(base["outputs"])
    # -- deterministic gates (every run) ------------------------------------
    assert base["finished"] == fault["finished"] == n_total, (
        "requests were lost under replica failure")
    assert fault["shed"] == 0, f"{fault['shed']} requests shed"
    assert fault["replicas_died"] == 1
    assert fault["recovered_resumable"] > 0, (
        "no handoff-staged recovery exercised the zero-re-prefill path")
    assert fault["decode_prefill_tokens"] == 0, (
        f"surviving decode pool re-prefilled "
        f"{fault['decode_prefill_tokens']} tokens")
    assert base["outputs"] == fault["outputs"], (
        "failover changed greedy outputs vs the fault-free run")
    print(f"  outputs identical={True}  lost=0  "
          f"resumable={fault['recovered_resumable']}  "
          f"decode-pool re-prefilled tokens=0")

    # -- wall-clock gate (full runs only) -----------------------------------
    ratio = fault["itl_p99_ms"] / max(base["itl_p99_ms"], 1e-9)
    print(f"  decode ITL p99: fault-free {base['itl_p99_ms']:.1f} ms -> "
          f"under failure {fault['itl_p99_ms']:.1f} ms ({ratio:.2f}x)")
    if not args.quick:
        assert ratio < 10.0, (
            f"losing a replica blew up tail ITL {ratio:.1f}x")

    mode_key = "failover_quick" if args.quick else "failover_full"
    stripped = [{k: v for k, v in r.items() if k != "outputs"}
                for r in results]
    data = _load_sections()            # preserve the other benches' sections
    data[mode_key] = {
        "workload": {"quick": args.quick, "seed": 5},
        "results": stripped,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(data, f, indent=1)
    print(f"  wrote BENCH_throughput.json [{mode_key}]")
    return results


if __name__ == "__main__":
    main()
