"""Shared benchmark utilities: cost-model calibration to the paper's load
regime, table formatting, result persistence.

Calibration: the paper runs Qwen3-8B on one RTX 4090 with 200 ShareGPT
requests at 0.1 s inter-arrival (max ctx/gen 512) and measures FCFS mean E2E
~118.7 s at chunk=256 — a heavily overloaded regime (queueing dominates).
We reproduce the REGIME, not the GPU: a single global speed multiplier on the
analytic cost model is bisected so FCFS/chunk-256 mean E2E lands at the
paper's operating point.  All policies then run under the identical
calibrated engine, so RELATIVE improvements (the paper's claims) are
apples-to-apples.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List


from repro.core.scheduler import SchedulerConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.simulator import run_policy
from repro.engine.workload import WorkloadSpec, sharegpt_like

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# base per-token costs (shape of the latency function); the calibrated
# multiplier scales all dynamic terms together
BASE = CostModelConfig(
    c0_ms=2.0, c_prefill_ms=0.045, c_attn_ms=4e-6, c_decode_ms=0.10,
    c_ctx_ms=3.5e-5, c_seq_ms=0.08, noise_std=0.02,
)

PAPER_TARGET_E2E_S = 118.72      # Table 4, FCFS chunk=256 mean E2E
_CAL_CACHE: Dict[str, float] = {}


def scaled(cfg: CostModelConfig, k: float) -> CostModelConfig:
    # fixed per-round overhead grows sub-linearly (kernel launch / host code
    # does not slow down with model size as much as the math does)
    return dataclasses.replace(
        cfg,
        c0_ms=cfg.c0_ms * k ** 0.5,
        c_prefill_ms=cfg.c_prefill_ms * k,
        c_attn_ms=cfg.c_attn_ms * k,
        c_decode_ms=cfg.c_decode_ms * k,
        c_ctx_ms=cfg.c_ctx_ms * k,
        c_seq_ms=cfg.c_seq_ms * k,
        c_mix_ms=cfg.c_mix_ms * k,
    )


def paper_workload(n: int = 200, seed: int = 0) -> List:
    return sharegpt_like(WorkloadSpec(
        n_requests=n, inter_arrival_s=0.1, max_context=512,
        max_new_tokens=512, seed=seed,
    ))


def calibrate_multiplier(
    *, target_s: float = PAPER_TARGET_E2E_S, chunk: int = 256,
    max_seqs: int = 48, n: int = 200, seed: int = 0, iters: int = 12,
) -> float:
    """Bisect the speed multiplier so FCFS mean E2E == target."""
    key = f"{target_s}:{chunk}:{max_seqs}:{n}:{seed}"
    if key in _CAL_CACHE:
        return _CAL_CACHE[key]
    lo, hi = 0.05, 500.0

    def e2e(k: float) -> float:
        res = run_policy(
            paper_workload(n, seed),
            SchedulerConfig(policy="fcfs", token_budget=chunk, max_seqs=max_seqs),
            cost_model=CostModel(scaled(BASE, k)),
        )
        return res.report.e2e["mean"]

    for _ in range(iters):
        mid = (lo * hi) ** 0.5
        if e2e(mid) < target_s:
            lo = mid
        else:
            hi = mid
    k = (lo * hi) ** 0.5
    _CAL_CACHE[key] = k
    return k


def calibrate_round_ms(target_round_ms: float = 105.0, budget: int = 1024) -> float:
    """Structural calibration for the LPRS/APC experiments (§4.4-4.5): pick
    the speed multiplier so one FULL prefill round (budget tokens, fresh
    context) costs the paper's ~105 ms — their engine's natural efficiency
    point — instead of the Table-4 overload regime.  Closed form from the
    linear cost model: c0*sqrt(k) + (c_prefill*B + c_seq)*k = target."""
    a = BASE.c_prefill_ms * budget + BASE.c_seq_ms
    b = BASE.c0_ms
    c = -target_round_ms
    # a*k + b*sqrt(k) + c = 0 -> quadratic in sqrt(k)
    s = (-b + (b * b - 4 * a * c) ** 0.5) / (2 * a)
    return s * s


def fmt_table(title: str, header: List[str], rows: List[List], widths=None) -> str:
    widths = widths or [max(len(str(r[i])) for r in rows + [header]) + 2
                        for i in range(len(header))]
    out = [f"\n### {title}"]
    out.append("".join(str(h).ljust(w) for h, w in zip(header, widths)))
    out.append("-" * sum(widths))
    for r in rows:
        out.append("".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


def pct_change(new: float, old: float) -> str:
    return f"{100.0 * (new - old) / old:+.2f}%"
