"""Preemption-mode benchmark: swap-out vs recompute under KV pool pressure.

The latency-control story (LPRS + APC) assumes preemption is cheap; with
recompute it is not — every pool-pressure eviction converts into a fresh
prefill burst that (a) re-burns compute for tokens the victim already paid
for and (b) re-enters the chunked-prefill queue as a LONG job, exactly the
fragmentation APC exists to suppress.  Swap-out preemption stages the
victim's KV host-side instead: its comeback is one restore round.

This bench runs ONE seeded workload through the real JAX engine on a pool
sized well below the working set (steady forced preemptions), under
``preemption_mode="recompute"`` and ``"swap"``, the tiered-hierarchy swap
variants (``swap+prefetch``, ``swap+tier``, ``swap+int8``), plus an
unconstrained reference (pool big enough that nobody is evicted).  It
reports, per mode:

  * preemptions / swap-outs / re-prefilled tokens (the recompute tax),
  * tier activity: prefetched restores, restore-wait rounds, host
    demotions, host peak bytes,
  * E2E latency percentiles over ALL requests and over the VICTIMS
    (requests preempted at least once in that run),
  * wall time and rounds.

Gates (asserted): greedy outputs identical across all full-precision runs
(by workload position — including runs whose staged victims were demoted
off the host tier and re-completed via recompute; int8 staging is lossy by
construction, so its gate is determinism across reps plus the bounded
logit-deviation probe below, not bit-equality with the bf16 runs), swap
mode's victim P99 E2E below recompute's, prefetch's restore-wait rounds
strictly below plain swap's, the host-tier byte ledger closed at exit
(its charge/release asserts enforce budget + closure at every mutation in
between), and the INT8 logit-deviation probe under ``INT8_LOGIT_TOL``
with greedy argmax unchanged.

Every run uses the SYNC serve loop: the pipelined loop's eager drain
(``inflight.toks.is_ready()``) makes round structure depend on whether the
device beat the host back to ``step()`` — wall-clock, not workload — so
round-count gates would flake.  Sync rounds are bit-deterministic, which
is what lets this bench assert exact cross-rep and cross-mode structure.

``--quick`` shrinks the workload for the CI smoke job.
``--check-regression`` compares the derived tier metrics against the
committed ``BENCH_throughput.json`` section (``preempt_quick`` /
``preempt_full``) and fails on >25% erosion.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save_json
from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like
from repro.kernels.ref import dequantize_pages, quantize_pages
from repro.models.model import build_model

# Committed quantization-error bound for int8 host pages, measured on the
# deterministic logit probe below (seeded tiny model, bf16 cache): the max
# abs next-token logit deviation after an int8 KV roundtrip.  Measured
# 0.0078125 on this config (one bf16 ulp at logit magnitude); committed
# with ~6x margin.  A regression past this means the quantizer (scales,
# rounding, layout) broke, not noise.
INT8_LOGIT_TOL = 0.05

# --check-regression slack on the derived tier metrics (saved re-prefill
# fraction, prefetch wait-round reduction, swap round reduction): the fresh
# run may erode at most this far below the committed BENCH_throughput.json
# section before the gate trips.  The >0 structural asserts catch breakage;
# this catches gradual erosion that still clears zero.
REGRESSION_TOL = 0.25

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_throughput.json")


def _load_sections() -> dict:
    try:
        with open(ROOT_JSON) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def _workload(quick: bool, model_cfg, seed: int = 21):
    # t=0 arrivals: round structure (and therefore the output-identity gate)
    # is independent of wall-clock timing, exactly like bench_serve_throughput
    spec = WorkloadSpec(
        n_requests=10 if quick else 24,
        inter_arrival_s=0.0,
        # prompt + generation must fit the engine's max_context (256)
        max_context=160 if quick else 192,
        max_new_tokens=32 if quick else 64,
        seed=seed,
    )
    reqs = sharegpt_like(spec)
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=seed)
    return reqs


def run_mode(name: str, *, mode: str, n_blocks: int, quick: bool,
             paged: bool = True, reps: int = 2, swap_prefetch_depth: int = 0,
             host_max_bytes=None, host_kv_dtype: str = "auto"):
    """Best-of-``reps`` by wall time (shared CI boxes stall individual runs;
    outputs and round counts must be identical across reps anyway — the sync
    serve loop makes every counter bit-deterministic)."""
    best = None
    for _ in range(reps):
        r = _run_once(name, mode=mode, n_blocks=n_blocks, quick=quick,
                      paged=paged, swap_prefetch_depth=swap_prefetch_depth,
                      host_max_bytes=host_max_bytes,
                      host_kv_dtype=host_kv_dtype)
        if best is not None:
            assert r["outputs"] == best["outputs"], f"{name}: nondeterministic"
            assert r["rounds"] == best["rounds"], f"{name}: round drift"
        if best is None or r["wall_s"] < best["wall_s"]:
            best = r
    return best


def _run_once(name: str, *, mode: str, n_blocks: int, quick: bool,
              paged: bool = True, swap_prefetch_depth: int = 0,
              host_max_bytes=None, host_kv_dtype: str = "auto"):
    model_cfg = tiny_config("qwen1.5-0.5b")
    # sync loop, NOT pipelined: the pipelined loop's eager drain fires on
    # device readiness (wall clock), which perturbs round structure and
    # every restore/preemption counter this bench gates on.  Sync rounds
    # are a pure function of the workload — identical on every machine.
    eng = JAXEngine(model_cfg, EngineConfig(
        n_slots=8, max_context=256, paged_kv=paged, pipelined=False,
        preemption_mode=mode, chunk_buckets=(1, 16, 32, 64),
    ))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    host_max_bytes=host_max_bytes,
                                    host_kv_dtype=host_kv_dtype))
    # bind BEFORE warmup: adopting an external pool rebuilds the physical
    # page array (page ids must equal the pool's block ids), which would
    # invalidate every shape the warmup just compiled — measured rounds
    # would then pay the jit cost warmup exists to hoist out
    eng.bind_kv_pool(pool)
    assert eng.kv_pool is pool and not eng.warmed, (
        "bench_preemption: the external KV pool must be bound BEFORE "
        "engine.warmup() — a post-warmup bind rebuilds the physical page "
        "array and re-pays every jit compile inside the measured rounds"
    )
    eng.warmup()
    # a small chunk budget stretches each recompute across many rounds —
    # exactly the fragmentation the paper's APC section attributes to
    # preemption-heavy regimes
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=32, max_seqs=8,
                        swap_prefetch_depth=swap_prefetch_depth)
    )
    reqs = _workload(quick, model_cfg)
    t0 = time.perf_counter()
    res = serve(reqs, sched, eng, kv_pool=pool)
    wall_s = time.perf_counter() - t0
    pool.check_invariants()
    host_stats = pool.host.stats if pool.host is not None else None
    if host_stats is not None:
        # the two-tier byte ledger must CLOSE: every byte ever staged came
        # back off (charge/release asserted budget + closure per mutation)
        pool.host.check_invariants()
        assert host_stats.resident_bytes == 0, (
            f"{name}: host tier leaked {host_stats.resident_bytes} bytes"
        )

    e2e = np.asarray([r.e2e_latency() for r in reqs], np.float64)
    victims = [r for r in reqs if r.preemptions > 0]
    v_e2e = np.asarray([r.e2e_latency() for r in victims], np.float64)
    return {
        "name": name,
        "mode": mode,
        "n_blocks": n_blocks,
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall_s,
        "preemptions": sched.stats.preemptions,
        "swap_preemptions": sched.stats.swap_preemptions,
        "swap_restores": sched.stats.swap_restores,
        "prefetched_restores": sched.stats.prefetched_restores,
        "restore_wait_rounds": sched.stats.restore_wait_rounds,
        "host_demotions": sched.stats.host_demotions,
        "partial_restores": sched.stats.partial_restores,
        "host_peak_bytes": host_stats.peak_bytes if host_stats else 0,
        "host_evictions": host_stats.evictions if host_stats else 0,
        # the recompute tax: prefill tokens scheduled beyond the workload's
        # own prompts (re-prefills of already-delivered context)
        "prefill_tokens": sched.stats.scheduled_prefill_tokens,
        "n_victims": len(victims),
        "e2e_p50_ms": float(np.percentile(e2e, 50) * 1e3),
        "e2e_p99_ms": float(np.percentile(e2e, 99) * 1e3),
        "victim_p99_ms": (
            float(np.percentile(v_e2e, 99) * 1e3) if len(victims) else 0.0
        ),
        "victim_mean_ms": (
            float(v_e2e.mean() * 1e3) if len(victims) else 0.0
        ),
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }


def measure_int8_logit_deviation():
    """Deterministic INT8 quantization-error probe: prefill a seeded prompt
    into a paged bf16 KV cache, then take ONE decode step twice — once
    against the original pages, once against pages roundtripped through the
    int8 host staging quantizer (exactly what a swap-out/swap-in cycle does
    to a victim's KV).  Returns (max abs logit deviation, greedy argmax
    unchanged)."""
    cfg = tiny_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    impl = model.impl
    rng = np.random.default_rng(17)
    B, P, bs = 2, 48, 16
    hd = cfg.resolved_head_dim
    max_pages = (P + 2 * bs) // bs
    n_phys = B * max_pages + 1          # +1 padding sink page
    pages = {
        "k": jnp.zeros((cfg.n_layers, n_phys, bs, cfg.n_kv_heads, hd),
                       jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, n_phys, bs, cfg.n_kv_heads, hd),
                       jnp.bfloat16),
    }
    bt = jnp.asarray(
        np.arange(B * max_pages).reshape(B, max_pages), jnp.int32)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, P)))
    lens0 = jnp.zeros((B,), jnp.int32)
    cl = jnp.full((B,), P, jnp.int32)
    logits, pages = impl.chunked_step_paged(params, toks, pages, lens0, cl, bt)
    nxt = jnp.argmax(logits, -1).astype(toks.dtype)[:, None]
    lens = jnp.full((B,), P, jnp.int32)
    one = jnp.ones((B,), jnp.int32)
    la, _ = impl.chunked_step_paged(params, nxt, pages, lens, one, bt)
    qk, sk = quantize_pages(pages["k"])
    qv, sv = quantize_pages(pages["v"])
    rt = {"k": dequantize_pages(qk, sk, jnp.bfloat16),
          "v": dequantize_pages(qv, sv, jnp.bfloat16)}
    lb, _ = impl.chunked_step_paged(params, nxt, rt, lens, one, bt)
    a = np.asarray(la, np.float32)
    b = np.asarray(lb, np.float32)
    dev = float(np.abs(a - b).max())
    argmax_same = bool((np.argmax(a, -1) == np.argmax(b, -1)).all())
    return dev, argmax_same


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (tiny workload)")
    ap.add_argument("--blocks", type=int, default=0,
                    help="pressured pool size in blocks (0 = auto)")
    ap.add_argument("--check-regression", action="store_true",
                    help="compare the derived tier metrics against the "
                         "committed BENCH_throughput.json section")
    args = ap.parse_args(argv)

    pressured = args.blocks or (14 if args.quick else 40)
    # a tier sized well below the concurrent staging peak of the plain swap
    # run (832 B quick / 3296 B full), so swap-outs demote the stage-time-
    # LRU-oldest record to recompute — but large enough that most restores
    # still come off the host tier
    host_budget = 600 if args.quick else 1600
    reps = 2 if args.quick else 3
    results = [
        run_mode("recompute", mode="recompute", n_blocks=pressured,
                 quick=args.quick, reps=reps),
        run_mode("swap", mode="swap", n_blocks=pressured, quick=args.quick,
                 reps=reps),
        run_mode("swap+prefetch", mode="swap", n_blocks=pressured,
                 quick=args.quick, reps=reps, swap_prefetch_depth=4),
        run_mode("swap+tier", mode="swap", n_blocks=pressured,
                 quick=args.quick, reps=reps, host_max_bytes=host_budget),
        run_mode("swap+int8", mode="swap", n_blocks=pressured,
                 quick=args.quick, reps=reps, host_kv_dtype="int8"),
        run_mode("unconstrained", mode="recompute", n_blocks=4096,
                 quick=args.quick, reps=reps),
    ]

    rows = [
        [r["name"], r["finished"], r["rounds"], r["preemptions"],
         r["swap_preemptions"], r["prefetched_restores"],
         r["restore_wait_rounds"], r["host_demotions"], r["prefill_tokens"],
         f"{r['victim_p99_ms']:.0f}", f"{r['e2e_p99_ms']:.0f}"]
        for r in results
    ]
    print(fmt_table(
        "Preemption modes under KV pool pressure (real JAX engine, sync/paged)",
        ["mode", "done", "rounds", "preempt", "swaps", "prefetch",
         "wait rnds", "demoted", "prefill tok", "victim p99 ms",
         "p99 e2e ms"],
        rows,
    ))

    rec, swp, pre, tier, int8, unc = results
    # correctness gate: one workload, five full-precision pool/mode regimes,
    # same tokens — including host-demoted victims re-completed via recompute
    # (tier).  int8 is exempt BY DESIGN: quantized staging perturbs restored
    # KV by up to half a scale step, which legitimately flips greedy argmax
    # on razor-thin logit margins; its gates are rep-determinism (asserted in
    # run_mode) plus the bounded logit-deviation probe below.
    for r in (swp, pre, tier, unc):
        assert r["outputs"] == rec["outputs"], (
            f"greedy outputs diverged: {r['name']} vs recompute"
        )
    n_diverged = sum(a != b for a, b in zip(int8["outputs"], rec["outputs"]))
    print(f"  int8 outputs: {n_diverged}/{len(int8['outputs'])} requests "
          f"diverged from bf16 (argmax flips inside the quantization band)")
    assert rec["preemptions"] > 0, "pressure too low: recompute never preempted"
    assert swp["swap_preemptions"] > 0, "swap mode never swapped"
    # deterministic structural gates (identical on every machine): swap must
    # eliminate re-prefill work and the rounds it fragments into
    saved_prefill = rec["prefill_tokens"] - swp["prefill_tokens"]
    assert saved_prefill > 0, "swap mode saved no re-prefill tokens"
    assert swp["rounds"] < rec["rounds"], (
        "swap mode did not reduce scheduling rounds under pressure"
    )
    # tier gates: prefetch eliminates cold restore rounds (victims come back
    # strictly earlier than plain swap's pop-path restores); the host budget
    # actually demoted staged victims — and they still finished bit-identical
    assert pre["prefetched_restores"] > 0, (
        "swap+prefetch: no restore was ever prefetched"
    )
    assert pre["restore_wait_rounds"] < swp["restore_wait_rounds"], (
        f"swap+prefetch did not reduce restore-wait rounds "
        f"({pre['restore_wait_rounds']} vs {swp['restore_wait_rounds']})"
    )
    assert tier["host_demotions"] > 0, (
        "swap+tier: the host budget never demoted a staged victim"
    )
    assert tier["host_peak_bytes"] <= host_budget
    assert int8["swap_preemptions"] > 0 and int8["host_peak_bytes"] > 0
    # int8 staging charges half the host bytes of full-width staging
    assert int8["host_peak_bytes"] < swp["host_peak_bytes"] or \
        swp["host_peak_bytes"] == 0
    # committed quantization-error gate: max abs next-token logit deviation
    # after an int8 KV roundtrip, greedy argmax unchanged
    dev, argmax_same = measure_int8_logit_deviation()
    print(f"  int8 logit probe: max abs deviation {dev:.4f} "
          f"(tol {INT8_LOGIT_TOL}), greedy argmax unchanged: {argmax_same}")
    assert dev < INT8_LOGIT_TOL, (
        f"int8 KV roundtrip logit deviation {dev:.4f} >= {INT8_LOGIT_TOL}"
    )
    assert argmax_same, "int8 KV roundtrip flipped a greedy argmax"
    print(f"  outputs identical across full-precision modes; swap avoided "
          f"re-prefilling "
          f"{saved_prefill} tokens "
          f"({saved_prefill / max(rec['prefill_tokens'], 1):.0%} of "
          f"recompute-mode prefill work) and ran "
          f"{rec['rounds'] - swp['rounds']} fewer rounds; prefetch cut "
          f"restore-wait rounds {swp['restore_wait_rounds']} -> "
          f"{pre['restore_wait_rounds']}; host tier demoted "
          f"{tier['host_demotions']} staged victims at peak "
          f"{tier['host_peak_bytes']} B")
    if rec["n_victims"] and swp["n_victims"]:
        gain = 1.0 - swp["victim_p99_ms"] / max(rec["victim_p99_ms"], 1e-9)
        print(f"  victim P99 E2E: {rec['victim_p99_ms']:.0f} ms (recompute) "
              f"-> {swp['victim_p99_ms']:.0f} ms (swap)  ({gain:+.1%})")
        # wall-clock gate only on full runs (the quotable number): at --quick
        # scale the whole run is a few seconds of interpret-mode dispatch, so
        # victim P99 is dominated by scheduling jitter, not by the recompute
        # tax — the deterministic round/token gates above are the CI smoke's
        # flake-proof signal
        if not args.quick:
            assert swp["victim_p99_ms"] < rec["victim_p99_ms"], (
                "swap mode did not reduce preempted-request P99 E2E"
            )

    # -- BENCH_throughput.json section + regression --------------------------
    # derived tier metrics: each is a deterministic function of the workload
    # (sync loop), so regressions here mean the hierarchy got worse, not that
    # the CI box got slower
    derived = {
        "saved_prefill_frac": saved_prefill / max(rec["prefill_tokens"], 1),
        "round_reduction": rec["rounds"] - swp["rounds"],
        "wait_round_reduction": (
            swp["restore_wait_rounds"] - pre["restore_wait_rounds"]
        ),
        "host_demotions": tier["host_demotions"],
        "int8_peak_frac": int8["host_peak_bytes"] / max(swp["host_peak_bytes"], 1),
        "int8_logit_dev": dev,
    }
    mode_key = "preempt_quick" if args.quick else "preempt_full"
    payload = {
        "pressured_blocks": pressured,
        "host_budget_bytes": host_budget,
        "derived": derived,
        "results": [{k: v for k, v in r.items() if k != "outputs"}
                    for r in results],
    }
    baseline = _load_sections().get(mode_key) if args.check_regression else None
    data = _load_sections()            # preserve the other sections
    data[mode_key] = payload
    with open(ROOT_JSON, "w") as f:
        json.dump(data, f, indent=1)
    print(f"\n  wrote {os.path.normpath(ROOT_JSON)} [{mode_key}]")

    if args.check_regression:
        if baseline is None:
            print(f"  no committed {mode_key!r} baseline to compare against")
        else:
            old = baseline["derived"]
            failures = []
            higher_better = ("saved_prefill_frac", "round_reduction",
                             "wait_round_reduction", "host_demotions")
            for k in higher_better:
                if derived[k] < old[k] * (1.0 - REGRESSION_TOL):
                    failures.append(
                        f"{k} {derived[k]:.3f} vs {old[k]:.3f} "
                        f"(>{REGRESSION_TOL:.0%} erosion)")
            for k in ("int8_peak_frac", "int8_logit_dev"):
                if derived[k] > old[k] * (1.0 + REGRESSION_TOL):
                    failures.append(
                        f"{k} {derived[k]:.4f} vs {old[k]:.4f} "
                        f"(>{REGRESSION_TOL:.0%} growth)")
            if failures:
                print(f"  REGRESSIONS vs committed {mode_key}: {failures}")
                raise SystemExit(1)
            print(f"  no regression vs committed {mode_key} "
                  f"(tol {REGRESSION_TOL:.0%})")

    save_json("bench_preemption.json", {
        "quick": args.quick,
        "pressured_blocks": pressured,
        "derived": derived,
        "results": payload["results"],
    })
    return results


if __name__ == "__main__":
    main()
