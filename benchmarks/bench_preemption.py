"""Preemption-mode benchmark: swap-out vs recompute under KV pool pressure.

The latency-control story (LPRS + APC) assumes preemption is cheap; with
recompute it is not — every pool-pressure eviction converts into a fresh
prefill burst that (a) re-burns compute for tokens the victim already paid
for and (b) re-enters the chunked-prefill queue as a LONG job, exactly the
fragmentation APC exists to suppress.  Swap-out preemption stages the
victim's KV host-side instead: its comeback is one restore round.

This bench runs ONE seeded workload through the real JAX engine on a pool
sized well below the working set (steady forced preemptions), under
``preemption_mode="recompute"`` and ``"swap"``, plus an unconstrained
reference (pool big enough that nobody is evicted).  It reports, per mode:

  * preemptions / swap-outs / re-prefilled tokens (the recompute tax),
  * E2E latency percentiles over ALL requests and over the VICTIMS
    (requests preempted at least once in that run),
  * wall time and rounds.

Gates (asserted): greedy outputs identical across all three runs (by
workload position), and swap mode's victim P99 E2E below recompute's.

``--quick`` shrinks the workload for the CI smoke job.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import fmt_table, save_json
from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import WorkloadSpec, attach_prompt_tokens, sharegpt_like


def _workload(quick: bool, model_cfg, seed: int = 21):
    # t=0 arrivals: round structure (and therefore the output-identity gate)
    # is independent of wall-clock timing, exactly like bench_serve_throughput
    spec = WorkloadSpec(
        n_requests=10 if quick else 24,
        inter_arrival_s=0.0,
        # prompt + generation must fit the engine's max_context (256)
        max_context=160 if quick else 192,
        max_new_tokens=32 if quick else 64,
        seed=seed,
    )
    reqs = sharegpt_like(spec)
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=seed)
    return reqs


def run_mode(name: str, *, mode: str, n_blocks: int, quick: bool,
             paged: bool = True, reps: int = 2):
    """Best-of-``reps`` by wall time (shared CI boxes stall individual runs;
    outputs and round counts must be identical across reps anyway)."""
    best = None
    for _ in range(reps):
        r = _run_once(name, mode=mode, n_blocks=n_blocks, quick=quick,
                      paged=paged)
        if best is not None:
            assert r["outputs"] == best["outputs"], f"{name}: nondeterministic"
            assert r["rounds"] == best["rounds"], f"{name}: round drift"
        if best is None or r["wall_s"] < best["wall_s"]:
            best = r
    return best


def _run_once(name: str, *, mode: str, n_blocks: int, quick: bool,
              paged: bool = True):
    model_cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(model_cfg, EngineConfig(
        n_slots=8, max_context=256, paged_kv=paged, pipelined=True,
        preemption_mode=mode, chunk_buckets=(1, 16, 32, 64),
    ))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4))
    # bind BEFORE warmup: adopting an external pool rebuilds the physical
    # page array (page ids must equal the pool's block ids), which would
    # invalidate every shape the warmup just compiled — measured rounds
    # would then pay the jit cost warmup exists to hoist out
    eng.bind_kv_pool(pool)
    eng.warmup()
    # a small chunk budget stretches each recompute across many rounds —
    # exactly the fragmentation the paper's APC section attributes to
    # preemption-heavy regimes
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=32, max_seqs=8)
    )
    reqs = _workload(quick, model_cfg)
    t0 = time.perf_counter()
    res = serve(reqs, sched, eng, kv_pool=pool)
    wall_s = time.perf_counter() - t0
    pool.check_invariants()

    e2e = np.asarray([r.e2e_latency() for r in reqs], np.float64)
    victims = [r for r in reqs if r.preemptions > 0]
    v_e2e = np.asarray([r.e2e_latency() for r in victims], np.float64)
    return {
        "name": name,
        "mode": mode,
        "n_blocks": n_blocks,
        "finished": res.report.n_finished,
        "rounds": res.rounds,
        "wall_s": wall_s,
        "preemptions": sched.stats.preemptions,
        "swap_preemptions": sched.stats.swap_preemptions,
        "swap_restores": sched.stats.swap_restores,
        # the recompute tax: prefill tokens scheduled beyond the workload's
        # own prompts (re-prefills of already-delivered context)
        "prefill_tokens": sched.stats.scheduled_prefill_tokens,
        "n_victims": len(victims),
        "e2e_p50_ms": float(np.percentile(e2e, 50) * 1e3),
        "e2e_p99_ms": float(np.percentile(e2e, 99) * 1e3),
        "victim_p99_ms": (
            float(np.percentile(v_e2e, 99) * 1e3) if len(victims) else 0.0
        ),
        "victim_mean_ms": (
            float(v_e2e.mean() * 1e3) if len(victims) else 0.0
        ),
        "outputs": [res.outputs[r.req_id] for r in reqs],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (tiny workload)")
    ap.add_argument("--blocks", type=int, default=0,
                    help="pressured pool size in blocks (0 = auto)")
    args = ap.parse_args(argv)

    pressured = args.blocks or (14 if args.quick else 40)
    reps = 2 if args.quick else 3
    results = [
        run_mode("recompute", mode="recompute", n_blocks=pressured,
                 quick=args.quick, reps=reps),
        run_mode("swap", mode="swap", n_blocks=pressured, quick=args.quick,
                 reps=reps),
        run_mode("unconstrained", mode="recompute", n_blocks=4096,
                 quick=args.quick, reps=reps),
    ]

    rows = [
        [r["name"], r["finished"], r["rounds"], r["preemptions"],
         r["swap_preemptions"], r["prefill_tokens"], r["n_victims"],
         f"{r['victim_mean_ms']:.0f}", f"{r['victim_p99_ms']:.0f}",
         f"{r['e2e_p99_ms']:.0f}"]
        for r in results
    ]
    print(fmt_table(
        "Preemption modes under KV pool pressure (real JAX engine, pipelined/paged)",
        ["mode", "done", "rounds", "preempt", "swaps", "prefill tok",
         "victims", "victim mean ms", "victim p99 ms", "p99 e2e ms"],
        rows,
    ))

    rec, swp, unc = results
    # correctness gate: one workload, three pool/mode regimes, same tokens
    assert rec["outputs"] == swp["outputs"] == unc["outputs"], (
        "greedy outputs diverged across preemption modes"
    )
    assert rec["preemptions"] > 0, "pressure too low: recompute never preempted"
    assert swp["swap_preemptions"] > 0, "swap mode never swapped"
    # deterministic structural gates (identical on every machine): swap must
    # eliminate re-prefill work and the rounds it fragments into
    saved_prefill = rec["prefill_tokens"] - swp["prefill_tokens"]
    assert saved_prefill > 0, "swap mode saved no re-prefill tokens"
    assert swp["rounds"] < rec["rounds"], (
        "swap mode did not reduce scheduling rounds under pressure"
    )
    print(f"  outputs identical across modes; swap avoided re-prefilling "
          f"{saved_prefill} tokens "
          f"({saved_prefill / max(rec['prefill_tokens'], 1):.0%} of "
          f"recompute-mode prefill work) and ran "
          f"{rec['rounds'] - swp['rounds']} fewer rounds")
    if rec["n_victims"] and swp["n_victims"]:
        gain = 1.0 - swp["victim_p99_ms"] / max(rec["victim_p99_ms"], 1e-9)
        print(f"  victim P99 E2E: {rec['victim_p99_ms']:.0f} ms (recompute) "
              f"-> {swp['victim_p99_ms']:.0f} ms (swap)  ({gain:+.1%})")
        # wall-clock gate only on full runs (the quotable number): at --quick
        # scale the whole run is a few seconds of interpret-mode dispatch, so
        # victim P99 is dominated by scheduling jitter, not by the recompute
        # tax — the deterministic round/token gates above are the CI smoke's
        # flake-proof signal
        if not args.quick:
            assert swp["victim_p99_ms"] < rec["victim_p99_ms"], (
                "swap mode did not reduce preempted-request P99 E2E"
            )

    save_json("bench_preemption.json", {
        "quick": args.quick,
        "pressured_blocks": pressured,
        "results": [{k: v for k, v in r.items() if k != "outputs"}
                    for r in results],
    })
    return results


if __name__ == "__main__":
    main()
