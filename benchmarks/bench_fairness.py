"""Multi-tenant fairness: FCFS vs Aging vs Aging+VTC under a 1-heavy/4-light
tenant mix.

The paper's Aging policy is fair across REQUESTS; this bench shows what that
means for TENANTS: one heavy client (30 rps, long prompts — far above engine
capacity) pushes every light client's P99 TTFT two orders of magnitude above
its isolated-run value, even under perfect request-level aging, because a
light request must out-age the heavy tenant's entire standing backlog.  The
tenancy subsystem's weighted Virtual Token Counter restores isolation: each
light tenant's P99 TTFT stays within 2x of what it sees running ALONE on the
same engine, and Jain's fairness index over per-tenant service (measured at
a fixed horizon, mid-backlog) strictly improves.

Cost model: a deliberately overhead-dominated round (c0 = 60 ms fixed cost
per round, Sarathi-style fused-batch launch + host scheduling floor), so
round latency is comparable between a full 512-token mixed round and a
light tenant's small isolated round — TTFT differences then measure
QUEUEING interference, not batch-size arithmetic.

Acceptance gates (printed as PASS/FAIL at the end):
  1. jain(aging+vtc) > jain(aging)            at the 30 s horizon
  2. P99 TTFT(light, shared aging+vtc) <= 2x P99 TTFT(light, isolated)
"""
from __future__ import annotations

from typing import Dict, List


from benchmarks.common import fmt_table, save_json
from repro.core.scheduler import SchedulerConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.metrics import summarize_by_tenant
from repro.engine.simulator import run_policy
from repro.engine.workload import TenantTraffic, multi_tenant
from repro.tenancy import FairnessConfig, TenantSpec

# overhead-dominated engine: 60 ms/round floor, ~6k prefill tok/s saturated
COST = CostModelConfig(
    c0_ms=60.0, c_prefill_ms=0.05, c_attn_ms=1e-6,
    c_decode_ms=0.15, c_ctx_ms=1e-5, c_seq_ms=0.08, noise_std=0.01,
)
ALPHA, BETA = 1.0, -0.01
BUDGET, MAX_SEQS = 512, 128
DURATION_S = 30.0
LIGHTS = [f"light{i}" for i in range(4)]

# each tenant's contracted rate = its 1/5 share of ~6k tok/s engine capacity
SHARE_TOK_S = 1200.0
SPECS = tuple(
    [TenantSpec("heavy0", rate_tokens_per_s=SHARE_TOK_S, burst_tokens=2 * SHARE_TOK_S)]
    + [TenantSpec(t, rate_tokens_per_s=SHARE_TOK_S, burst_tokens=3 * SHARE_TOK_S)
       for t in LIGHTS]
)

CONFIGS = {
    "fcfs": dict(policy="fcfs", fairness=None),
    "aging": dict(policy="aging", fairness=None),
    "aging+vtc": dict(policy="aging", fairness=FairnessConfig(
        tenants=SPECS, admission=False)),
    "aging+vtc+adm": dict(policy="aging", fairness=FairnessConfig(
        tenants=SPECS, admission=True, penalty_window_s=2.0)),
}


def tenant_mix() -> List[TenantTraffic]:
    """1 heavy (5x overload on its own) + 4 light interactive tenants."""
    return [
        TenantTraffic("heavy0", "heavy", rps=30.0, prompt_mean=256.0,
                      max_new_tokens=24),
    ] + [
        TenantTraffic(t, "light", rps=3.0, prompt_mean=96.0,
                      prompt_sigma=0.35, max_new_tokens=16)
        for t in LIGHTS
    ]


def workload(seed: int):
    return multi_tenant(tenant_mix(), duration_s=DURATION_S, seed=seed)


def scheduler_cfg(policy: str, fairness) -> SchedulerConfig:
    return SchedulerConfig(policy=policy, alpha=ALPHA, beta=BETA,
                           token_budget=BUDGET, max_seqs=MAX_SEQS,
                           fairness=fairness)


def run_shared(seed: int) -> Dict[str, dict]:
    """Each config twice: horizon-clipped (service share mid-backlog) and
    run-to-completion (every TTFT defined)."""
    cost = CostModel(COST)
    out = {}
    for label, cfg in CONFIGS.items():
        sc = scheduler_cfg(cfg["policy"], cfg["fairness"])
        at_horizon = summarize_by_tenant(
            run_policy(workload(seed), sc, cost_model=cost,
                       horizon_s=DURATION_S).requests)
        complete = summarize_by_tenant(
            run_policy(workload(seed), sc, cost_model=cost).requests)
        out[label] = {
            "jain": at_horizon.jain,
            "max_service_delta": at_horizon.max_service_delta,
            "service": at_horizon.service_tokens,
            "p99_ttft": {t: r.ttft["p99"] for t, r in complete.per_tenant.items()},
            "mean_ttft": {t: r.ttft["mean"] for t, r in complete.per_tenant.items()},
        }
    return out


def run_isolated(seed: int) -> Dict[str, float]:
    """Each light tenant alone on the same engine + aging+vtc config."""
    cost = CostModel(COST)
    sc = scheduler_cfg("aging", CONFIGS["aging+vtc"]["fairness"])
    iso = {}
    for t in LIGHTS:
        reqs = [r for r in workload(seed) if r.tenant == t]
        rep = summarize_by_tenant(run_policy(reqs, sc, cost_model=cost).requests)
        iso[t] = rep.per_tenant[t].ttft["p99"]
    return iso


def main(seed: int = 0, duration_s: float = None):
    global DURATION_S
    if duration_s is not None:
        DURATION_S = duration_s     # CI smoke: tiny horizon, gates informational
    shared = run_shared(seed)
    iso = run_isolated(seed)

    rows = []
    for label, r in shared.items():
        rows.append([
            label,
            f"{r['jain']:.3f}",
            f"{r['max_service_delta'] / 1e3:.1f}k",
            f"{r['p99_ttft']['heavy0']:.2f}s",
            f"{max(r['p99_ttft'][t] for t in LIGHTS):.2f}s",
            f"{max(r['p99_ttft'][t] / iso[t] for t in LIGHTS):.2f}x",
        ])
    print(fmt_table(
        f"Fairness — 1 heavy (30 rps) vs 4 light (3 rps) tenants, {DURATION_S:.0f}s",
        ["Config", "Jain@30s", "SvcΔ", "Heavy P99 TTFT", "Worst light P99",
         "Worst light vs isolated"],
        rows,
    ))
    print("\n  isolated light P99 TTFT: "
          + ", ".join(f"{t}={iso[t] * 1e3:.0f}ms" for t in LIGHTS))

    # -- acceptance gates ----------------------------------------------------
    jain_gain = shared["aging+vtc"]["jain"] - shared["aging"]["jain"]
    gate1 = shared["aging+vtc"]["jain"] > shared["aging"]["jain"]
    worst_ratio = max(shared["aging+vtc"]["p99_ttft"][t] / iso[t] for t in LIGHTS)
    gate2 = worst_ratio <= 2.0
    aging_ratio = max(shared["aging"]["p99_ttft"][t] / iso[t] for t in LIGHTS)
    print(f"\n  gate 1 [{'PASS' if gate1 else 'FAIL'}] "
          f"Jain aging {shared['aging']['jain']:.3f} -> aging+vtc "
          f"{shared['aging+vtc']['jain']:.3f} (+{jain_gain:.3f})")
    print(f"  gate 2 [{'PASS' if gate2 else 'FAIL'}] "
          f"worst light P99 vs isolated: {worst_ratio:.2f}x <= 2x "
          f"(aging alone: {aging_ratio:.0f}x)")

    save_json("bench_fairness.json", {
        "seed": seed, "shared": shared, "isolated": iso,
        "gates": {"jain_improves": bool(gate1),
                  "light_p99_within_2x_isolated": bool(gate2)},
    })
    return shared, iso


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--duration", type=float, default=None,
                    help="override the 30 s workload horizon (CI smoke)")
    args = ap.parse_args()
    main(seed=args.seed, duration_s=args.duration)
