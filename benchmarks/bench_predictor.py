"""Paper Table 8: latency-predictor accuracy.

The profiling dataset is collected exactly as §3.2.1 describes — running the
token-budget scheduler over diverse arrival rates / length mixes /
concurrency levels and recording (16-dim features, per-round latency) — with
the calibrated cost model standing in for the instrumented GPU (its noise
term models real measurement jitter)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BASE, calibrate_multiplier, fmt_table, save_json, scaled
from repro.core.predictor import (
    LatencyPredictor, PredictorConfig, bucket_and_downsample,
)
from repro.core.scheduler import SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.simulator import run_policy
from repro.engine.workload import WorkloadSpec, sharegpt_like

TARGET_SAMPLES = 36_868      # paper's profiling-set size


def collect_profile(k: float, target: int = TARGET_SAMPLES, seed: int = 0,
                    budget: int = 1024, max_seqs: int = 64):
    """§3.2.1 step 3: run the token-budget scheduler under diverse arrival
    rates, prompt-length mixtures and concurrency levels — at the DEPLOYED
    budget config (the paper profiles the engine it will serve with, not a
    grid of engines), then clean the raw samples."""
    feats, lats = [], []
    cm = scaled(BASE, k)
    cfgs = []
    s = seed
    for interval in (0.02, 0.05, 0.1, 0.3):
        for max_ctx in (256, 512, 1024):
            for max_new in (64, 256):
                cfgs.append((interval, max_ctx, max_new, s))
                s += 1
    i = 0
    while sum(len(l) for l in lats) < target:
        interval, max_ctx, max_new, s = cfgs[i % len(cfgs)]
        i += 1
        reqs = sharegpt_like(WorkloadSpec(
            n_requests=300, inter_arrival_s=interval, max_context=max_ctx,
            max_new_tokens=max_new, seed=s + 1000 * i,
        ))
        res = run_policy(
            reqs,
            SchedulerConfig(policy="fcfs", token_budget=budget,
                            max_seqs=max_seqs),
            cost_model=CostModel(cm),
            collect_samples=True,
        )
        if res.samples is not None:
            feats.append(res.samples[0])
            lats.append(res.samples[1])
    X = np.concatenate(feats)[:target]
    y = np.concatenate(lats)[:target]
    return X, y


def main(quick: bool = False):
    k = calibrate_multiplier()
    target = 6000 if quick else TARGET_SAMPLES
    X, y = collect_profile(k, target)
    print(f"  profiling dataset: {len(y)} rounds "
          f"(paper: {TARGET_SAMPLES}), latency p50 {np.median(y):.1f} ms")

    # 8:1:1 split (paper)
    n = len(y)
    idx = np.random.default_rng(0).permutation(n)
    tr, va, te = np.split(idx, [int(0.8 * n), int(0.9 * n)])

    keep, w = bucket_and_downsample(X[tr][:, 12])
    rows = []
    out = {}
    for label, cfg in (
        ("paper-exact (Table 7)", PredictorConfig(epochs=60 if quick else 300)),
        ("tuned (dropout 0)", PredictorConfig(epochs=60 if quick else 300,
                                              dropout=0.0)),
    ):
        pred = LatencyPredictor(cfg)
        pred.fit(X[tr][keep], y[tr][keep], sample_weights=w)
        m = pred.evaluate(X[te], y[te])
        out[label] = m
        rows.append([
            label,
            f"{m['mae_ms']:.2f}", f"{m['rmse_ms']:.2f}", f"{m['mape_pct']:.2f}%",
            f"{m['p50_ms']:.2f}", f"{m['p99_ms']:.2f}",
            f"{m['within_5ms_pct']:.1f}%", f"{m['within_10ms_pct']:.1f}%",
        ])
    print(fmt_table(
        f"Table 8 — predictor accuracy on the held-out test set (n={len(te)})",
        ["Variant", "MAE", "RMSE", "MAPE", "P50", "P99", "<=5ms", "<=10ms"],
        rows,
    ))
    med = float(np.median(y))
    m = out["tuned (dropout 0)"]
    print(f"  paper: MAE 1.13 ms on ~100 ms rounds (1.1% of scale); "
          f"ours: MAE {m['mae_ms']:.1f} ms on {med:.0f} ms rounds "
          f"({100 * m['mae_ms'] / med:.1f}% of scale), MAPE {m['mape_pct']:.2f}% "
          f"(paper 1.26%)")
    save_json("bench_predictor.json", {"metrics": out, "n_samples": int(n)})
    return out


if __name__ == "__main__":
    main()
