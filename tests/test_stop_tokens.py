"""Value-dependent stop tokens (EOS) in both serve loops.

The pipelined engine learns token VALUES one round late, so a stop is only
observable at drain time — by which point the scheduler may already have
booked the request into the next, not-yet-dispatched round.  The contract:
greedy outputs under a stop token are BIT-IDENTICAL between the synchronous
and pipelined loops (both equal the no-stop reference truncated at the first
stop occurrence), the over-scheduled round's bookings are refunded, and the
pools balance — including under KV pressure with swap preemption racing the
late stops.
"""
import pytest

from repro.configs import tiny_config
from repro.core.request import RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, ReplicaServer, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.metrics import summarize_slo
from repro.engine.workload import shared_prefix
from repro.tenancy import FairnessConfig, TenantSpec


def _two_wave(seed=5, n=12, new_tokens=10):
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
    return reqs


def _serve(reqs, *, pipelined, n_blocks=11, stop=None):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=True, pipelined=pipelined,
                                      preemption_mode="swap", seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    if stop is not None:
        for r in reqs:
            r.stop_token = stop
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    assert not pool.swapped_requests()
    return res, sched


def test_stop_token_sync_and_pipelined_identical():
    """Harvest a mid-stream token from the no-stop reference, then re-run
    with it as the EOS: both loop modes must truncate every request at its
    own first occurrence, produce identical outputs, and refund whatever the
    pipelined loop had over-scheduled past the stop."""
    reqs_ref = _two_wave()
    res_ref, _ = _serve(reqs_ref, pipelined=True, n_blocks=400)
    ref_out = {i: res_ref.outputs[r.req_id] for i, r in enumerate(reqs_ref)}
    stop = ref_out[0][4]          # position-0 request's 5th token

    reqs_p = _two_wave()
    res_p, sched_p = _serve(reqs_p, pipelined=True, stop=stop)
    reqs_s = _two_wave()
    res_s, sched_s = _serve(reqs_s, pipelined=False, stop=stop)

    assert all(r.state == RequestState.FINISHED for r in reqs_p + reqs_s)
    for i, (a, b) in enumerate(zip(reqs_p, reqs_s)):
        ref = ref_out[i]
        first = ref.index(stop) if stop in ref else None
        want = ref if first is None else ref[:first + 1]
        assert res_p.outputs[a.req_id] == want
        assert res_s.outputs[b.req_id] == want
        # a stop landing exactly on the length-cap token is a length finish
        # in both modes (FINISHED is handled before the stop check)
        expect_stopped = first is not None and first < len(ref) - 1
        assert a.stopped == b.stopped == expect_stopped
    # the stop actually exercised the late path in both modes
    assert sched_p.stats.late_stops > 0
    assert sched_s.stats.late_stops > 0
    assert sched_p.stats.late_stops == sched_s.stats.late_stops
    # only the pipelined loop can over-schedule past a stop (it books round
    # N+1 before round N's values are visible) — and when it does, the
    # phantom bookings are refunded
    assert sched_p.stats.refunded_decode_tokens > 0
    assert sched_s.stats.refunded_decode_tokens == 0


def test_stop_on_first_token_terminates_immediately():
    """A stop equal to a request's FIRST sampled token: one output token,
    stopped flag set, no decode rounds wasted, in both loop modes."""
    reqs_ref = _two_wave()
    res_ref, _ = _serve(reqs_ref, pipelined=True, n_blocks=400)
    stop = res_ref.outputs[reqs_ref[0].req_id][0]

    for pipelined in (True, False):
        reqs = _two_wave()
        res, sched = _serve(reqs, pipelined=pipelined, stop=stop)
        assert reqs[0].stopped
        assert res.outputs[reqs[0].req_id] == [stop]
        assert reqs[0].generated == 1
        assert sched.stats.late_stops > 0


@pytest.mark.parametrize("paged", [True, False])
def test_late_stop_on_shed_request_is_skipped(paged):
    """SLO-shed x late-stop interplay: a request shed WHILE its just-sampled
    token is still in the pipelined in-flight round must be skipped by the
    drain's stop check — the shed already unwound its bookings (KV blocks,
    slot, queue/fairness state), so applying the stop again would
    double-finish a FINISHED request.  The shed request ends in the shed
    attainment bucket, never as a violation, in paged and dense engines."""
    reqs = _two_wave(new_tokens=8)
    for r in reqs:
        r.arrival_time = 0.0
        r.tenant = "t"

    eng = JAXEngine(tiny_config("qwen1.5-0.5b"),
                    EngineConfig(n_slots=6, max_context=128, paged_kv=paged,
                                 pipelined=True, preemption_mode="swap",
                                 seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=400, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True)) if paged else None
    sched = ChunkedPrefillScheduler(SchedulerConfig(
        policy="fcfs", token_budget=96, max_seqs=6,
        fairness=FairnessConfig(tenants=(TenantSpec("t", ttft_slo_s=1e6),),
                                admission=False),
    ))
    victim = reqs[0]

    def shed_at_prefill_complete(server, r):
        # fires in the round that completed r's prefill — in pipelined mode
        # its first sampled token is STILL IN FLIGHT (placeholder id); shed
        # now and the drain must leave the finished request alone
        if r is victim and r.shed_reason is None:
            server.sched.shed_request(r, reason="deadline")

    server = ReplicaServer(sched, eng, kv_pool=pool,
                           on_prefill_complete=shed_at_prefill_complete)
    for r in reqs:
        server.submit(r)
    steps = 0
    while server.busy() and steps < 5000:
        server.step(server._now())
        steps += 1
    server.finish()

    assert victim.state == RequestState.FINISHED
    assert victim.shed_reason == "deadline"
    assert victim.finish_time is None            # never served to completion
    assert not victim.stopped                    # the late stop did NOT land
    assert sched.stats.sheds == 1
    if paged:
        # the shed refunded every booking: no blocks, no staged swap record
        assert not pool.tables.get(victim.req_id)
        pool.check_invariants()
        assert not pool.swapped_requests()
    survivors = [r for r in reqs if r is not victim]
    assert all(r.state == RequestState.FINISHED and r.finish_time is not None
               for r in survivors)
    rep = summarize_slo(reqs, sched.fairness.registry)
    assert rep.per_tenant["t"].shed == 1
    assert rep.per_tenant["t"].violated == 0     # shed is never a violation
    assert rep.per_tenant["t"].attained == len(survivors)


@pytest.mark.parametrize("paged", [True, False])
def test_stop_after_shed_never_double_unwinds(paged):
    """Same interplay with a real stop token armed on the victim: the stop
    value is sampled into the in-flight round before the shed retires the
    request, so the drain sees a FINISHED request whose output tail EQUALS
    its stop token — the one configuration where a missing state check
    would call finish_stopped() on a finished request and crash."""
    # harvest the victim's first sampled token as the stop value
    ref = _two_wave(new_tokens=6)
    for r in ref:
        r.arrival_time = 0.0
    res_ref, _ = _serve(ref, pipelined=True, n_blocks=400)
    stop = res_ref.outputs[ref[0].req_id][0]

    reqs = _two_wave(new_tokens=6)
    for r in reqs:
        r.arrival_time = 0.0
        r.tenant = "t"
        r.stop_token = stop
    eng = JAXEngine(tiny_config("qwen1.5-0.5b"),
                    EngineConfig(n_slots=6, max_context=128, paged_kv=paged,
                                 pipelined=True, preemption_mode="swap",
                                 seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=400, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True)) if paged else None
    sched = ChunkedPrefillScheduler(SchedulerConfig(
        policy="fcfs", token_budget=96, max_seqs=6,
        fairness=FairnessConfig(tenants=(TenantSpec("t", ttft_slo_s=1e6),),
                                admission=False),
    ))
    victim = reqs[0]

    def shed_hook(server, r):
        if r is victim and r.shed_reason is None:
            server.sched.shed_request(r, reason="deadline")

    server = ReplicaServer(sched, eng, kv_pool=pool,
                           on_prefill_complete=shed_hook)
    for r in reqs:
        server.submit(r)
    steps = 0
    while server.busy() and steps < 5000:
        server.step(server._now())
        steps += 1
    server.finish()

    assert victim.output_tokens and victim.output_tokens[0] == stop
    assert not victim.stopped and victim.finish_time is None
    assert victim.shed_reason == "deadline"
    # every OTHER request still honors its own stop normally
    assert all(r.state == RequestState.FINISHED for r in reqs)
    rep = summarize_slo(reqs, sched.fairness.registry)
    assert rep.per_tenant["t"].shed == 1 and rep.per_tenant["t"].violated == 0
    if paged:
        pool.check_invariants()
        assert not pool.swapped_requests()


def test_no_stop_token_is_byte_identical_to_baseline():
    """stop_token=None must leave the serve loops untouched: same outputs,
    zero stop-path stats."""
    reqs_a = _two_wave()
    res_a, sched_a = _serve(reqs_a, pipelined=True)
    assert sched_a.stats.late_stops == 0
    assert sched_a.stats.refunded_decode_tokens == 0
    assert all(not r.stopped for r in reqs_a)
    assert all(len(res_a.outputs[r.req_id]) == r.max_new_tokens or
               r.max_new_tokens >= len(res_a.outputs[r.req_id]) > 0
               for r in reqs_a)
