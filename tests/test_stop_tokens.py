"""Value-dependent stop tokens (EOS) in both serve loops.

The pipelined engine learns token VALUES one round late, so a stop is only
observable at drain time — by which point the scheduler may already have
booked the request into the next, not-yet-dispatched round.  The contract:
greedy outputs under a stop token are BIT-IDENTICAL between the synchronous
and pipelined loops (both equal the no-stop reference truncated at the first
stop occurrence), the over-scheduled round's bookings are refunded, and the
pools balance — including under KV pressure with swap preemption racing the
late stops.
"""
from repro.configs import tiny_config
from repro.core.request import RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import shared_prefix


def _two_wave(seed=5, n=12, new_tokens=10):
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
    return reqs


def _serve(reqs, *, pipelined, n_blocks=11, stop=None):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=True, pipelined=pipelined,
                                      preemption_mode="swap", seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    if stop is not None:
        for r in reqs:
            r.stop_token = stop
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    assert not pool.swapped_requests()
    return res, sched


def test_stop_token_sync_and_pipelined_identical():
    """Harvest a mid-stream token from the no-stop reference, then re-run
    with it as the EOS: both loop modes must truncate every request at its
    own first occurrence, produce identical outputs, and refund whatever the
    pipelined loop had over-scheduled past the stop."""
    reqs_ref = _two_wave()
    res_ref, _ = _serve(reqs_ref, pipelined=True, n_blocks=400)
    ref_out = {i: res_ref.outputs[r.req_id] for i, r in enumerate(reqs_ref)}
    stop = ref_out[0][4]          # position-0 request's 5th token

    reqs_p = _two_wave()
    res_p, sched_p = _serve(reqs_p, pipelined=True, stop=stop)
    reqs_s = _two_wave()
    res_s, sched_s = _serve(reqs_s, pipelined=False, stop=stop)

    assert all(r.state == RequestState.FINISHED for r in reqs_p + reqs_s)
    for i, (a, b) in enumerate(zip(reqs_p, reqs_s)):
        ref = ref_out[i]
        first = ref.index(stop) if stop in ref else None
        want = ref if first is None else ref[:first + 1]
        assert res_p.outputs[a.req_id] == want
        assert res_s.outputs[b.req_id] == want
        # a stop landing exactly on the length-cap token is a length finish
        # in both modes (FINISHED is handled before the stop check)
        expect_stopped = first is not None and first < len(ref) - 1
        assert a.stopped == b.stopped == expect_stopped
    # the stop actually exercised the late path in both modes
    assert sched_p.stats.late_stops > 0
    assert sched_s.stats.late_stops > 0
    assert sched_p.stats.late_stops == sched_s.stats.late_stops
    # only the pipelined loop can over-schedule past a stop (it books round
    # N+1 before round N's values are visible) — and when it does, the
    # phantom bookings are refunded
    assert sched_p.stats.refunded_decode_tokens > 0
    assert sched_s.stats.refunded_decode_tokens == 0


def test_stop_on_first_token_terminates_immediately():
    """A stop equal to a request's FIRST sampled token: one output token,
    stopped flag set, no decode rounds wasted, in both loop modes."""
    reqs_ref = _two_wave()
    res_ref, _ = _serve(reqs_ref, pipelined=True, n_blocks=400)
    stop = res_ref.outputs[reqs_ref[0].req_id][0]

    for pipelined in (True, False):
        reqs = _two_wave()
        res, sched = _serve(reqs, pipelined=pipelined, stop=stop)
        assert reqs[0].stopped
        assert res.outputs[reqs[0].req_id] == [stop]
        assert reqs[0].generated == 1
        assert sched.stats.late_stops > 0


def test_no_stop_token_is_byte_identical_to_baseline():
    """stop_token=None must leave the serve loops untouched: same outputs,
    zero stop-path stats."""
    reqs_a = _two_wave()
    res_a, sched_a = _serve(reqs_a, pipelined=True)
    assert sched_a.stats.late_stops == 0
    assert sched_a.stats.refunded_decode_tokens == 0
    assert all(not r.stopped for r in reqs_a)
    assert all(len(res_a.outputs[r.req_id]) == r.max_new_tokens or
               r.max_new_tokens >= len(res_a.outputs[r.req_id]) > 0
               for r in reqs_a)
