"""Multi-tenant fairness subsystem: VTC accounting invariants, weighted
sharing, admission control, fair-queue ordering, and fairness metrics."""
import numpy as np
import pytest

from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.engine import compress_idle_gap
from repro.engine.metrics import jain_index, summarize_by_tenant
from repro.engine.simulator import ServingSimulator, run_policy
from repro.engine.workload import TenantTraffic, multi_tenant
from repro.tenancy import (
    AdmissionController,
    FairnessConfig,
    FairPrefillQueue,
    TenantRegistry,
    TenantSpec,
    VirtualTokenCounter,
)
from repro.core.policies import PrefillQueue, make_policy


def mk(prompt, arrival=0.0, tenant="default", gen=4):
    return Request(prompt_len=prompt, max_new_tokens=gen,
                   arrival_time=arrival, tenant=tenant)


def fair_cfg(*specs, **kw):
    return FairnessConfig(tenants=tuple(specs), **kw)


# ---------------------------------------------------------------------------
# Jain's index edge cases
# ---------------------------------------------------------------------------


def test_jain_empty_is_nan():
    assert np.isnan(jain_index([]))


def test_jain_single_tenant_is_one():
    assert jain_index([123.0]) == pytest.approx(1.0)


def test_jain_uniform_is_one():
    assert jain_index([5.0] * 7) == pytest.approx(1.0)


def test_jain_all_zero_is_one():
    assert jain_index([0.0, 0.0]) == pytest.approx(1.0)


def test_jain_monopolist_is_one_over_n():
    assert jain_index([10.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)


def test_jain_skew_below_one():
    assert jain_index([100.0, 1.0, 1.0]) < 0.5


# ---------------------------------------------------------------------------
# VTC unit behavior
# ---------------------------------------------------------------------------


def test_vtc_charge_weights_and_weighting():
    reg = TenantRegistry((TenantSpec("a", weight=2.0),))
    vtc = VirtualTokenCounter(reg, prefill_weight=1.0, decode_weight=2.0)
    inc = vtc.charge("a", prefill_tokens=10, decode_tokens=5)
    # (1*10 + 2*5) / weight 2 = 10
    assert inc == pytest.approx(10.0)
    assert vtc.virtual_service("a") == pytest.approx(10.0)
    assert vtc.actual_tokens("a") == 15


def test_vtc_lift_prevents_idle_credit():
    reg = TenantRegistry(())
    vtc = VirtualTokenCounter(reg)
    vtc.charge("busy", 1000, 0)
    # idle tenant re-activates while 'busy' is active: lifted to the floor
    vtc.on_activate("idle", active={"busy"})
    assert vtc.virtual_service("idle") == pytest.approx(1000.0)
    # activating with no active peers leaves the counter untouched
    vtc.on_activate("alone", active=set())
    assert vtc.virtual_service("alone") == 0.0
    # a lift never lowers a counter
    vtc.charge("rich", 5000, 0)
    vtc.on_activate("rich", active={"busy"})
    assert vtc.virtual_service("rich") == pytest.approx(5000.0)


# ---------------------------------------------------------------------------
# VTC conservation through the scheduler
# ---------------------------------------------------------------------------


def test_vtc_conservation_total_charged_equals_total_executed():
    cfg = SchedulerConfig(
        policy="aging", alpha=1.0, beta=-0.1, token_budget=256, max_seqs=32,
        fairness=fair_cfg(admission=False),
    )
    sched = ChunkedPrefillScheduler(cfg)
    reqs = multi_tenant(duration_s=8.0, seed=3)
    ServingSimulator(sched, CostModel()).run(reqs)
    vtc = sched.fairness.vtc
    executed = (
        sched.stats.scheduled_prefill_tokens + sched.stats.scheduled_decode_tokens
    )
    # first output tokens ride the prefill-completion round (not counted in
    # scheduled_decode_tokens) but are delivered service, so the VTC books them
    first_tokens = sum(1 for r in reqs if r.prefill_end_time is not None)
    assert vtc.total_actual_tokens() == executed + first_tokens
    assert vtc.total_prefill_tokens() == sched.stats.scheduled_prefill_tokens
    assert vtc.total_decode_tokens() == (
        sched.stats.scheduled_decode_tokens + first_tokens
    )
    # and the per-request view agrees (nothing double- or under-charged):
    # every token delivered to a request — prefill progress plus generated
    # output, including the first token that rides the prefill-completion
    # round (Sarathi semantics) — is on the VTC's books exactly once
    delivered = sum(r.prefill_done + r.generated for r in reqs)
    assert vtc.total_actual_tokens() == delivered


def _vtc_apc_engine_run(seed, pipelined):
    """Real-engine serve under an APC config tuned to block aggressively
    (c_max=1: ONE active prefill ever, so every other candidate is
    cap-blocked and re-queued each round; l_min=48 against a 64-token budget
    keeps the cap at exactly min(1, floor(residual/48))), then check the
    VTC's books against ground truth: per-tenant charged tokens == tokens
    actually delivered (prefill progress + generated output), despite every
    deferral, warm start, and re-queue the gate causes.  NOTE l_min must
    stay <= token_budget: a larger l_min pins Eq. 12's cap at 0 and APC
    (correctly, but fatally for a serve loop) blocks all prefills forever."""
    from repro.configs import tiny_config
    from repro.core.apc import APCConfig
    from repro.engine.engine import EngineConfig, JAXEngine, serve

    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(10):
        r = mk(int(rng.integers(16, 80)), arrival=float(0.02 * i),
               tenant=("a" if i % 2 else "b"), gen=int(rng.integers(2, 8)))
        r.prompt_tokens = [int(t) for t in rng.integers(0, 512, r.prompt_len)]
        reqs.append(r)
    eng = JAXEngine(tiny_config("qwen1.5-0.5b"),
                    EngineConfig(n_slots=4, max_context=128,
                                 pipelined=pipelined, seed=3))
    sched = ChunkedPrefillScheduler(SchedulerConfig(
        policy="fcfs", token_budget=64, max_seqs=4,
        apc=APCConfig(c_max=1, l_min=48),
        fairness=fair_cfg(admission=False),
    ))
    serve(reqs, sched, eng, max_rounds=5000)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert sched.stats.apc.blocked_by_cap + sched.stats.apc.warm_starts > 0
    vtc = sched.fairness.vtc
    for t in ("a", "b"):
        delivered = sum(r.prefill_done + r.generated
                        for r in reqs if r.tenant == t)
        assert vtc.actual_tokens(t) == delivered
    assert vtc.total_actual_tokens() == sum(
        r.prefill_done + r.generated for r in reqs
    )


from _hyp import given, settings, st  # noqa: E402


@settings(max_examples=2, deadline=None)
@given(seed=st.integers(0, 1000), pipelined=st.booleans())
def test_vtc_charge_matches_execution_under_apc_blocking_fuzzed(seed, pipelined):
    _vtc_apc_engine_run(seed, pipelined)


@pytest.mark.parametrize("pipelined", [False, True])
def test_vtc_charge_matches_execution_under_apc_blocking(pipelined):
    """Deterministic companion to the fuzzed version: runs even without
    hypothesis installed, covering both serve-loop modes."""
    _vtc_apc_engine_run(0, pipelined)


# ---------------------------------------------------------------------------
# weighted-share convergence under saturation
# ---------------------------------------------------------------------------


def test_weighted_share_convergence_two_tenants():
    cfg = SchedulerConfig(
        policy="aging", alpha=1.0, beta=-0.1, token_budget=256, max_seqs=48,
        fairness=fair_cfg(
            TenantSpec("a", weight=1.0), TenantSpec("b", weight=3.0),
            admission=False,
        ),
    )
    sched = ChunkedPrefillScheduler(cfg)
    reqs = []
    for _ in range(200):  # both queues saturated from t=0, pure prefill
        reqs.append(mk(200, tenant="a", gen=1))
        reqs.append(mk(200, tenant="b", gen=1))
    ServingSimulator(sched, CostModel(), max_rounds=150).run(reqs)
    vtc = sched.fairness.vtc
    sa, sb = vtc.actual_tokens("a"), vtc.actual_tokens("b")
    assert sa > 0 and sb > 0
    assert sb / sa == pytest.approx(3.0, rel=0.25)      # service follows weights
    # the virtual counters — what the queue equalizes — end up nearly equal
    va, vb = vtc.virtual_service("a"), vtc.virtual_service("b")
    assert abs(va - vb) / max(va, vb) < 0.1


def test_starvation_freedom_every_tenant_finishes():
    heavy = [TenantTraffic("hog", "heavy", rps=12.0)]
    lights = [TenantTraffic(f"t{i}", "light", rps=0.5) for i in range(3)]
    reqs = multi_tenant(heavy + lights, duration_s=10.0, seed=7)
    cfg = SchedulerConfig(
        policy="aging", alpha=1.0, beta=-0.1, token_budget=256, max_seqs=32,
        fairness=fair_cfg(admission=False),
    )
    res = run_policy(reqs, cfg)
    assert res.report.n_finished == len(reqs)
    for t in ("hog", "t0", "t1", "t2"):
        assert any(r.tenant == t and r.state == RequestState.FINISHED for r in reqs)


# ---------------------------------------------------------------------------
# token-bucket admission control
# ---------------------------------------------------------------------------


def _controller(rate=100.0, burst=500.0, policy="deprioritize", window=2.0):
    reg = TenantRegistry((
        TenantSpec("limited", rate_tokens_per_s=rate, burst_tokens=burst),
        TenantSpec("free"),
    ))
    return AdmissionController(reg, policy=policy, penalty_window_s=window)


def test_bucket_burst_admits_then_penalizes():
    adm = _controller()
    # burst of 500 covers 2 requests of cost 250 (200 prompt + 50 gen)
    r1 = adm.assess(mk(200, arrival=0.0, tenant="limited", gen=50))
    r2 = adm.assess(mk(200, arrival=0.0, tenant="limited", gen=50))
    assert r1.admitted and not r1.penalized
    assert r2.admitted and not r2.penalized
    # third request at t=0 exceeds the bucket -> penalty window opens
    r3 = adm.assess(mk(200, arrival=0.0, tenant="limited", gen=50))
    assert r3.admitted and r3.penalized and r3.deficit == pytest.approx(250.0)
    assert adm.is_penalized("limited", now=0.1)


def test_bucket_refills_over_time():
    adm = _controller(rate=100.0, burst=500.0)
    adm.assess(mk(450, arrival=0.0, tenant="limited", gen=50))  # drain bucket
    # 5 s later the bucket holds 500 again: a full-burst request is clean
    r = adm.assess(mk(450, arrival=5.0, tenant="limited", gen=50))
    assert r.admitted and not r.penalized


def test_penalty_expires():
    adm = _controller(rate=10.0, burst=100.0, window=2.0)
    r = adm.assess(mk(500, arrival=0.0, tenant="limited", gen=0))
    assert r.penalized and r.penalty_expires_at == pytest.approx(2.0)
    assert adm.is_penalized("limited", now=1.99)
    assert not adm.is_penalized("limited", now=2.01)


def test_reject_policy_refuses_over_quota():
    adm = _controller(rate=10.0, burst=100.0, policy="reject")
    ok = adm.assess(mk(50, arrival=0.0, tenant="limited", gen=10))
    bad = adm.assess(mk(500, arrival=0.0, tenant="limited", gen=0))
    assert ok.admitted
    assert not bad.admitted and not bad.penalized
    assert adm.stats.rejected == 1


def test_unlimited_tenant_never_penalized():
    adm = _controller()
    for i in range(50):
        d = adm.assess(mk(512, arrival=0.0, tenant="free", gen=512))
        assert d.admitted and not d.penalized


def test_queue_policy_delays_until_bucket_refills():
    adm = _controller(rate=100.0, burst=250.0, policy="queue")
    # first request (cost 250) spends the burst cleanly
    r1 = adm.assess(mk(200, arrival=0.0, tenant="limited", gen=50))
    assert r1.admitted and not r1.delayed
    # second is admitted but delayed until the bucket earns 250 tokens back
    r2 = adm.assess(mk(200, arrival=0.0, tenant="limited", gen=50))
    assert r2.admitted and r2.delayed
    assert r2.ready_at == pytest.approx(2.5)
    # third queues BEHIND the second (debts stack at the contracted rate)
    r3 = adm.assess(mk(200, arrival=0.0, tenant="limited", gen=50))
    assert r3.delayed and r3.ready_at == pytest.approx(5.0)
    assert adm.stats.queued == 2


def test_queue_policy_scheduler_parks_then_releases():
    cfg = SchedulerConfig(
        policy="fcfs", token_budget=256,
        fairness=fair_cfg(
            TenantSpec("limited", rate_tokens_per_s=100.0, burst_tokens=100.0),
            admission_policy="queue",
        ),
    )
    sched = ChunkedPrefillScheduler(cfg)
    assert sched.submit(mk(90, arrival=0.0, tenant="limited", gen=10))   # clean
    delayed = mk(90, arrival=0.0, tenant="limited", gen=10)
    assert sched.submit(delayed)                       # admitted, parked
    assert len(sched.queue) == 2                       # delayed counts as work
    assert sched.queue.delayed_count() == 1
    assert delayed in sched.queue
    # before ready_at the pen holds it: only the clean request pops
    b0 = sched.schedule(now=0.0)
    assert [r.req_id for r, _ in b0.prefill_chunks] != [delayed.req_id]
    sched.on_batch_done(b0, 0.01)
    # after the bucket refills (100 tokens @ 100 tok/s = 1 s) it is released
    b1 = sched.schedule(now=1.1)
    assert any(r.req_id == delayed.req_id for r, _ in b1.prefill_chunks)
    assert sched.queue.delayed_count() == 0


def test_queue_policy_simulator_drains_at_contracted_rate():
    from repro.engine.simulator import run_policy

    specs = fair_cfg(
        TenantSpec("t", rate_tokens_per_s=100.0, burst_tokens=200.0),
        admission_policy="queue",
    )
    reqs = [mk(80, arrival=0.0, tenant="t", gen=20) for _ in range(5)]
    res = run_policy(
        reqs, SchedulerConfig(policy="fcfs", token_budget=128, max_seqs=8,
                              fairness=specs),
    )
    assert res.report.n_finished == 5
    finishes = sorted(r.finish_time for r in res.requests)
    # burst covers 2 up-front; the rest drain ~1 s apart (cost 100 @ 100/s)
    gaps = np.diff(finishes[1:])
    assert all(0.8 < g < 1.3 for g in gaps), gaps


def test_scheduler_reject_policy_drops_request():
    cfg = SchedulerConfig(
        policy="fcfs", token_budget=256,
        fairness=fair_cfg(
            TenantSpec("limited", rate_tokens_per_s=10.0, burst_tokens=100.0),
            admission_policy="reject",
        ),
    )
    sched = ChunkedPrefillScheduler(cfg)
    assert sched.submit(mk(50, tenant="limited", gen=10))
    rejected = mk(500, tenant="limited", gen=0)
    assert not sched.submit(rejected)                 # over quota -> dropped
    assert len(sched.queue) == 1
    assert len(sched.fairness.rejected) == 1
    # rejected requests terminate (no serve-loop spin) but never count as
    # completed in latency metrics (finish_time stays None)
    assert rejected.state == RequestState.FINISHED
    assert rejected.finish_time is None


# ---------------------------------------------------------------------------
# fair queue ordering
# ---------------------------------------------------------------------------


def _fair_queue(admission=None):
    reg = TenantRegistry(())
    vtc = VirtualTokenCounter(reg)
    q = FairPrefillQueue(lambda: make_policy("fcfs"), vtc, admission=admission)
    return q, vtc


def test_fair_queue_pops_lowest_virtual_service():
    q, vtc = _fair_queue()
    q.add(mk(10, arrival=0.0, tenant="a"))
    q.add(mk(10, arrival=0.0, tenant="b"))
    vtc.charge("a", 1000, 0)                    # a is far ahead on service
    assert q.pop().tenant == "b"


def test_fair_queue_intra_tenant_policy_order():
    q, _ = _fair_queue()
    late = mk(10, arrival=5.0, tenant="a")
    early = mk(10, arrival=1.0, tenant="a")
    q.add(late)
    q.add(early)
    assert q.pop() is early                      # FCFS within the tenant


def test_fair_queue_penalized_tenant_served_last():
    reg = TenantRegistry((
        TenantSpec("hog", rate_tokens_per_s=10.0, burst_tokens=10.0),
    ))
    adm = AdmissionController(reg, penalty_window_s=100.0)
    vtc = VirtualTokenCounter(reg)
    q = FairPrefillQueue(lambda: make_policy("fcfs"), vtc, admission=adm)
    hog_req = mk(500, arrival=0.0, tenant="hog", gen=0)
    adm.assess(hog_req)                          # over quota -> penalized
    q.add(hog_req)
    q.add(mk(10, arrival=0.0, tenant="polite"))
    vtc.charge("polite", 10_000, 0)              # even with far MORE service...
    q.set_now(0.5)
    assert q.pop().tenant == "polite"            # ...unpenalized wins
    assert q.pop().tenant == "hog"               # hog still served eventually


def test_fair_queue_readd_does_not_relift():
    """A request bouncing back after a chunk must not trigger the idle-lift:
    the tenant was never idle."""
    q, vtc = _fair_queue()
    r = mk(100, arrival=0.0, tenant="a")
    q.add(r)
    vtc.charge("b", 1000, 0)
    q.add(mk(10, arrival=0.0, tenant="b"))
    popped = q.pop()                             # a (service 0 < b's 1000)
    assert popped is r
    q.add(r)                                     # deferred back, same round
    assert vtc.virtual_service("a") == 0.0       # no lift to b's floor


def test_fair_queue_mirrors_prefill_queue_interface():
    q, _ = _fair_queue()
    reqs = [mk(10, arrival=i, tenant=f"t{i % 2}") for i in range(4)]
    for r in reqs:
        q.add(r)
    assert len(q) == 4
    assert reqs[0] in q
    assert q.peek() is not None
    assert len(list(q.requests())) == 4
    q.remove(reqs[0])
    assert len(q) == 3
    drained = q.drain_sorted()
    assert len(drained) == 3 and q.pop() is None


# ---------------------------------------------------------------------------
# fairness=None leaves the paper's scheduler untouched
# ---------------------------------------------------------------------------


def test_fairness_none_uses_plain_queue():
    sched = ChunkedPrefillScheduler(SchedulerConfig(policy="aging", beta=-0.1))
    assert sched.fairness is None
    assert type(sched.queue) is PrefillQueue


def test_fairness_none_and_enabled_schedule_same_single_tenant_work():
    """With one tenant and no admission limits, the fair queue degenerates to
    the inner policy: both schedulers must finish the same workload."""
    cfg = dict(policy="aging", alpha=1.0, beta=-0.1, token_budget=128, max_seqs=16)
    base = run_policy(
        [mk(64, arrival=0.05 * i, gen=4) for i in range(30)],
        SchedulerConfig(**cfg),
    )
    fair = run_policy(
        [mk(64, arrival=0.05 * i, gen=4) for i in range(30)],
        SchedulerConfig(**cfg, fairness=fair_cfg(admission=False)),
    )
    assert base.report.n_finished == fair.report.n_finished == 30
    assert base.rounds == fair.rounds


# ---------------------------------------------------------------------------
# per-tenant metrics
# ---------------------------------------------------------------------------


def test_summarize_by_tenant_groups_and_normalizes():
    reqs = []
    for t, n in (("a", 3), ("b", 2)):
        for i in range(n):
            r = mk(100, arrival=0.0, tenant=t, gen=10)
            r.prefill_done = 100
            r.generated = 10
            r.state = RequestState.FINISHED
            r.first_token_time = 1.0
            r.prefill_end_time = 1.0
            r.finish_time = 2.0
            reqs.append(r)
    rep = summarize_by_tenant(reqs, weights={"a": 3.0, "b": 2.0})
    assert set(rep.per_tenant) == {"a", "b"}
    assert rep.service_tokens == {"a": 330.0, "b": 220.0}
    assert rep.normalized_service["a"] == pytest.approx(110.0)
    assert rep.normalized_service["b"] == pytest.approx(110.0)
    assert rep.jain == pytest.approx(1.0)
    assert rep.max_service_delta == pytest.approx(0.0)
    assert rep.per_tenant["a"].n_finished == 3


# ---------------------------------------------------------------------------
# multi-tenant workload generator
# ---------------------------------------------------------------------------


def test_multi_tenant_workload_shape():
    reqs = multi_tenant(duration_s=10.0, seed=0)
    arr = [r.arrival_time for r in reqs]
    assert arr == sorted(arr)
    assert all(0.0 <= a < 10.0 for a in arr)
    tenants = {r.tenant for r in reqs}
    assert tenants == {"heavy0", "light0", "light1", "light2", "light3"}
    heavy_toks = sum(r.prompt_len for r in reqs if r.tenant == "heavy0")
    light_toks = sum(r.prompt_len for r in reqs if r.tenant == "light0")
    assert heavy_toks > 5 * light_toks           # heavy dominates demand


def test_multi_tenant_bursty_clusters_arrivals():
    reqs = multi_tenant(
        [TenantTraffic("b", "bursty", rps=4.0, burst_period_s=5.0, burst_duty=0.2)],
        duration_s=20.0, seed=1,
    )
    assert len(reqs) > 10
    # arrivals cluster in an "on" window of 20% of each 5 s cycle (the window
    # phase is randomized per tenant, so locate it via the largest circular gap)
    pos = sorted(r.arrival_time % 5.0 for r in reqs)
    gaps = [b - a for a, b in zip(pos, pos[1:])] + [pos[0] + 5.0 - pos[-1]]
    on_window = 5.0 - max(gaps)
    assert on_window <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# tenant-aware multi-replica routing
# ---------------------------------------------------------------------------


def _fair_router(n_replicas=2):
    from repro.engine.router import Router, RouterConfig

    return Router(RouterConfig(
        scheduler=SchedulerConfig(
            policy="aging", alpha=1.0, beta=-0.1, token_budget=256, max_seqs=32,
            fairness=fair_cfg(admission=False),
        ),
    ), n_replicas=n_replicas)


def test_router_tenant_aware_completes_and_accounts():
    r = _fair_router()
    reqs = multi_tenant(duration_s=5.0, seed=11)
    r.run(reqs)
    fin = sum(1 for q in r.journal.values() if q.state == RequestState.FINISHED)
    assert fin == len(reqs)
    svc = r.tenant_service()
    assert set(svc) == {q.tenant for q in reqs}
    # aggregated VTC charges across replicas == tokens executed fleet-wide
    # plus the first output tokens riding prefill-completion rounds
    executed = sum(
        st.scheduler.stats.scheduled_prefill_tokens
        + st.scheduler.stats.scheduled_decode_tokens
        for st in r.replicas.values()
    )
    first_tokens = sum(
        1 for q in r.journal.values() if q.prefill_end_time is not None
    )
    assert sum(svc.values()) == executed + first_tokens
    rep = r.fairness_report()
    assert set(rep.per_tenant) == set(svc)


def test_router_failover_preserves_tenant_accounting():
    r = _fair_router(n_replicas=3)
    reqs = multi_tenant(duration_s=5.0, seed=12)
    r.run(reqs, fault_at={0.5: lambda rt: rt.kill_replica(0)})
    fin = sum(1 for q in r.journal.values() if q.state == RequestState.FINISHED)
    assert fin == len(reqs)
    # replayed requests keep their tenant tag: every tenant's service survives
    for t in {q.tenant for q in reqs}:
        assert r.tenant_service().get(t, 0) > 0


def test_router_spreads_tenant_across_replicas():
    r = _fair_router(n_replicas=2)
    for i in range(4):
        r.submit(mk(100, arrival=0.0, tenant="solo", gen=4))
    per_replica = [
        sum(1 for q in st.assigned.values() if q.tenant == "solo")
        for st in r.replicas.values()
    ]
    assert per_replica == [2, 2]          # not all on one replica


# ---------------------------------------------------------------------------
# engine idle-gap compression fix
# ---------------------------------------------------------------------------


def test_compress_idle_gap_preserves_inter_arrival_spacing():
    pending = [mk(10, arrival=a) for a in (5.0, 6.0, 9.5)]
    compress_idle_gap(pending, next_i=0, now=1.0)
    assert [r.arrival_time for r in pending] == pytest.approx([1.0, 2.0, 5.5])


def test_compress_idle_gap_partial_index():
    pending = [mk(10, arrival=a) for a in (0.0, 10.0, 12.0)]
    compress_idle_gap(pending, next_i=1, now=3.0)
    assert pending[0].arrival_time == 0.0        # already-admitted untouched
    assert [r.arrival_time for r in pending[1:]] == pytest.approx([3.0, 5.0])
