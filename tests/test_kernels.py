"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes kernel bodies in Python on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunked_prefill_attention import chunked_prefill_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fused_swiglu import fused_swiglu
from repro.models import layers as L

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# chunked prefill attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,hd,blk_q,blk_k",
    [
        (1, 64, 128, 4, 4, 32, 32, 64),      # MHA
        (2, 128, 256, 8, 2, 64, 64, 128),    # GQA g=4
        (1, 32, 96, 8, 1, 64, 32, 32),       # MQA
        (3, 64, 64, 4, 4, 128, 64, 64),      # hd=128, self only
        (2, 256, 256, 2, 2, 16, 128, 128),   # long chunk
    ],
)
def test_chunked_prefill_vs_oracle(rng, dtype, B, Sq, Skv, Hq, Hkv, hd, blk_q, blk_k):
    q = _rand(rng, (B, Sq, Hq, hd), dtype)
    k = _rand(rng, (B, Skv, Hkv, hd), dtype)
    v = _rand(rng, (B, Skv, Hkv, hd), dtype)
    # random prefix per batch row; kv valid = prefix + chunk
    q_off = jnp.asarray(rng.integers(0, Skv - Sq + 1, B), jnp.int32)
    kv_lens = q_off + Sq
    out = chunked_prefill_attention(
        q, k, v, kv_lens, q_off, block_q=blk_q, block_k=blk_k
    )
    want = ref.chunked_prefill_attention_ref(q, k, v, kv_lens, q_off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype],
    )


def test_chunked_prefill_zero_prefix_is_causal_self_attention(rng):
    """q_offset=0, kv == chunk itself: must equal plain causal attention."""
    B, S, H, hd = 2, 64, 4, 32
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    out = chunked_prefill_attention(
        q, q, q, jnp.full((B,), S, jnp.int32), jnp.zeros((B,), jnp.int32),
        block_q=32, block_k=32,
    )
    want = L.attention_naive(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,hd,S,blk",
    [
        (1, 4, 4, 32, 128, 64),
        (4, 8, 2, 64, 512, 128),
        (2, 8, 1, 128, 256, 256),
        (3, 16, 4, 64, 384, 128),
    ],
)
def test_decode_attention_vs_oracle(rng, dtype, B, Hq, Hkv, hd, S, blk):
    q = _rand(rng, (B, Hq, hd), dtype)
    k = _rand(rng, (B, S, Hkv, hd), dtype)
    v = _rand(rng, (B, S, Hkv, hd), dtype)
    lens = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=blk)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype],
    )


def test_decode_attention_len_one(rng):
    """Edge: cache holds exactly one token."""
    q = _rand(rng, (2, 4, 32), jnp.float32)
    k = _rand(rng, (2, 128, 4, 32), jnp.float32)
    v = _rand(rng, (2, 128, 4, 32), jnp.float32)
    lens = jnp.array([1, 1], jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=64)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# fused swiglu
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,D,F,bm,bf",
    [
        (64, 32, 128, 32, 64),
        (128, 96, 256, 64, 128),
        (256, 128, 512, 128, 256),
        (32, 64, 64, 32, 64),
    ],
)
def test_fused_swiglu_vs_oracle(rng, dtype, M, D, F, bm, bf):
    x = _rand(rng, (M, D), dtype)
    s = 0.1
    wg = _rand(rng, (D, F), dtype) * s
    wu = _rand(rng, (D, F), dtype) * s
    wd = _rand(rng, (F, D), dtype) * s
    out = fused_swiglu(x, wg, wu, wd, block_m=bm, block_f=bf)
    want = ref.fused_swiglu_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=5 * TOLS[dtype], rtol=5 * TOLS[dtype],
    )


# ---------------------------------------------------------------------------
# flash attention (jnp production path) vs naive oracle — all mask modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        dict(),                                           # causal self
        dict(causal=False),                               # bidirectional
        dict(sliding_window=16),                          # SWA
    ],
)
def test_flash_attention_modes(rng, kw):
    B, S, Hq, Hkv, hd = 2, 64, 8, 4, 32
    q = _rand(rng, (B, S, Hq, hd), jnp.float32)
    k = _rand(rng, (B, S, Hkv, hd), jnp.float32)
    v = _rand(rng, (B, S, Hkv, hd), jnp.float32)
    a = L.attention_naive(q, k, v, **kw)
    b = L.flash_attention(q, k, v, block_q=16, block_k=32, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_flash_attention_offset_and_lens(rng):
    B, Sq, Skv, H, hd = 2, 32, 128, 4, 32
    q = _rand(rng, (B, Sq, H, hd), jnp.float32)
    k = _rand(rng, (B, Skv, H, hd), jnp.float32)
    v = _rand(rng, (B, Skv, H, hd), jnp.float32)
    q_off = jnp.array([50, 3], jnp.int32)
    kv_lens = q_off + Sq
    a = L.attention_naive(q, k, v, q_offset=q_off, kv_lens=kv_lens)
    b = L.flash_attention(q, k, v, q_offset=q_off, kv_lens=kv_lens,
                          block_q=16, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_kernel_matches_flash_matches_naive(rng):
    """Triangle check: Pallas kernel == flash jnp == naive, same inputs."""
    B, Sq, Skv, H, hd = 2, 64, 128, 4, 64
    q = _rand(rng, (B, Sq, H, hd), jnp.float32)
    k = _rand(rng, (B, Skv, H, hd), jnp.float32)
    v = _rand(rng, (B, Skv, H, hd), jnp.float32)
    q_off = jnp.array([64, 10], jnp.int32)
    kv_lens = q_off + Sq
    kern = chunked_prefill_attention(q, k, v, kv_lens, q_off,
                                     block_q=32, block_k=64)
    flash = L.flash_attention(q, k, v, q_offset=q_off, kv_lens=kv_lens,
                              block_q=32, block_k=64)
    naive = L.attention_naive(q, k, v, q_offset=q_off, kv_lens=kv_lens)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(naive), atol=3e-5)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive), atol=3e-5)
