"""Disaggregated prefill/decode pools with cross-replica KV handoff.

The acceptance bar mirrors the swap-preemption suite: GREEDY OUTPUT
BIT-IDENTITY.  A 1-prefill + 1-decode fleet — every request's KV exported at
prefill completion through the host-side handoff store and resumed
decode-only on the other replica, with real KV-pressure preemptions racing
the handoffs — must produce exactly the tokens of the same workload on a
single unconstrained engine, in both KV layouts.  On top of that, the
property invariants: every live request's KV accounted in exactly one of
{source pool, handoff store, destination pool}; shared-VTC service balances
to tokens actually executed fleet-wide; a request killed mid-handoff (late
stop while its gather is in flight) leaks nothing anywhere.
"""
import pytest

from repro.configs import tiny_config
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.disagg import (
    DisaggConfig,
    HandoffCostConfig,
    HandoffCostModel,
    build_disagg,
    serve_disagg,
)
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import shared_prefix


def _two_wave(seed=5, n=12, new_tokens=10):
    """Same deterministic two-wave pressure generator as the swap suite:
    concurrency forces KV preemption on a small pool, with round structure
    independent of wall-clock timing so output comparisons are exact."""
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
    return reqs


def _serve_single(reqs, *, paged=True, pipelined=True, n_blocks=400):
    """Unconstrained single-engine reference (same weights: same seed)."""
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=paged, pipelined=pipelined,
                                      seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    return res


def _build_pressured(*, paged=True, pipelined=True, n_blocks=11,
                     n_prefill=1, n_decode=1, mode="swap", fairness=None,
                     cost=None, min_handoff_tokens=0, prefetch=True,
                     kv_layout="split", buffering_depth=1):
    cfg = tiny_config("qwen1.5-0.5b")
    return build_disagg(
        cfg,
        cfg=DisaggConfig(n_prefill=n_prefill, n_decode=n_decode,
                         min_handoff_tokens=min_handoff_tokens, cost=cost,
                         prefetch=prefetch),
        engine_cfg=EngineConfig(n_slots=6, max_context=128, paged_kv=paged,
                                pipelined=pipelined, preemption_mode=mode,
                                kv_layout=kv_layout if paged else "split",
                                buffering_depth=buffering_depth,
                                seed=3),
        sched_cfg=SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6,
                                  fairness=fairness),
        n_blocks=n_blocks, block_size=16,
    )


def _decode_prefill_tokens(router):
    return sum(rs.sched.stats.scheduled_prefill_tokens for rs in router.decode)


def _fleet_preemptions(router):
    return sum(rs.sched.stats.preemptions for rs in router.replicas)


# ---------------------------------------------------------------------------
# greedy parity: disaggregated vs single engine, handoffs racing preemption
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense"])
def test_disagg_outputs_identical_to_single_engine(paged):
    # decodes long enough to grow past block boundaries on a 9-block pool:
    # preemption fires on BOTH replicas, racing the in-flight handoffs
    reqs_d = _two_wave(new_tokens=24)
    router = _build_pressured(paged=paged, n_blocks=9)
    res_d = serve_disagg(reqs_d, router)
    reqs_r = _two_wave(new_tokens=24)
    res_r = _serve_single(reqs_r, paged=paged)

    assert res_d.report.n_finished == len(reqs_d)
    assert res_r.report.n_finished == len(reqs_r)
    # every request crossed the link exactly once, pressure actually bit on
    # the small pools (handoffs raced live preemptions), and the decode pool
    # never re-prefilled a single token
    assert res_d.handoffs == len(reqs_d) and res_d.colocated == 0
    assert _fleet_preemptions(router) > 0
    assert _decode_prefill_tokens(router) == 0
    assert any(t != 0 for out in res_d.outputs.values() for t in out)
    # req_ids are globally assigned: compare by workload POSITION
    for a, b in zip(reqs_d, reqs_r):
        assert res_d.outputs[a.req_id] == res_r.outputs[b.req_id]
    for r in reqs_d:
        assert r.handoffs == 1
    router.check_invariants()


def test_disagg_sync_engine_matches_pipelined():
    """The handoff path also runs under the synchronous round loop (the
    gather finalizes through explicit ``finalize_swaps`` steps instead of
    riding an in-flight drain)."""
    reqs_p = _two_wave()
    res_p = serve_disagg(reqs_p, _build_pressured(pipelined=True))
    reqs_s = _two_wave()
    router_s = _build_pressured(pipelined=False)
    res_s = serve_disagg(reqs_s, router_s)
    assert res_s.handoffs == len(reqs_s)
    assert _decode_prefill_tokens(router_s) == 0
    for a, b in zip(reqs_p, reqs_s):
        assert res_p.outputs[a.req_id] == res_s.outputs[b.req_id]


def test_disagg_prefetch_off_matches_prefetch_on():
    """Prefetch (adopting the record while the source gather is still in
    flight) is a pure latency optimization: outputs must be bit-identical to
    the wait-for-swap-ready path, and the counters must show the two paths
    actually diverged."""
    reqs_p = _two_wave()
    router_p = _build_pressured(prefetch=True)
    res_p = serve_disagg(reqs_p, router_p)
    reqs_w = _two_wave()
    router_w = _build_pressured(prefetch=False)
    res_w = serve_disagg(reqs_w, router_w)
    assert res_p.handoffs == res_w.handoffs == len(reqs_p)
    # pipelined: the gather drains one round late, so every prefetch-mode
    # adoption happens while the copy is still in flight
    assert router_p.store.stats.prefetched > 0
    assert router_w.store.stats.prefetched == 0
    for a, b in zip(reqs_p, reqs_w):
        assert res_p.outputs[a.req_id] == res_w.outputs[b.req_id]
    router_p.check_invariants()
    router_w.check_invariants()


@pytest.mark.parametrize("depth", [1, 2])
def test_disagg_fused_layout_outputs_identical(depth):
    """The fused head-interleaved pool rides the whole handoff path (gather,
    host staging, cross-pool import, scatter-restore) with single-tensor
    payloads; outputs must match the split layout bit-for-bit."""
    reqs_f = _two_wave()
    router_f = _build_pressured(kv_layout="fused", buffering_depth=depth)
    res_f = serve_disagg(reqs_f, router_f)
    reqs_s = _two_wave()
    res_s = serve_disagg(reqs_s, _build_pressured())
    assert res_f.handoffs == len(reqs_f)
    assert _decode_prefill_tokens(router_f) == 0
    for a, b in zip(reqs_f, reqs_s):
        assert res_f.outputs[a.req_id] == res_s.outputs[b.req_id]
    router_f.check_invariants()


def test_cost_model_colocates_everything_when_link_is_expensive():
    """With a prohibitively priced link every completion stays colocated:
    decode runs to completion on the prefill replica, nothing ever enters
    the store, outputs still match the reference."""
    reqs = _two_wave()
    router = _build_pressured(
        n_blocks=64,
        cost=HandoffCostConfig(link_fixed_ms=1e9, contention_ms_per_token=0.0),
    )
    res = serve_disagg(reqs, router)
    reqs_r = _two_wave()
    res_r = _serve_single(reqs_r)
    assert res.handoffs == 0
    assert res.colocated == len(reqs)
    assert res.report.n_finished == len(reqs)
    for a, b in zip(reqs, reqs_r):
        assert res.outputs[a.req_id] == res_r.outputs[b.req_id]
    router.check_invariants()


def test_cost_model_decision_boundaries():
    m = HandoffCostModel(HandoffCostConfig(), min_handoff_tokens=32)
    # under the floor: never moves, no matter how long the decode
    assert not m.should_handoff(16, 100_000, 4)
    # transfer dwarfs the contention of one remaining token
    assert not m.should_handoff(64, 1, 1 << 20)
    # long decode amortizes the transfer
    assert m.should_handoff(64, 10_000, 4)


# ---------------------------------------------------------------------------
# property: every live request's KV lives in exactly one place
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefetch", [True, False], ids=["prefetch", "wait"])
def test_kv_accounted_in_exactly_one_location_throughout(prefetch):
    """Drive the fleet sweep-by-sweep (the serve_disagg loop, instrumented):
    after every sweep each unfinished request's KV is accounted by AT MOST
    one location — a decoding request by exactly one — and at quiesce the
    store is empty and every pool's accounting balances.  Prefetch moves a
    still-SWAPPING record across pools; the invariant must hold through that
    window too."""
    import time as _time

    reqs = _two_wave(new_tokens=24)
    router = _build_pressured(n_blocks=9, prefetch=prefetch)
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    t_start = _time.perf_counter()
    for rs in router.replicas:
        rs.start(t_start)
    next_i = 0
    checks = 0
    from repro.engine.engine import compress_idle_gap

    for _ in range(200_000):
        now = _time.perf_counter() - t_start
        while next_i < len(pending) and pending[next_i].arrival_time <= now:
            router.submit(pending[next_i])
            next_i += 1
        statuses = [rs.step(now) for rs in router.replicas]
        moved = router.pump()
        for r in pending[:next_i]:
            if r.state == RequestState.FINISHED:
                continue
            n = router.kv_locations(r.req_id)
            assert n <= 1, f"req {r.req_id} KV in {n} places"
            if r.state == RequestState.DECODING or r.swapped:
                assert n == 1, f"req {r.req_id} ({r.state}) KV nowhere"
                checks += 1
        progress = moved > 0 or any(
            s in ("round", "drained", "finalized") for s in statuses)
        if (not progress and not router._pending
                and not any(rs.busy() for rs in router.replicas)):
            if next_i >= len(pending):
                break
            compress_idle_gap(pending, next_i, now)
    for rs in router.replicas:
        rs.finish()
    router.pump()

    assert checks > 0
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert len(router.store) == 0
    for r in reqs:
        assert router.kv_locations(r.req_id) == 0
    router.check_invariants()


# ---------------------------------------------------------------------------
# shared VTC: per-tenant service balances fleet-wide across the handoff
# ---------------------------------------------------------------------------


def test_shared_vtc_balances_across_handoff():
    from repro.tenancy import FairnessConfig, TenantSpec

    fairness = FairnessConfig(tenants=(
        TenantSpec(name="a", weight=1.0), TenantSpec(name="b", weight=1.0),
    ))
    reqs = _two_wave()
    for i, r in enumerate(reqs):
        r.tenant = "a" if i % 2 == 0 else "b"
    router = _build_pressured(fairness=fairness)
    res = serve_disagg(reqs, router)
    assert res.report.n_finished == len(reqs)
    assert res.handoffs == len(reqs)

    # one VirtualTokenCounter spans the whole fleet (anti-laundering): every
    # scheduler charges the same object
    vtcs = {id(rs.sched.fairness.vtc) for rs in router.replicas}
    assert len(vtcs) == 1
    vtc = router.replicas[0].sched.fairness.vtc

    # the balance: tokens charged == tokens executed fleet-wide, plus the
    # first output token riding each prefill-completion round.  A handoff
    # charges its prefill on the source replica and its decode on the
    # destination, both into the shared counter — never twice.
    executed = sum(
        rs.sched.stats.scheduled_prefill_tokens
        + rs.sched.stats.scheduled_decode_tokens
        for rs in router.replicas
    )
    first_tokens = sum(1 for r in reqs if r.prefill_end_time is not None)
    charged = sum(vtc.actual_tokens(t) for t in vtc.tenants())
    assert charged == executed + first_tokens
    router.check_invariants()


# ---------------------------------------------------------------------------
# killed mid-handoff: a late stop racing the gather leaks nothing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefetch", [True, False], ids=["prefetch", "wait"])
def test_killed_mid_handoff_leaks_nothing(prefetch):
    """A stop token equal to a request's FIRST output id kills it at the
    source drain — exactly the moment its export gather lands.  Without
    prefetch the record sits in the router's pending-handoff list; WITH
    prefetch it was already adopted by the decode pool, and the stop hook
    must chase it there and retract it.  Either way the staging record is
    discarded (never counted delivered), every pool balances, and all other
    requests' outputs match the no-stop reference truncated at their own
    first stop occurrence."""
    reqs_ref = _two_wave()
    res_ref = _serve_single(reqs_ref)
    stop = res_ref.outputs[reqs_ref[0].req_id][0]

    reqs = _two_wave()
    for r in reqs:
        r.stop_token = stop
    router = _build_pressured(prefetch=prefetch)
    res = serve_disagg(reqs, router)

    assert all(r.state == RequestState.FINISHED for r in reqs)
    # request 0 (at least) died with its gather in flight
    assert res.dropped_handoffs >= 1
    assert reqs[0].stopped and len(res.outputs[reqs[0].req_id]) == 1
    # the fleet-wide balance: every prefill completion either delivered,
    # dropped, or stayed colocated
    stats = router.store.stats
    assert stats.colocated == 0
    assert stats.delivered + res.dropped_handoffs == len(reqs)
    # outputs: reference truncated at each request's own first stop
    for a, b in zip(reqs, reqs_ref):
        ref = res_ref.outputs[b.req_id]
        want = ref[:ref.index(stop) + 1] if stop in ref else ref
        assert res.outputs[a.req_id] == want
        assert a.stopped == (stop in ref)
    assert len(router.store) == 0
    for r in reqs:
        assert router.kv_locations(r.req_id) == 0
    router.check_invariants()


# ---------------------------------------------------------------------------
# placement: KV locality dominates load
# ---------------------------------------------------------------------------


def test_placement_prefers_replica_with_resident_prefix():
    """After one request's handoff lands (and its blocks are released into
    the destination's prefix cache), a second request sharing its prompt
    must place onto that replica even when it is the more loaded one; an
    unrelated request follows load to the other replica."""
    reqs = shared_prefix(n_requests=1, n_prefixes=1, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=6,
                         inter_arrival_s=0.0, vocab_size=512, seed=9)
    router = _build_pressured(n_blocks=64, n_decode=2)
    res = serve_disagg(reqs, router)
    assert res.handoffs == 1
    # index tie-break sent the only handoff to decode replica 0, whose pool
    # now content-addresses the prompt's full blocks
    imports = [rs.kv_pool.stats.handoff_imports for rs in router.decode]
    assert imports == [1, 0]
    assert router.decode[0].kv_pool.probe_prefix(reqs[0].prompt_tokens) >= 48

    # load decode0 with queued work: pure load placement would now pick
    # decode1, locality must override it
    dummy = Request(prompt_len=64, max_new_tokens=32,
                    prompt_tokens=list(range(100, 164)))
    router.decode[0].submit(dummy)
    warm = Request(prompt_len=reqs[0].prompt_len, max_new_tokens=8,
                   prompt_tokens=list(reqs[0].prompt_tokens))
    assert router._place(warm) is router.decode[0]
    cold = Request(prompt_len=64, max_new_tokens=8,
                   prompt_tokens=list(range(300, 364)))
    assert router._place(cold) is router.decode[1]


# ---------------------------------------------------------------------------
# cache-aware aging credit
# ---------------------------------------------------------------------------


def test_cache_credit_orders_resident_kv_first():
    """Two equal-priority candidates: with ``cache_credit`` on, the one
    whose KV is already materialized on the pool ranks first; with it off,
    submission order wins."""

    class _Pool:
        def __init__(self):
            self.resident = {}

        def resident_tokens(self, req_id):
            return self.resident.get(req_id, 0)

    pool = _Pool()
    cold = Request(prompt_len=64, max_new_tokens=4, arrival_time=0.0)
    warm = Request(prompt_len=64, max_new_tokens=4, arrival_time=0.0)
    pool.resident[warm.req_id] = 64

    def order(credit):
        sched = ChunkedPrefillScheduler(
            SchedulerConfig(policy="aging", alpha=1.0, beta=-0.01,
                            cache_credit=credit),
            kv_pool=pool, kv_booking=False,
        )
        sched.submit(cold)
        sched.submit(warm)
        return sched.queue.pop()

    assert order(credit=0.5) is warm
    assert order(credit=0.0) is cold
