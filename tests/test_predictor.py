"""LPRS latency predictor (§3.2.1): training convergence, asymmetric-Huber
semantics, bucketing, persistence round-trip."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.features import derive_features
from repro.core.predictor import (
    AnalyticPredictor, LatencyPredictor, PredictorConfig,
    asymmetric_huber, bucket_and_downsample,
)


def synth_dataset(n=3000, seed=0):
    """Features + latencies from a noisy analytic model (stand-in GPU)."""
    rng = np.random.default_rng(seed)
    raw = np.zeros((n, 11))
    raw[:, 0] = rng.integers(0, 2048, n)            # prefill_tokens
    raw[:, 1] = rng.integers(0, 64, n)              # decode_tokens
    raw[:, 2] = raw[:, 1] + (raw[:, 0] > 0)
    raw[:, 3] = raw[:, 1] * rng.integers(10, 2000, n)
    raw[:, 4] = rng.integers(0, 4096, n)
    raw[:, 5] = rng.integers(0, 4096, n)
    feats = derive_features(raw)
    oracle = AnalyticPredictor(c0=2.0, c_prefill=0.05, c_decode=0.12, c_ctx=3e-5,
                               c_batch=0.06)
    y = oracle.predict(feats) * rng.lognormal(0, 0.02, n)
    return feats.astype(np.float64), y


def test_predictor_converges_to_low_mape():
    feats, y = synth_dataset()
    n_tr = 2400
    pred = LatencyPredictor(PredictorConfig(epochs=150, seed=1))
    pred.fit(feats[:n_tr], y[:n_tr])
    m = pred.evaluate(feats[n_tr:], y[n_tr:])
    # paper reports 1.26% MAPE on real data; noisy synthetic: be generous
    assert m["mape_pct"] < 10.0, m
    assert m["mae_ms"] < 5.0, m


def test_asymmetric_huber_penalizes_underestimation():
    y = jnp.asarray([100.0])
    under = asymmetric_huber(y, jnp.asarray([90.0]), 5.0, w_under=2.0, w_over=1.0)
    over = asymmetric_huber(y, jnp.asarray([110.0]), 5.0, w_under=2.0, w_over=1.0)
    assert float(under[0]) == pytest.approx(2 * float(over[0]))


def test_huber_is_quadratic_then_linear():
    y = jnp.zeros((1,))
    small = asymmetric_huber(y, jnp.asarray([1.0]), 5.0, 1.0, 1.0)
    assert float(small[0]) == pytest.approx(0.5)
    big = asymmetric_huber(y, jnp.asarray([100.0]), 5.0, 1.0, 1.0)
    assert float(big[0]) == pytest.approx(5 * (100 - 2.5))


def test_bucket_downsample_caps_overrepresented():
    st = np.concatenate([np.full(900, 1024.0), np.linspace(1, 512, 100)])
    keep, w = bucket_and_downsample(st, n_buckets=8, max_bucket_frac=0.25, seed=0)
    kept_full = (st[keep] == 1024.0).sum()
    assert kept_full <= 0.30 * len(st)
    assert len(w) == len(keep)
    assert w.min() > 0


def test_state_dict_roundtrip():
    feats, y = synth_dataset(400)
    p = LatencyPredictor(PredictorConfig(epochs=10))
    p.fit(feats, y)
    q = LatencyPredictor.from_state(p.state_dict())
    np.testing.assert_allclose(p.predict(feats[:16]), q.predict(feats[:16]),
                               rtol=1e-6)
