"""SLO serving-tier property suite (deadline-aware LPRS / urgency / victim
weighting / load shedding).

Three families of invariants, per the tier's contract:

  1. **off == absent** — an all-flags-off ``SLOConfig`` (and the default
     config over tenants with no SLOs) is bit-identical to ``slo=None``:
     same per-round batch composition, same chunk trace, same finish times.
  2. **deadline monotonicity** — tightening ONE tenant's ``ttft_slo_s``
     (queue-urgency only) never worsens that tenant's first-request TTFT.
  3. **attainment partition** — every terminal request lands in exactly one
     of {attained, violated, shed, rejected}; the buckets reconcile with
     the scheduler's shed counter and the admission stats, fuzzed over
     arrivals, KV-pressure preemption, and swap.

Pure-projection properties (feasible/urgent/victim_class consistency) run
under hypothesis when installed and as seeded deterministic fuzz otherwise.
"""
import random

import pytest
from _hyp import given, settings, st

from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.core.slo import (
    SLOConfig, SLOTracker, VICTIM_NO_SLO, VICTIM_PROTECTED, VICTIM_VIOLATING,
)
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.metrics import summarize_slo
from repro.engine.simulator import run_policy
from repro.engine.workload import TenantTraffic, multi_tenant
from repro.tenancy import FairnessConfig, TenantRegistry, TenantSpec

COST = CostModelConfig(c0_ms=20.0, c_prefill_ms=0.05, c_attn_ms=1e-6,
                       c_decode_ms=0.15, c_ctx_ms=1e-5, c_seq_ms=0.08,
                       noise_std=0.0)

SLO_OFF = SLOConfig(deadline_lprs=False, queue_urgency=False,
                    victim_weighting=False, apc_protect=False, shed=False)


def mk(prompt, arrival=0.0, tenant="default", gen=4):
    return Request(prompt_len=prompt, max_new_tokens=gen,
                   arrival_time=arrival, tenant=tenant)


def fuzz_requests(rng, *, tenants, n=40, t_span=3.0):
    reqs = [
        mk(rng.randint(16, 256), arrival=rng.uniform(0.0, t_span),
           tenant=rng.choice(tenants), gen=rng.randint(1, 8))
        for _ in range(n)
    ]
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs


def trace(reqs):
    """Everything the scheduler decided, per request: chunk sequence,
    completion wall times, tokens delivered."""
    return [
        (r.tenant, tuple(r.chunks), r.prefill_done, r.generated,
         r.first_token_time, r.finish_time, r.state)
        for r in reqs
    ]


# ---------------------------------------------------------------------------
# 1. off == absent (bit-identity)
# ---------------------------------------------------------------------------


SLO_TENANTS = (
    TenantSpec("a", weight=2.0, ttft_slo_s=0.5, e2e_slo_s=5.0),
    TenantSpec("b", ttft_slo_s=1.0),
    TenantSpec("c"),
)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_slo_all_flags_off_bit_identical(seed):
    """All feature flags off: the tracker is attached (admission gate and
    urgency hooks NOT installed, victim key unchanged) and the full trace —
    batch composition, chunk sizes, finish times — matches slo=None."""
    rng = random.Random(seed)
    arrivals = fuzz_requests(rng, tenants=["a", "b", "c"])

    def run(slo_cfg):
        reqs = [mk(r.prompt_len, r.arrival_time, r.tenant, r.max_new_tokens)
                for r in arrivals]
        res = run_policy(
            reqs,
            SchedulerConfig(policy="aging", alpha=1.0, beta=-0.1,
                            token_budget=128, max_seqs=8,
                            fairness=FairnessConfig(tenants=SLO_TENANTS),
                            slo=slo_cfg),
            cost_model=CostModel(COST),
        )
        return res, reqs

    base, base_reqs = run(None)
    off, off_reqs = run(SLO_OFF)
    assert trace(base_reqs) == trace(off_reqs)
    assert base.rounds == off.rounds
    # the off-run still REPORTS attainment (gauges are free), sheds nothing
    assert off.slo is not None and off.slo.shed == 0


def test_slo_defaults_noop_without_tenant_slos():
    """Default SLOConfig (all flags ON) over tenants with NO latency targets
    must also be bit-identical: every projection is (None, 0), so no gate,
    urgency, ranking change, or shed can fire."""
    no_slo = tuple(TenantSpec(s.name, weight=s.weight) for s in SLO_TENANTS)
    rng = random.Random(7)
    arrivals = fuzz_requests(rng, tenants=["a", "b", "c"])

    def run(slo_cfg):
        reqs = [mk(r.prompt_len, r.arrival_time, r.tenant, r.max_new_tokens)
                for r in arrivals]
        run_policy(
            reqs,
            SchedulerConfig(policy="fcfs", token_budget=128, max_seqs=8,
                            fairness=FairnessConfig(tenants=no_slo),
                            slo=slo_cfg),
            cost_model=CostModel(COST),
        )
        return reqs

    assert trace(run(None)) == trace(run(SLOConfig()))


def test_slo_requires_fairness():
    with pytest.raises(ValueError, match="requires fairness"):
        ChunkedPrefillScheduler(SchedulerConfig(slo=SLOConfig()))


# ---------------------------------------------------------------------------
# 2. deadline monotonicity (queue urgency)
# ---------------------------------------------------------------------------


URGENCY_ONLY = SLOConfig(deadline_lprs=False, victim_weighting=False,
                         apc_protect=False, shed=False, queue_urgency=True)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tightening_ttft_slo_never_worsens_first_request(seed):
    """Ladder a single tenant's ttft_slo_s down while everything else is
    fixed (urgency is the only active mechanism, no shedding, no KV pool):
    the tenant's FIRST request reaches its first token no later.  The traces
    are identical up to the round where urgency first differs, and in that
    round the tighter deadline pops the tenant no later — so TTFT of the
    head request is non-increasing in SLO tightness."""
    rng = random.Random(seed)
    arrivals = fuzz_requests(rng, tenants=["slo", "bulk", "bulk"], n=50)
    assert any(r.tenant == "slo" for r in arrivals)

    ttfts = []
    for slo_s in (30.0, 2.0, 0.8, 0.3):
        reqs = [mk(r.prompt_len, r.arrival_time, r.tenant, r.max_new_tokens)
                for r in arrivals]
        run_policy(
            reqs,
            SchedulerConfig(
                policy="fcfs", token_budget=128, max_seqs=8,
                fairness=FairnessConfig(tenants=(
                    TenantSpec("slo", ttft_slo_s=slo_s),
                    TenantSpec("bulk", weight=4.0),
                )),
                slo=URGENCY_ONLY,
            ),
            cost_model=CostModel(COST),
        )
        first = min((r for r in reqs if r.tenant == "slo"),
                    key=lambda r: (r.arrival_time, r.req_id))
        assert first.first_token_time is not None
        ttfts.append(first.first_token_time - first.arrival_time)

    for loose, tight in zip(ttfts, ttfts[1:]):
        assert tight <= loose + 1e-9, ttfts


# ---------------------------------------------------------------------------
# 3. attainment partition under preemption/swap/shedding
# ---------------------------------------------------------------------------


def _partition_run(seed, *, admission_policy="deprioritize", rate=0.0):
    specs = (
        TenantSpec("hot", ttft_slo_s=0.4, e2e_slo_s=6.0),
        TenantSpec("bulk", weight=4.0, ttft_slo_s=3.0,
                   rate_tokens_per_s=rate, burst_tokens=rate),
        TenantSpec("free"),
    )
    traffic = [
        TenantTraffic("hot", "light", rps=2.0, prompt_mean=96.0,
                      max_new_tokens=8),
        TenantTraffic("bulk", "bursty", rps=14.0, prompt_mean=192.0,
                      max_new_tokens=16, burst_period_s=3.0, burst_duty=0.3),
        TenantTraffic("free", "light", rps=1.0, prompt_mean=64.0,
                      max_new_tokens=8),
    ]
    reqs = multi_tenant(traffic, duration_s=6.0, seed=seed)
    pool = KVBlockPool(KVPoolConfig(n_blocks=96, block_size=16,
                                    bytes_per_token=4))
    cfg = SchedulerConfig(
        policy="aging", alpha=1.0, beta=-0.1, token_budget=192, max_seqs=12,
        fairness=FairnessConfig(tenants=specs,
                                admission_policy=admission_policy),
        slo=SLOConfig(),
    )
    sched = ChunkedPrefillScheduler(cfg, kv_pool=pool)
    from repro.engine.simulator import ServingSimulator

    res = ServingSimulator(sched, CostModel(COST), kv_pool=pool,
                           preemption_mode="swap").run(reqs)
    return res, reqs, sched


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_attainment_partition_fuzzed(seed):
    """attained + violated + shed + rejected == terminal requests, per
    tenant, under KV-pressure swap preemption and live shedding; every
    request is terminal at the end of the run; the report's shed total
    equals the scheduler's shed counter (admission + queue legs)."""
    res, reqs, sched = _partition_run(seed)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    rep = res.slo
    assert rep is not None
    for t, tr in rep.per_tenant.items():
        n_terminal = sum(1 for r in reqs if r.tenant == t)
        assert tr.attained + tr.violated + tr.shed + tr.rejected == n_terminal
        assert tr.finished == tr.attained + tr.violated
        assert tr.finished == sum(
            1 for r in reqs if r.tenant == t and r.finish_time is not None
        )
        assert 0.0 <= tr.attainment <= 1.0
    # bucket totals reconcile with the scheduler's own books
    assert rep.shed == sched.stats.sheds
    assert rep.shed == sum(
        1 for r in reqs if r.shed_reason is not None
    )
    adm = sched.fairness.admission
    # deprioritize never hard-rejects: the only refusals are SLO sheds
    assert rep.rejected == 0
    assert adm.stats.shed == len(sched.fairness.shed)
    # a shed request never has completion timestamps (it is not a violation)
    for r in reqs:
        if r.shed_reason is not None:
            assert r.finish_time is None and r.first_token_time is None


def test_attainment_partition_with_hard_rejects():
    """``reject`` admission on a rate-limited tenant: the rejected bucket
    fills from quota refusals, sheds from deadline refusals, and the
    partition still holds."""
    res, reqs, sched = _partition_run(9, admission_policy="reject",
                                      rate=800.0)
    rep = res.slo
    for t, tr in rep.per_tenant.items():
        n_terminal = sum(1 for r in reqs if r.tenant == t)
        assert tr.attained + tr.violated + tr.shed + tr.rejected == n_terminal
    assert rep.rejected == len(sched.fairness.rejected)
    assert rep.rejected > 0            # the quota actually bound
    assert rep.shed == sched.stats.sheds


def test_shed_request_refunds_pool_and_queue():
    """Direct-drive: shedding a queued, partially-prefilled request releases
    its KV blocks, removes it from the queue, and buckets it as shed."""
    pool = KVBlockPool(KVPoolConfig(n_blocks=32, block_size=16,
                                    bytes_per_token=4))
    cfg = SchedulerConfig(
        policy="fcfs", token_budget=64, max_seqs=4,
        fairness=FairnessConfig(tenants=(TenantSpec("t", ttft_slo_s=0.5),)),
        slo=SLO_OFF,       # shed manually below; no automatic gate
    )
    sched = ChunkedPrefillScheduler(cfg, kv_pool=pool)
    req = mk(200, tenant="t", gen=4)
    assert sched.submit(req)
    b = sched.schedule(0.0)            # partial chunk books blocks
    sched.on_batch_done(b, 0.05)
    assert req.prefill_done > 0 and req in sched.queue
    held = len(pool.tables.get(req.req_id, ()))
    assert held > 0

    sched.shed_request(req, reason="deadline")
    assert req.state == RequestState.FINISHED
    assert req.shed_reason == "deadline"
    assert req not in sched.queue
    assert not pool.tables.get(req.req_id)
    pool.check_invariants()
    assert sched.stats.sheds == 1
    rep = summarize_slo([req], sched.fairness.registry)
    assert rep.per_tenant["t"].shed == 1 and rep.violated == 0


# ---------------------------------------------------------------------------
# tracker projection properties (hypothesis when available, seeded otherwise)
# ---------------------------------------------------------------------------


def _tracker(ttft=0.5, e2e=None, **cfg_kw):
    reg = TenantRegistry((TenantSpec("t", ttft_slo_s=ttft, e2e_slo_s=e2e),
                          TenantSpec("free")))
    return SLOTracker(SLOConfig(**cfg_kw), reg, token_budget=128)


def _check_projection_consistency(tr, req, now):
    deadline, rounds = tr.projection(req)
    if deadline is None:
        assert tr.feasible(req, now)
        assert not tr.urgent(req, now)
        assert tr.victim_class(req, now) == VICTIM_NO_SLO
        return
    assert rounds >= 1
    required = tr.required_s(rounds)
    slack = tr.slack_s(req, now)
    assert slack == pytest.approx(deadline - now)
    # feasible <-> slack covers the minimum service time
    assert tr.feasible(req, now) == (slack >= required)
    # urgent is one-sided: infeasible or tight implies urgent
    if not tr.feasible(req, now):
        assert tr.urgent(req, now)
        assert tr.victim_class(req, now) == VICTIM_VIOLATING
    else:
        assert tr.victim_class(req, now) == VICTIM_PROTECTED
        if not tr.urgent(req, now):
            assert slack > required * tr.cfg.urgency_factor
    # feasibility is monotone in time: later never MORE feasible
    assert tr.feasible(req, now) or not tr.feasible(req, now + 1.0)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_projection_consistency_seeded(seed):
    rng = random.Random(seed)
    for _ in range(200):
        tr = _tracker(
            ttft=rng.choice([None, rng.uniform(0.05, 2.0)]),
            e2e=rng.choice([None, rng.uniform(0.5, 10.0)]),
            slack_safety=rng.uniform(0.5, 2.0),
            urgency_factor=rng.uniform(1.0, 4.0),
            round_ms_init=rng.uniform(5.0, 200.0),
        )
        req = mk(rng.randint(1, 512), arrival=rng.uniform(0.0, 5.0),
                 tenant=rng.choice(["t", "free"]), gen=rng.randint(1, 32))
        _check_projection_consistency(tr, req, rng.uniform(0.0, 8.0))


@settings(max_examples=150, deadline=None)
@given(
    prompt=st.integers(1, 512), gen=st.integers(1, 32),
    arrival=st.floats(0.0, 5.0), now=st.floats(0.0, 8.0),
    ttft=st.one_of(st.none(), st.floats(0.05, 2.0)),
    e2e=st.one_of(st.none(), st.floats(0.5, 10.0)),
    safety=st.floats(0.5, 2.0), factor=st.floats(1.0, 4.0),
)
def test_projection_consistency_hypothesis(prompt, gen, arrival, now, ttft,
                                           e2e, safety, factor):
    tr = _tracker(ttft=ttft, e2e=e2e, slack_safety=safety,
                  urgency_factor=factor)
    req = mk(prompt, arrival=arrival, tenant="t", gen=gen)
    _check_projection_consistency(tr, req, now)


def test_round_target_clamped_and_tightest_wins():
    tr = _tracker(ttft=0.5, min_target_ms=5.0)
    base = 200.0
    # no deadline-bearing requests: the static target survives untouched
    assert tr.round_target_ms([mk(64, tenant="free")], 0.0, base) == base
    # one tight deadline: slack/rounds wins over the static target
    tight = mk(64, arrival=0.0, tenant="t")
    deadline, rounds = tr.projection(tight)
    expect = (deadline - 0.3) * 1e3 / rounds
    assert tr.round_target_ms([tight], 0.3, base) == pytest.approx(
        min(base, max(expect, 5.0)))
    # an already-expired deadline clamps at the floor, never negative
    assert tr.round_target_ms([tight], 10.0, base) == 5.0


def test_ewma_round_cost_updates_only_when_busy():
    tr = _tracker(round_ms_init=50.0, round_ms_ewma=0.5)
    tr.begin_round(0.0, prev_busy=False)
    assert tr.round_ms == 50.0
    tr.begin_round(0.1, prev_busy=False)      # idle gap: not round cost
    assert tr.round_ms == 50.0
    tr.begin_round(0.2, prev_busy=True)       # 100 ms busy round observed
    assert tr.round_ms == pytest.approx(75.0)


def test_apc_protect_overrides_cap_for_urgent_request():
    """A deadline-urgent prefill bypasses the APC activity cap: with
    apc_protect on, the protected tenant's chunk lands in the round even
    when the cap would block any new prefill."""
    from repro.core.apc import APCConfig

    def run(apc_protect):
        reqs = [mk(400, arrival=0.0, tenant="bulk", gen=1) for _ in range(3)]
        hot = mk(96, arrival=0.05, tenant="hot", gen=1)
        reqs.append(hot)
        cfg = SchedulerConfig(
            policy="fcfs", token_budget=96, max_seqs=8,
            apc=APCConfig(c_max=1, l_min=64),
            fairness=FairnessConfig(tenants=(
                TenantSpec("bulk", weight=8.0),
                TenantSpec("hot", ttft_slo_s=0.2),
            )),
            slo=SLOConfig(deadline_lprs=False, victim_weighting=False,
                          shed=False, queue_urgency=True,
                          apc_protect=apc_protect),
        )
        run_policy(reqs, cfg, cost_model=CostModel(COST))
        return hot, [r for r in reqs if r is not hot]

    hot_on, _ = run(True)
    hot_off, _ = run(False)
    assert hot_on.first_token_time is not None
    assert hot_off.first_token_time is not None
    assert hot_on.first_token_time <= hot_off.first_token_time
