"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
same-family config and runs one forward/train step on CPU asserting shapes
and finiteness (spec deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, tiny_config
from repro.configs.base import applicable_shapes
from repro.models.model import build_model


def _batch_for(cfg, B=2, S=32, with_labels=True):
    b = {}
    if cfg.family == "encdec":
        b["frames"] = jnp.zeros((B, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16)
        b["tokens"] = jnp.ones((B, S), jnp.int32)
    elif cfg.family == "vlm":
        P = cfg.n_patch_tokens
        b["patch_embeds"] = jnp.zeros((B, P, cfg.d_model), jnp.bfloat16)
        b["tokens"] = jnp.ones((B, S - P), jnp.int32)
    else:
        b["tokens"] = jnp.ones((B, S), jnp.int32)
    if with_labels:
        b["labels"] = jnp.ones_like(b["tokens"])
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one grad step moves the loss
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, with_labels=False)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    lens = jnp.full((B,), S, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode(params, tok, cache, lens)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache pytree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128_256),
        "granite-34b": (88, 6144, 48, 1, 24_576, 49_152),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151_936),
        "mistral-large-123b": (88, 12_288, 96, 8, 28_672, 32_768),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24_576, 65_536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32_000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14_336, 32_000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14_336, 32_000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50_304),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.moe.d_ff if arch == "arctic-480b" else cfg.d_ff, cfg.vocab_size)
    assert got == spec


def test_moe_details():
    mix = get_config("mixtral-8x7b")
    assert mix.moe.n_experts == 8 and mix.moe.top_k == 2
    assert mix.sliding_window == 4096
    arc = get_config("arctic-480b")
    assert arc.moe.n_experts == 128 and arc.moe.top_k == 2
    assert arc.moe.dense_residual
    jam = get_config("jamba-1.5-large-398b")
    assert jam.moe.n_experts == 16 and jam.attn_every == 8


def test_qwen_has_qkv_bias():
    assert get_config("qwen1.5-0.5b").qkv_bias


def test_shape_skips_per_spec():
    """long_500k only for sub-quadratic archs; 33 live cells of 40."""
    total = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        shapes = {s.name for s in applicable_shapes(cfg)}
        total += len(shapes)
        if arch in ("jamba-1.5-large-398b", "mixtral-8x7b", "xlstm-1.3b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
    assert total == 33


def test_param_counts_in_band():
    """Analytic param counts should be near the nameplate sizes."""
    bands = {
        "llama3.2-1b": (0.9e9, 1.6e9),
        # the ASSIGNED dims (88L x 6144 x 24576) analytically give ~47B;
        # the assignment spec wins over the nameplate label
        "granite-34b": (40e9, 52e9),
        "qwen1.5-0.5b": (0.35e9, 0.7e9),
        "mistral-large-123b": (110e9, 130e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "arctic-480b": (420e9, 520e9),
        "mixtral-8x7b": (42e9, 50e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "xlstm-1.3b": (0.9e9, 1.8e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_sliding_window_ring_cache_decode():
    """Mixtral-family: decode past the window wraps the ring buffer."""
    cfg = tiny_config("mixtral-8x7b")
    assert cfg.sliding_window == 32
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 48   # prompt longer than the 32-token window
    logits, cache = model.prefill(params, {"tokens": jnp.ones((B, S), jnp.int32)})
    assert cache["k"].shape[2] == 32               # ring cache is W-sized
    lens = jnp.full((B,), S, jnp.int32)
    for i in range(4):                              # decode wraps the ring
        tok = jnp.full((B, 1), 5, jnp.int32)
        logits, cache = model.decode(params, tok, cache, lens + i)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
