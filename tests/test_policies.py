"""Unit + property tests for the paper's core: Aging policy (§3.1), the
heap's O(k log n) ordering equivalence (Eq. 3/4), FCFS/SJF baselines."""
import pytest
from _hyp import given, settings, st

from repro.core.policies import NaiveAgingQueue, aging_priority, make_policy
from repro.core.request import Request


def mk(prompt, arrival, gen=16):
    return Request(prompt_len=prompt, max_new_tokens=gen, arrival_time=arrival)


# ---------------------------------------------------------------------------
# ordering-key equivalence: Eq. 1 ranking == Eq. 4 static-key heap ranking
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(
    data=st.lists(
        st.tuples(
            st.integers(min_value=1, max_value=2000),   # prompt len
            st.floats(min_value=0, max_value=100, allow_nan=False),  # arrival
        ),
        min_size=1,
        max_size=40,
    ),
    alpha=st.floats(min_value=1e-3, max_value=100, allow_nan=False),
    beta=st.floats(min_value=-100, max_value=-1e-3, allow_nan=False),
    now=st.floats(min_value=100, max_value=200, allow_nan=False),
)
def test_heap_order_matches_eq1_priority(data, alpha, beta, now):
    """The time-independent key K_i = -alpha*a_i + beta*r_i must rank
    identically to P_i(n) = alpha*(t - a_i) + beta*r_i at any shared t
    (paper Eq. 3: the alpha*t term is rank-invariant)."""
    reqs = [mk(p, a) for p, a in data]
    heap = make_policy("aging", alpha=alpha, beta=beta)
    for r in reqs:
        heap.add(r)
    heap_order = [heap.pop().req_id for _ in range(len(reqs))]

    # ties (equal priority) may legitimately reorder; compare priorities
    pri = {r.req_id: aging_priority(r, now, alpha, beta) for r in reqs}
    heap_pris = [pri[i] for i in heap_order]
    assert heap_pris == sorted(heap_pris, reverse=True)


@settings(max_examples=100, deadline=None)
@given(
    data=st.lists(
        st.tuples(st.integers(1, 500), st.floats(0, 50, allow_nan=False)),
        min_size=1, max_size=25, unique_by=lambda t: t[1],
    )
)
def test_heap_matches_naive_recompute(data):
    """Heap implementation == the O(n) full-recompute reference."""
    alpha, beta = 1.0, -0.01
    reqs = [mk(p, a) for p, a in data]
    heap = make_policy("aging", alpha=alpha, beta=beta)
    naive = NaiveAgingQueue(alpha, beta)
    for r in reqs:
        heap.add(r)
        naive.add(r)
    while len(naive):
        a = heap.pop()
        b = naive.pop(now=123.0)
        pa = aging_priority(a, 123.0, alpha, beta)
        pb = aging_priority(b, 123.0, alpha, beta)
        assert pa == pytest.approx(pb)


def test_fcfs_is_arrival_order():
    q = make_policy("fcfs")
    reqs = [mk(100, t) for t in (5.0, 1.0, 3.0)]
    for r in reqs:
        q.add(r)
    out = [q.pop().arrival_time for _ in range(3)]
    assert out == [1.0, 3.0, 5.0]


def test_sjf_is_shortest_first():
    q = make_policy("sjf")
    reqs = [mk(p, 0.0) for p in (300, 10, 150)]
    for r in reqs:
        q.add(r)
    assert [q.pop().prompt_len for _ in range(3)] == [10, 150, 300]


def test_aging_update_after_chunk_raises_priority():
    """Eq. 2: receiving a chunk reduces remaining work -> higher key."""
    q = make_policy("aging", alpha=1.0, beta=-0.1)
    big = mk(1000, 0.0)
    small = mk(400, 0.0)
    q.add(big)
    q.add(small)
    assert q.peek() is small           # less remaining work wins
    big.receive_chunk(900)             # big now has only 100 left
    q.update(big)
    assert q.peek() is big


def test_aging_starvation_prevention():
    """A long request eventually overtakes a stream of fresh short ones."""
    alpha, beta = 1.0, -0.01
    long_req = mk(5000, arrival=0.0)
    # short request arriving at t: priority alpha*(t_now - t) + beta*50
    # long request at t_now=60: 60*1 - 50 = +10; fresh short: 0 - 0.5
    t_now = 60.0
    p_long = aging_priority(long_req, t_now, alpha, beta)
    fresh_short = mk(50, arrival=t_now)
    p_short = aging_priority(fresh_short, t_now, alpha, beta)
    assert p_long > p_short


def test_heap_remove_and_contains():
    q = make_policy("fcfs")
    a, b = mk(10, 0.0), mk(10, 1.0)
    q.add(a)
    q.add(b)
    assert a in q and b in q
    q.remove(a)
    assert a not in q
    assert q.pop() is b
    assert q.pop() is None


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 1000), min_size=1, max_size=30))
def test_heap_pop_is_total_and_unique(prompts):
    q = make_policy("aging", alpha=2.0, beta=-0.5)
    reqs = [mk(p, i * 0.1) for i, p in enumerate(prompts)]
    for r in reqs:
        q.add(r)
    seen = set()
    while True:
        r = q.pop()
        if r is None:
            break
        assert r.req_id not in seen
        seen.add(r.req_id)
    assert len(seen) == len(reqs)
