"""Paged-attention kernel parity: the block-table Pallas kernels (interpret
mode on CPU) and their gather oracles vs the DENSE reference on the same
logical K/V — across GQA group sizes, ragged ``kv_lens``, non-block-aligned
lengths, and permuted (non-contiguous) block tables — plus the engine-level
check that ``chunked_step_paged`` reproduces the dense ``chunked_step``
logits through a multi-round mixed schedule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.kernels import ref
from repro.kernels.paged_decode_attention import (
    paged_decode_attention,
    paged_decode_attention_fused,
)
from repro.kernels.paged_prefill_attention import (
    paged_prefill_attention,
    paged_prefill_attention_fused,
)
from repro.models.model import build_model

TOL_F32 = 1e-5
TOL_BF16 = 2e-2


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _paged_setup(rng, B, Hkv, hd, page_size, max_pages, dtype, permuted=True):
    """A physical page pool larger than needed, with per-sequence tables that
    scatter each sequence's pages non-contiguously across it."""
    n_pages = 2 * B * max_pages + 3
    k_pages = _rand(rng, (n_pages, page_size, Hkv, hd), dtype)
    v_pages = _rand(rng, (n_pages, page_size, Hkv, hd), dtype)
    ids = rng.permutation(n_pages - 1)[: B * max_pages] if permuted else \
        np.arange(B * max_pages)
    block_tables = jnp.asarray(ids.reshape(B, max_pages), jnp.int32)
    return k_pages, v_pages, block_tables


def _dense_view(pages, block_tables):
    """The logical per-sequence dense cache the tables describe."""
    return np.asarray(ref.gather_pages(pages, block_tables))


# ---------------------------------------------------------------------------
# paged flash-decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, TOL_F32), (jnp.bfloat16, TOL_BF16)])
@pytest.mark.parametrize(
    "B,Hq,Hkv,hd,page_size,max_pages",
    [
        (1, 4, 4, 32, 16, 8),      # MHA
        (3, 8, 2, 64, 16, 6),      # GQA g=4
        (2, 8, 1, 32, 32, 4),      # MQA, bigger page
        (4, 16, 4, 16, 16, 5),     # engine tiny-config head_dim
    ],
)
def test_paged_decode_vs_dense_reference(rng, dtype, tol, B, Hq, Hkv, hd,
                                         page_size, max_pages):
    q = _rand(rng, (B, Hq, hd), dtype)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, page_size, max_pages, dtype)
    # ragged, non-block-aligned valid lengths
    kv_lens = jnp.asarray(rng.integers(1, max_pages * page_size + 1, B), jnp.int32)

    out = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens)
    want = ref.decode_attention_ref(
        q, _dense_view(k_pages, bt), _dense_view(v_pages, bt), kv_lens
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_paged_decode_non_aligned_and_page_edges(rng):
    """Lengths straddling page boundaries: 1, ps-1, ps, ps+1, full."""
    B, Hq, Hkv, hd, ps, mp = 5, 4, 2, 32, 16, 4
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    kv_lens = jnp.asarray([1, ps - 1, ps, ps + 1, mp * ps], jnp.int32)
    out = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, bt, kv_lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


def test_paged_decode_layout_invariance(rng):
    """The same logical K/V under two different physical placements must give
    the same output — page indirection is pure data movement."""
    B, Hq, Hkv, hd, ps, mp = 2, 8, 2, 32, 16, 4
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    kv_lens = jnp.asarray([37, 61], jnp.int32)
    out1 = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens)

    # re-scatter the same logical pages to fresh physical ids
    n_pages = k_pages.shape[0]
    perm = np.asarray(rng.permutation(n_pages))
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n_pages)
    out2 = paged_decode_attention(
        q, k_pages[perm], v_pages[perm], jnp.asarray(inv)[bt], kv_lens
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("pages_per_tile", [1, 2, 4])
def test_paged_decode_pages_per_tile(rng, pages_per_tile):
    """Multi-page K/V tiles must be pure data movement: every tile width
    reproduces the gather oracle on ragged, NON-tile-aligned kv_lens, with
    max_pages not a multiple of the tile (exercises table padding)."""
    B, Hq, Hkv, hd, ps, mp = 5, 8, 2, 32, 16, 5     # 5 pages: pads for 2 and 4
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    # straddle page AND tile boundaries: 1, ps-1, one-past-tile, mid, full
    kv_lens = jnp.asarray(
        [1, ps - 1, pages_per_tile * ps + 1, 3 * ps + 7, mp * ps], jnp.int32
    )
    out = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens,
                                 pages_per_tile=pages_per_tile)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, bt, kv_lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


@pytest.mark.parametrize("pages_per_tile", [1, 2, 4])
def test_paged_prefill_pages_per_tile(rng, pages_per_tile):
    """Chunked-prefill parity for every tile width: causal offset + ragged
    non-aligned prefixes, max_pages not a multiple of the tile."""
    B, Sq, Hq, Hkv, hd, ps, mp = 3, 32, 8, 2, 32, 16, 5
    q = _rand(rng, (B, Sq, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    q_off = jnp.asarray([0, 7, mp * ps - Sq - 3], jnp.int32)   # non-aligned
    kv_lens = q_off + Sq
    out = paged_prefill_attention(q, k_pages, v_pages, bt, kv_lens, q_off,
                                  block_q=16, pages_per_tile=pages_per_tile)
    want = ref.paged_prefill_attention_ref(
        q, k_pages, v_pages, bt, kv_lens, q_off
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


@pytest.mark.parametrize("pages_per_tile", [2, 4])
def test_paged_decode_tile_width_invariance(rng, pages_per_tile):
    """Tile width is a pure schedule knob: wider tiles must agree with the
    single-page kernel bit-for-bit up to accumulation tolerance."""
    B, Hq, Hkv, hd, ps, mp = 2, 4, 4, 32, 16, 8
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    kv_lens = jnp.asarray([3 * ps + 5, mp * ps - 2], jnp.int32)
    a = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens,
                               pages_per_tile=1)
    b = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens,
                               pages_per_tile=pages_per_tile)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=TOL_F32, rtol=TOL_F32)


# ---------------------------------------------------------------------------
# double-buffered page DMA + fused head-interleaved layout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", [1, 2, 4])
@pytest.mark.parametrize("pages_per_tile", [1, 2])
def test_paged_decode_buffering_depth_invariance(rng, depth, pages_per_tile):
    """Buffering depth is a pure DMA-schedule knob: every depth must
    reproduce the gather oracle on ragged, non-tile-aligned kv_lens (a tail
    shorter than the prologue's lookahead included)."""
    B, Hq, Hkv, hd, ps, mp = 5, 8, 2, 32, 16, 5
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    kv_lens = jnp.asarray(
        [1, ps - 1, pages_per_tile * ps + 1, 3 * ps + 7, mp * ps], jnp.int32
    )
    out = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens,
                                 pages_per_tile=pages_per_tile,
                                 buffering_depth=depth)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, bt, kv_lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_paged_prefill_buffering_depth_invariance(rng, depth):
    """Same for the chunked-prefill kernel: causal offset + ragged prefixes
    under every DMA lookahead depth."""
    B, Sq, Hq, Hkv, hd, ps, mp = 3, 32, 8, 2, 32, 16, 5
    q = _rand(rng, (B, Sq, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    q_off = jnp.asarray([0, 7, mp * ps - Sq - 3], jnp.int32)
    kv_lens = q_off + Sq
    out = paged_prefill_attention(q, k_pages, v_pages, bt, kv_lens, q_off,
                                  block_q=16, buffering_depth=depth)
    want = ref.paged_prefill_attention_ref(
        q, k_pages, v_pages, bt, kv_lens, q_off
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


def test_fused_layout_roundtrip(rng):
    """fuse_pages interleaves K/V on the head axis; split_fused_pages must be
    its exact inverse (the layout is pure data movement)."""
    k = _rand(rng, (7, 16, 3, 32), jnp.float32)
    v = _rand(rng, (7, 16, 3, 32), jnp.float32)
    kv = ref.fuse_pages(k, v)
    assert kv.shape == (7, 16, 6, 32)
    k2, v2 = ref.split_fused_pages(kv)
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v))


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("pages_per_tile", [1, 2])
def test_paged_decode_fused_layout(rng, depth, pages_per_tile):
    """The fused head-interleaved kernel (one DMA per page feeding both K
    and V) must agree with the split kernel and with its own oracle."""
    B, Hq, Hkv, hd, ps, mp = 4, 8, 2, 32, 16, 5
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    kv_pages = ref.fuse_pages(k_pages, v_pages)
    kv_lens = jnp.asarray([1, ps - 1, 3 * ps + 7, mp * ps], jnp.int32)
    out = paged_decode_attention_fused(q, kv_pages, bt, kv_lens,
                                       pages_per_tile=pages_per_tile,
                                       buffering_depth=depth)
    split = paged_decode_attention(q, k_pages, v_pages, bt, kv_lens,
                                   pages_per_tile=pages_per_tile,
                                   buffering_depth=depth)
    want = ref.paged_decode_attention_fused_ref(q, kv_pages, bt, kv_lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(split), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


@pytest.mark.parametrize("depth", [1, 2])
def test_paged_prefill_fused_layout(rng, depth):
    B, Sq, Hq, Hkv, hd, ps, mp = 3, 32, 8, 2, 32, 16, 5
    q = _rand(rng, (B, Sq, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    kv_pages = ref.fuse_pages(k_pages, v_pages)
    q_off = jnp.asarray([0, 7, mp * ps - Sq - 3], jnp.int32)
    kv_lens = q_off + Sq
    out = paged_prefill_attention_fused(q, kv_pages, bt, kv_lens, q_off,
                                        block_q=16, buffering_depth=depth)
    split = paged_prefill_attention(q, k_pages, v_pages, bt, kv_lens, q_off,
                                    block_q=16, buffering_depth=depth)
    want = ref.paged_prefill_attention_fused_ref(q, kv_pages, bt, kv_lens, q_off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(split), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


# ---------------------------------------------------------------------------
# paged chunked-prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, TOL_F32), (jnp.bfloat16, TOL_BF16)])
@pytest.mark.parametrize(
    "B,Sq,Hq,Hkv,hd,page_size,max_pages,blk_q",
    [
        (1, 32, 4, 4, 32, 16, 6, 16),     # MHA
        (2, 64, 8, 2, 64, 16, 8, 32),     # GQA g=4
        (1, 16, 8, 1, 32, 32, 3, 16),     # MQA
        (3, 32, 16, 4, 16, 16, 4, 32),    # engine tiny-config head_dim
    ],
)
def test_paged_prefill_vs_dense_reference(rng, dtype, tol, B, Sq, Hq, Hkv, hd,
                                          page_size, max_pages, blk_q):
    q = _rand(rng, (B, Sq, Hq, hd), dtype)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, page_size, max_pages, dtype)
    # random (non-aligned) prefix per row; kv valid = prefix + chunk
    q_off = jnp.asarray(
        rng.integers(0, max_pages * page_size - Sq + 1, B), jnp.int32
    )
    kv_lens = q_off + Sq

    out = paged_prefill_attention(q, k_pages, v_pages, bt, kv_lens, q_off,
                                  block_q=blk_q)
    want = ref.chunked_prefill_attention_ref(
        q, _dense_view(k_pages, bt), _dense_view(v_pages, bt), kv_lens, q_off
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_paged_prefill_zero_prefix(rng):
    """q_offset=0, kv == the chunk itself scattered across pages: causal
    self-attention through the block table."""
    B, Sq, Hq, Hkv, hd, ps, mp = 2, 32, 4, 4, 32, 16, 2
    q = _rand(rng, (B, Sq, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    q_off = jnp.zeros((B,), jnp.int32)
    kv_lens = jnp.full((B,), Sq, jnp.int32)
    out = paged_prefill_attention(q, k_pages, v_pages, bt, kv_lens, q_off,
                                  block_q=16)
    want = ref.paged_prefill_attention_ref(q, k_pages, v_pages, bt, kv_lens, q_off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=TOL_F32, rtol=TOL_F32)


def test_paged_decode_equals_paged_prefill_single_token(rng):
    """A 1-token chunk through the prefill kernel must agree with the decode
    kernel — the engine dispatches between them by bucket size."""
    B, Hq, Hkv, hd, ps, mp = 3, 8, 2, 32, 16, 4
    q1 = _rand(rng, (B, 1, Hq, hd), jnp.float32)
    k_pages, v_pages, bt = _paged_setup(rng, B, Hkv, hd, ps, mp, jnp.float32)
    lens = jnp.asarray([5, 23, 64 - 1], jnp.int32)     # position of the token
    kv_lens = lens + 1
    a = paged_prefill_attention(q1, k_pages, v_pages, bt, kv_lens, lens,
                                block_q=1)
    b = paged_decode_attention(q1[:, 0], k_pages, v_pages, bt, kv_lens)
    np.testing.assert_allclose(np.asarray(a[:, 0]), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# engine step: paged vs dense chunked_step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True])
def test_chunked_step_paged_matches_dense(use_pallas):
    """Multi-round mixed schedule (prefill chunks + decode) through
    ``chunked_step_paged`` with a permuted block table must reproduce the
    dense ``chunked_step`` logits — the layout changes, the math must not."""
    cfg = tiny_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    impl = model.impl
    B, S, bs = 2, 64, 16
    hd = cfg.resolved_head_dim
    rng = np.random.default_rng(11)
    tokens_all = rng.integers(1, cfg.vocab_size, (B, S))

    dense = {
        "k": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    max_pages = S // bs
    n_phys = 2 * B * max_pages + 1          # slack so tables can be permuted
    paged = {
        "k": jnp.zeros((cfg.n_layers, n_phys, bs, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, n_phys, bs, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    ids = rng.permutation(n_phys - 1)[: B * max_pages]
    bt = jnp.asarray(ids.reshape(B, max_pages), jnp.int32)

    lens = jnp.zeros((B,), jnp.int32)
    # rounds: both prefill 16; slot0 decodes while slot1 prefills; both decode
    schedules = [
        (np.asarray([16, 16]), 16),
        (np.asarray([1, 16]), 16),
        (np.asarray([1, 1]), 1),
    ]
    pos = np.zeros((B,), int)
    for chunk_lens, C in schedules:
        toks = np.ones((B, C), np.int64)
        for b in range(B):
            c = chunk_lens[b]
            toks[b, :c] = tokens_all[b, pos[b] : pos[b] + c]
            pos[b] += c
        cl = jnp.asarray(chunk_lens, jnp.int32)
        ld, dense = impl.chunked_step(
            params, jnp.asarray(toks), dense, lens, cl, use_pallas=use_pallas
        )
        lp, paged = impl.chunked_step_paged(
            params, jnp.asarray(toks), paged, lens, cl, bt,
            use_pallas=use_pallas,
        )
        lens = lens + cl
        np.testing.assert_allclose(
            np.asarray(lp, np.float32), np.asarray(ld, np.float32),
            atol=2e-2, rtol=2e-2,       # bf16 cache, different gather order
        )
        assert (np.argmax(np.asarray(lp, np.float32), -1)
                == np.argmax(np.asarray(ld, np.float32), -1)).all()


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("depth", [1, 2])
def test_chunked_step_paged_fused_matches_split(use_pallas, depth):
    """The fused head-interleaved cache through the same multi-round mixed
    schedule must reproduce the split-layout logits EXACTLY (same dtype,
    same accumulation order — only the scatter/gather layout changes)."""
    cfg = tiny_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    impl = model.impl
    B, S, bs = 2, 64, 16
    hd = cfg.resolved_head_dim
    rng = np.random.default_rng(11)
    tokens_all = rng.integers(1, cfg.vocab_size, (B, S))

    max_pages = S // bs
    n_phys = 2 * B * max_pages + 1
    split = {
        "k": jnp.zeros((cfg.n_layers, n_phys, bs, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, n_phys, bs, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    fused = {
        "kv": jnp.zeros((cfg.n_layers, n_phys, bs, 2 * cfg.n_kv_heads, hd),
                        jnp.bfloat16),
    }
    ids = rng.permutation(n_phys - 1)[: B * max_pages]
    bt = jnp.asarray(ids.reshape(B, max_pages), jnp.int32)

    lens = jnp.zeros((B,), jnp.int32)
    schedules = [
        (np.asarray([16, 16]), 16),
        (np.asarray([1, 16]), 16),
        (np.asarray([1, 1]), 1),
    ]
    pos = np.zeros((B,), int)
    for chunk_lens, C in schedules:
        toks = np.ones((B, C), np.int64)
        for b in range(B):
            c = chunk_lens[b]
            toks[b, :c] = tokens_all[b, pos[b] : pos[b] + c]
            pos[b] += c
        cl = jnp.asarray(chunk_lens, jnp.int32)
        ls, split = impl.chunked_step_paged(
            params, jnp.asarray(toks), split, lens, cl, bt,
            use_pallas=use_pallas,
        )
        lf, fused = impl.chunked_step_paged(
            params, jnp.asarray(toks), fused, lens, cl, bt,
            use_pallas=use_pallas, kv_layout="fused", buffering_depth=depth,
        )
        lens = lens + cl
        np.testing.assert_allclose(
            np.asarray(lf, np.float32), np.asarray(ls, np.float32),
            atol=2e-5, rtol=2e-5,
        )
        assert (np.argmax(np.asarray(lf, np.float32), -1)
                == np.argmax(np.asarray(ls, np.float32), -1)).all()
        # the fused pool holds exactly the split pool's content, interleaved
        # on the head axis (even heads = K, odd heads = V)
        kv = np.asarray(fused["kv"], np.float32)
        np.testing.assert_array_equal(
            kv[:, :, :, 0::2], np.asarray(split["k"], np.float32))
        np.testing.assert_array_equal(
            kv[:, :, :, 1::2], np.asarray(split["v"], np.float32))
