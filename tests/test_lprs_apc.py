"""Unit + property tests for LPRS (§3.2, Algorithm 1) and APC (§3.3,
Eqs. 12-14)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.apc import APCConfig, APCStats, activity_cap, apply as apc_apply
from repro.core.apc import min_effective_progress
from repro.core.features import BatchState, N_FEATURES
from repro.core.lprs import LPRSConfig, candidate_set, score, select_chunk
from repro.core.predictor import AnalyticPredictor


# ---------------------------------------------------------------------------
# Eq. 8 candidate set
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(h=st.integers(-5, 5000), delta=st.integers(1, 700))
def test_candidate_set_properties(h, delta):
    c = candidate_set(h, delta)
    if h < 1:
        assert len(c) == 0
        return
    assert 1 in c and h in c                      # {1, h_i} always included
    assert all(1 <= x <= h for x in c)            # within bounds
    assert list(c) == sorted(set(c))              # sorted, unique
    for x in c:
        assert x == 1 or x == h or x % delta == 0  # only {1, h, k*delta}
    # every multiple of delta <= h present
    for k in range(1, h // delta + 1):
        assert k * delta in c


# ---------------------------------------------------------------------------
# Eq. 10 asymmetric scoring
# ---------------------------------------------------------------------------


def test_score_asymmetry_penalizes_overflow():
    s_under = score(np.array([90.0]), 100.0, lam_u=1.0, lam_o=3.0)[0]
    s_over = score(np.array([110.0]), 100.0, lam_u=1.0, lam_o=3.0)[0]
    assert s_over == pytest.approx(30.0)
    assert s_under == pytest.approx(10.0)
    assert s_over > s_under                       # same 10ms deviation


def test_select_chunk_hits_target():
    """With a linear predictor, the chosen chunk should approach the target
    latency from below (lambda_o > lambda_u makes overflow costly)."""
    pred = AnalyticPredictor(c0=2.0, c_prefill=0.1, c_decode=0.0, c_ctx=0.0, c_batch=0.0)
    cfg = LPRSConfig(target_latency_ms=50.0, search_delta=16, lambda_under=1.0, lambda_over=3.0)
    st_ = BatchState()
    c = select_chunk(
        remaining=4096, committed=0, token_budget=2048, batch_state=st_,
        processed=0, predictor=pred, cfg=cfg,
    )
    # latency = 2 + 0.1*(c) -> target 50ms at c=480; candidates step 16
    assert 1 <= c <= 2048
    pred_ms = 2.0 + 0.1 * c
    assert pred_ms <= 50.0 + 1e-9                 # never overflow when avoidable
    assert pred_ms > 50.0 - 0.1 * 16 - 1e-9       # …but as close as the grid allows


def test_select_chunk_respects_hard_budget():
    pred = AnalyticPredictor(c0=0.0, c_prefill=0.001)
    cfg = LPRSConfig(target_latency_ms=1e9, search_delta=64)  # target unreachable
    c = select_chunk(
        remaining=10_000, committed=1000, token_budget=1024 + 1000,
        batch_state=BatchState(), processed=0, predictor=pred, cfg=cfg,
    )
    assert c <= 1024                              # h_i = B_max - U_t


def test_select_chunk_warm_start_line_24():
    """Empty batch + all candidates overflowing -> returns 1 (Alg. 1 l.23-26)."""
    pred = AnalyticPredictor(c0=1000.0)           # everything over target
    cfg = LPRSConfig(target_latency_ms=1.0, search_delta=128,
                     lambda_under=1.0, lambda_over=1000.0)
    c = select_chunk(
        remaining=512, committed=0, token_budget=1024,
        batch_state=BatchState(), processed=0, predictor=pred, cfg=cfg,
    )
    assert c >= 1                                 # starvation guard


def test_skip_when_budget_exhausted():
    pred = AnalyticPredictor()
    cfg = LPRSConfig()
    c = select_chunk(
        remaining=100, committed=1024, token_budget=1024,
        batch_state=BatchState(), processed=0, predictor=pred, cfg=cfg,
    )
    assert c == 0


# ---------------------------------------------------------------------------
# derived features (§3.2.1 Table 2)
# ---------------------------------------------------------------------------


def test_derived_features_definitions():
    st_ = BatchState(
        prefill_tokens=100, decode_tokens=8, batch_request_count=9,
        sum_decode_context_len=4000, max_decode_context_len=900,
        prefill_processed_tokens=300, max_prefill_processed_tokens=200,
    )
    f = st_.features()
    assert f.shape == (N_FEATURES,)
    assert f[11] == 1.0                              # bias
    assert f[12] == 108.0                            # scheduled = dec + pf
    assert f[13] == pytest.approx(4000 / 8)          # avg_decode_ctx
    assert f[14] == pytest.approx(8 * 500)           # decode_ctx_interaction
    assert f[15] == pytest.approx(100 * 300)         # prefill_interaction


def test_with_extra_prefill_is_candidate_state():
    base = BatchState(prefill_tokens=10, decode_tokens=4, batch_request_count=4)
    cand = base.with_extra_prefill(64, processed=128)
    assert cand.prefill_tokens == 74
    assert cand.batch_request_count == 5
    assert cand.prefill_processed_tokens == 128
    assert base.prefill_tokens == 10                 # immutable


# ---------------------------------------------------------------------------
# APC: Eq. 12 cap, Eq. 13 min progress, Eq. 14 decision rule
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(
    n_decode=st.integers(0, 256), max_seqs=st.integers(1, 512),
    budget=st.integers(0, 8192), committed=st.integers(0, 8192),
    c_max=st.integers(1, 64), l_min=st.integers(1, 512),
)
def test_activity_cap_eq12(n_decode, max_seqs, budget, committed, c_max, l_min):
    cfg = APCConfig(c_max=c_max, l_min=l_min)
    cap = activity_cap(cfg, n_decode=n_decode, max_seqs=max_seqs,
                       token_budget=budget, committed=committed)
    # Eq. 12, clamped to 0: a decode-saturated or over-committed round yields
    # "no new prefills", never a negative count.
    assert cap == max(0, min(c_max, max_seqs - n_decode,
                             (budget - committed) // l_min))
    assert cap >= 0


def test_activity_cap_negative_clamps_to_zero():
    """Regression: decode count above max_seqs (or committed above budget)
    used to produce a NEGATIVE cap, which apply() then compared against
    n_active_prefills with nonsense results."""
    cfg = APCConfig(c_max=4, l_min=64)
    assert activity_cap(cfg, n_decode=12, max_seqs=8,
                        token_budget=1024, committed=0) == 0
    assert activity_cap(cfg, n_decode=0, max_seqs=8,
                        token_budget=256, committed=1024) == 0


def test_apc_apply_with_clamped_zero_cap_blocks_not_crashes():
    """A negative-cap round (clamped to 0) must BLOCK new prefills cleanly:
    apply() returns 0 and counts blocked_by_cap, no exception."""
    cfg = APCConfig(c_max=4, l_min=64)
    cap = activity_cap(cfg, n_decode=12, max_seqs=8,
                       token_budget=1024, committed=0)
    assert cap == 0
    stats = APCStats()
    c = apc_apply(cfg, stats, proposed=128, remaining=512, upper_bound=256,
                  n_active_prefills=0, cap=cap)
    assert c == 0
    assert stats.blocked_by_cap == 1


@settings(max_examples=200, deadline=None)
@given(remaining=st.integers(1, 4096), l_min=st.integers(1, 512))
def test_min_effective_progress_eq13(remaining, l_min):
    assert min_effective_progress(APCConfig(l_min=l_min), remaining) == min(
        remaining, l_min
    )


def test_apc_accepts_good_chunk():
    stats = APCStats()
    c = apc_apply(APCConfig(c_max=4, l_min=64), stats, proposed=128,
                  remaining=512, upper_bound=256, n_active_prefills=1, cap=4)
    assert c == 128
    assert stats.blocked_by_cap == 0 and stats.blocked_by_min_chunk == 0


def test_apc_blocks_fragmented_chunk():
    """micro-progress (1-token chunks) blocked when other prefills active."""
    stats = APCStats()
    c = apc_apply(APCConfig(c_max=4, l_min=64), stats, proposed=3,
                  remaining=512, upper_bound=256, n_active_prefills=2, cap=4)
    assert c == 0
    assert stats.blocked_by_min_chunk == 1


def test_apc_blocks_over_cap():
    stats = APCStats()
    c = apc_apply(APCConfig(c_max=2, l_min=64), stats, proposed=128,
                  remaining=512, upper_bound=256, n_active_prefills=2, cap=2)
    assert c == 0
    assert stats.blocked_by_cap == 1


def test_apc_warm_start_when_no_active_prefill():
    """Eq. 14 middle case: c* < m_i but batch has zero prefills."""
    stats = APCStats()
    c = apc_apply(APCConfig(c_max=4, l_min=64), stats, proposed=2,
                  remaining=512, upper_bound=40, n_active_prefills=0, cap=4)
    assert c == min(40, 64)                       # min(h_i, m_i)
    assert stats.warm_starts == 1


def test_apc_tail_chunk_smaller_than_lmin_allowed():
    """A request whose ENTIRE remainder < L_min may finish (m_i = r_i)."""
    stats = APCStats()
    c = apc_apply(APCConfig(c_max=4, l_min=64), stats, proposed=20,
                  remaining=20, upper_bound=20, n_active_prefills=0, cap=4)
    assert c == 20
