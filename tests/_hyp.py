"""Optional-hypothesis shim: property tests degrade to skips, deterministic
tests in the same module still run.

Usage (instead of ``from hypothesis import given, settings, strategies``)::

    from _hyp import given, settings, st

With hypothesis installed these are the real objects; without it, ``given``
marks the test skipped and ``st``/``settings`` become inert decoration-time
stand-ins, so module import — and every non-property test — succeeds.
"""
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Builds inert placeholders for any strategy expression."""

        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _StrategyStub()
    HealthCheck = ()          # list(HealthCheck) -> no checks to suppress
