"""KV memory subsystem: refcounted block pool, prefix cache, quotas,
chunk-granular booking, and preemption.

Property tests (via the ``_hyp`` shim) drive random operation sequences and
assert the pool's conservation invariants; deterministic tests pin the
specific lifecycle behaviors the scheduler relies on.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.kv_cache import (
    BlockState, KVBlockPool, KVPoolConfig, KVQuotaExceeded, pool_for_model,
)
from repro.engine.simulator import run_policy
from repro.engine.workload import shared_prefix


def mk_pool(n_blocks=32, block_size=16, cache=False):
    return KVBlockPool(KVPoolConfig(
        n_blocks=n_blocks, block_size=block_size, bytes_per_token=4,
        enable_prefix_cache=cache,
    ))


# ---------------------------------------------------------------------------
# refcounting and conservation
# ---------------------------------------------------------------------------


def test_release_is_idempotent():
    pool = mk_pool()
    pool.allocate(1, 100)
    pool.release(1)
    pool.release(1)                      # second release: no-op, no underflow
    assert pool.used_blocks == 0
    pool.check_invariants()


def test_shared_block_freed_only_at_last_reference():
    pool = mk_pool(cache=True)
    toks = list(range(32))
    pool.register_request(1, prompt_tokens=toks, prompt_len=33)
    pool.allocate(1, 33)
    pool.release(1)                      # both full blocks parked in the cache
    assert pool.cached_blocks == 2

    pool.register_request(2, prompt_tokens=toks, prompt_len=33)
    pool.register_request(3, prompt_tokens=toks, prompt_len=33)
    assert pool.match_prefix(2) == 32
    assert pool.match_prefix(3) == 32
    shared = pool.tables[2][0]
    assert pool.tables[3][0] == shared   # same physical block
    pool.release(2)
    assert shared not in pool.free_blocks  # req 3 still holds it
    pool.check_invariants()
    pool.release(3)
    assert pool.cached_blocks == 2       # back to evictable, not free
    pool.check_invariants()


def test_prefix_hit_returns_identical_block_ids():
    pool = mk_pool(cache=True)
    toks = list(range(48))
    pool.register_request(1, prompt_tokens=toks, prompt_len=48)
    pool.allocate(1, 48)
    original = list(pool.tables[1][:2])  # full blocks (3rd is the uncacheable tail)
    pool.release(1)
    pool.register_request(2, prompt_tokens=toks, prompt_len=48)
    assert pool.match_prefix(2) == 32    # never covers the whole prompt
    assert pool.tables[2] == original


def test_match_never_covers_whole_prompt():
    """Even a perfectly block-aligned fully-cached prompt keeps >= 1 token of
    prefill (the final token's logits start decoding)."""
    pool = mk_pool(cache=True)
    toks = list(range(32))               # exactly 2 blocks
    pool.register_request(1, prompt_tokens=toks, prompt_len=32)
    pool.allocate(1, 32)
    pool.release(1)
    pool.register_request(2, prompt_tokens=toks, prompt_len=32)
    assert pool.match_prefix(2) == 16    # only the first block


def test_chained_hash_distinguishes_same_block_different_prefix():
    pool = mk_pool(cache=True)
    a = list(range(32))
    b = list(range(100, 116)) + list(range(16, 32))  # same 2nd block tokens
    pool.register_request(1, prompt_tokens=a, prompt_len=33)
    pool.allocate(1, 33)
    pool.release(1)
    pool.register_request(2, prompt_tokens=b, prompt_len=33)
    assert pool.match_prefix(2) == 0     # first block differs -> chain breaks


def test_lru_eviction_reclaims_oldest_cached_block():
    pool = mk_pool(n_blocks=4, cache=True)
    for rid, base in ((1, 0), (2, 1000)):
        toks = list(range(base, base + 16))
        pool.register_request(rid, prompt_tokens=toks, prompt_len=17)
        pool.allocate(rid, 17)           # 2 blocks each (16 + 1 tail)
        pool.release(rid)                # full block cached, tail freed
    assert pool.cached_blocks == 2
    # allocating 3 blocks must evict the LRU cached block (req 1's)
    pool.allocate(9, 48)
    assert pool.stats.evictions >= 1
    pool.register_request(10, prompt_tokens=list(range(16)), prompt_len=17)
    assert pool.match_prefix(10) == 0    # req 1's block is gone
    pool.check_invariants()


def test_exhaustion_still_raises():
    pool = KVBlockPool(KVPoolConfig(n_blocks=2, block_size=16))
    with pytest.raises(MemoryError):
        pool.allocate(1, 100)


# ---------------------------------------------------------------------------
# per-tenant quotas
# ---------------------------------------------------------------------------


def test_quota_blocks_allocation_not_pool_space():
    pool = mk_pool(n_blocks=32)
    pool.set_tenant_quota("t", 4)
    pool.allocate(1, 64, tenant="t")     # exactly 4 blocks
    assert not pool.can_allocate(2, 16, tenant="t")
    assert pool.quota_blocked(2, 16, tenant="t")
    assert pool.can_allocate(3, 16, tenant="other")
    with pytest.raises(KVQuotaExceeded):
        pool.allocate(2, 16, tenant="t")
    pool.release(1)
    assert pool.can_allocate(2, 16, tenant="t")
    pool.check_invariants()


def test_quota_charged_on_prefix_match_and_refunded_on_release():
    pool = mk_pool(cache=True)
    toks = list(range(48))
    pool.register_request(1, tenant="t", prompt_tokens=toks, prompt_len=48)
    pool.allocate(1, 48, tenant="t")
    assert pool.tenant_used_blocks("t") == 3
    pool.release(1)
    assert pool.tenant_used_blocks("t") == 0
    pool.register_request(2, tenant="t", prompt_tokens=toks, prompt_len=48)
    pool.match_prefix(2)
    assert pool.tenant_used_blocks("t") == 2   # matched blocks pin quota too
    pool.check_invariants()


def test_max_new_tokens_respects_quota_and_slack():
    pool = mk_pool(n_blocks=32, block_size=16)
    pool.set_tenant_quota("t", 3)
    pool.allocate(1, 10, tenant="t")     # 1 block, 6 tokens slack
    assert pool.max_new_tokens(1, tenant="t") == 6 + 2 * 16


# ---------------------------------------------------------------------------
# scheduler integration: chunk-granular booking + preemption
# ---------------------------------------------------------------------------


def _drain(sched, max_rounds=500):
    now = 0.0
    rounds = 0
    while sched.has_work() and rounds < max_rounds:
        batch = sched.schedule(now)
        now += 0.01
        rounds += 1
        if batch.is_empty():
            continue
        sched.on_batch_done(batch, now)
    return rounds


def test_scheduler_books_exactly_what_it_schedules():
    pool = mk_pool(n_blocks=64)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=32, max_seqs=4), kv_pool=pool
    )
    req = Request(prompt_len=100, max_new_tokens=4)
    sched.submit(req)
    batch = sched.schedule(0.0)
    assert batch.prefill_chunks == [(req, 32)]
    assert pool.lens[req.req_id] == 32   # chunk booked, not the whole prompt
    sched.on_batch_done(batch, 0.01)
    _drain(sched)
    assert req.state == RequestState.FINISHED
    assert req.req_id not in pool.tables  # released on finish
    pool.check_invariants()


def test_chunk_shrinks_to_allocatable_blocks():
    pool = mk_pool(n_blocks=2, block_size=16)   # 32 tokens of KV, total
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=1024, max_seqs=4), kv_pool=pool
    )
    req = Request(prompt_len=500, max_new_tokens=1)
    sched.submit(req)
    batch = sched.schedule(0.0)
    assert batch.prefill_chunks[0][1] == 32     # gated by memory, not budget
    assert sched.stats.kv_deferrals == 1


def test_decode_preempts_youngest_to_make_room():
    pool = mk_pool(n_blocks=4, block_size=16)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=4), kv_pool=pool
    )
    # each fits alone (32 + 24 = 56 tokens = 4 blocks) but the pool cannot
    # hold both contexts at completion
    old = Request(prompt_len=32, max_new_tokens=24, arrival_time=0.0)
    young = Request(prompt_len=32, max_new_tokens=24, arrival_time=1.0)
    sched.submit(old)
    sched.submit(young)
    rounds = 0
    now = 0.0
    while old.state != RequestState.FINISHED and rounds < 200:
        batch = sched.schedule(now)
        now += 0.01
        rounds += 1
        if not batch.is_empty():
            sched.on_batch_done(batch, now)
    # the pool (64 tokens) cannot hold both contexts to completion: the
    # younger request must have been evicted at least once, never the older
    assert old.state == RequestState.FINISHED
    assert sched.stats.preemptions >= 1
    assert young.preemptions >= 1 and old.preemptions == 0
    pool.check_invariants()


def test_preempted_request_recomputes_and_finishes():
    reqs = shared_prefix(n_requests=16, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=24,
                         inter_arrival_s=0.002, seed=5)
    pool = mk_pool(n_blocks=20, block_size=16)
    res = run_policy(
        reqs, SchedulerConfig(policy="aging", token_budget=128, max_seqs=16),
        kv_pool=pool,
    )
    assert res.report.n_finished == 16
    assert res.scheduler_stats.preemptions > 0
    pool.check_invariants()
    assert pool.used_blocks == 0         # everything returned


def test_legacy_eager_mode_head_of_line_blocks():
    """The A/B baseline: eager whole-prompt admission blocks short requests
    behind a long prompt; chunk-granular admission does not."""
    def wl():
        longs = [Request(prompt_len=600, max_new_tokens=12, arrival_time=0.001 * i)
                 for i in range(3)]
        shorts = [Request(prompt_len=30, max_new_tokens=6,
                          arrival_time=0.01 + 0.005 * i) for i in range(20)]
        return longs + shorts

    cfg = SchedulerConfig(policy="aging", token_budget=256, max_seqs=64)
    eager = run_policy(wl(), cfg, kv_pool=mk_pool(n_blocks=64),
                       legacy_eager_kv=True)
    chunked = run_policy(wl(), cfg, kv_pool=mk_pool(n_blocks=64))
    mean_ttft = lambda res: float(np.mean(
        [r.ttft() for r in res.requests if r.prompt_len == 30]))
    assert chunked.report.n_finished == 23
    assert mean_ttft(chunked) < mean_ttft(eager)


def test_kv_none_paths_unchanged():
    """Without a pool the scheduler never touches KV machinery."""
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=4)
    )
    req = Request(prompt_len=100, max_new_tokens=2)
    sched.submit(req)
    _drain(sched)
    assert req.state == RequestState.FINISHED
    assert sched.stats.preemptions == 0


# ---------------------------------------------------------------------------
# evictable-cache bounds: capacity cap + TTL
# ---------------------------------------------------------------------------


def _park_prefix(pool, rid, base, n_tokens=17):
    """Allocate-and-release one request whose full blocks become cached."""
    toks = list(range(base, base + n_tokens))
    pool.register_request(rid, prompt_tokens=toks, prompt_len=n_tokens)
    pool.allocate(rid, n_tokens)
    pool.release(rid)


def test_cache_capacity_bound_trims_lru():
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=32, block_size=16, enable_prefix_cache=True, cache_max_blocks=2,
    ))
    for rid in range(4):
        _park_prefix(pool, rid, base=1000 * rid)     # one cached block each
    assert pool.cached_blocks == 2                   # bound holds
    assert pool.stats.capacity_evictions == 2
    assert pool.stats.evictions == 2
    # the two OLDEST parked prefixes were trimmed, the two newest match
    for rid, want in ((0, 0), (1, 0), (2, 16), (3, 16)):
        pool.register_request(10 + rid, prompt_tokens=list(range(1000 * rid, 1000 * rid + 17)),
                              prompt_len=17)
        assert pool.match_prefix(10 + rid) == want, rid
        pool.release(10 + rid)
    pool.check_invariants()


def test_cache_ttl_expires_idle_blocks():
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=32, block_size=16, enable_prefix_cache=True, cache_ttl_s=1.0,
    ))
    pool.advance_clock(0.0)
    _park_prefix(pool, 1, base=0)                    # parked at t=0
    pool.advance_clock(0.5)
    _park_prefix(pool, 2, base=500)                  # parked at t=0.5
    assert pool.cached_blocks == 2
    pool.advance_clock(1.2)                          # only req 1's expired
    assert pool.cached_blocks == 1
    assert pool.stats.ttl_evictions == 1
    pool.register_request(11, prompt_tokens=list(range(17)), prompt_len=17)
    assert pool.match_prefix(11) == 0                # expired: gone
    pool.register_request(12, prompt_tokens=list(range(500, 517)), prompt_len=17)
    assert pool.match_prefix(12) == 16               # fresh: still cached
    pool.release(12)
    pool.advance_clock(10.0)                         # everything expires
    assert pool.cached_blocks == 0
    assert pool.stats.evictions == pool.stats.ttl_evictions == 2
    pool.check_invariants()


def test_reacquired_block_resets_its_ttl():
    """A cache hit un-parks the block; re-release re-stamps it, so hot
    prefixes survive a TTL that would have expired their first parking."""
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=32, block_size=16, enable_prefix_cache=True, cache_ttl_s=1.0,
    ))
    pool.advance_clock(0.0)
    _park_prefix(pool, 1, base=0)
    pool.advance_clock(0.9)
    pool.register_request(2, prompt_tokens=list(range(17)), prompt_len=17)
    assert pool.match_prefix(2) == 16                # re-referenced at 0.9
    pool.release(2)                                  # re-parked at 0.9
    pool.advance_clock(1.5)                          # 0.6 idle < ttl
    pool.register_request(3, prompt_tokens=list(range(17)), prompt_len=17)
    assert pool.match_prefix(3) == 16
    pool.release(3)
    pool.check_invariants()


def test_eviction_counters_stay_consistent():
    """Total evictions always equals the sum of the per-cause counters, and
    the eviction order is LRU across causes."""
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=8, block_size=16, enable_prefix_cache=True,
        cache_max_blocks=3, cache_ttl_s=5.0,
    ))
    pool.advance_clock(0.0)
    for rid in range(4):                             # 4 parks, cap 3 -> 1 trim
        _park_prefix(pool, rid, base=1000 * rid)
    assert (pool.stats.capacity_evictions, pool.cached_blocks) == (1, 3)
    pool.advance_clock(6.0)                          # all 3 expire
    assert pool.stats.ttl_evictions == 3
    _park_prefix(pool, 7, base=7000)                 # re-park one block
    pool.allocate(8, 8 * 16)                         # needs all 8: demand-evict it
    assert pool.stats.demand_evictions == 1
    s = pool.stats
    assert s.evictions == s.demand_evictions + s.capacity_evictions + s.ttl_evictions == 5
    pool.release(8)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# swap-out staging: lifecycle, quota balance, conservation
# ---------------------------------------------------------------------------


def test_swap_out_moves_tokens_to_staging_and_back():
    pool = mk_pool(n_blocks=8, block_size=16)
    pool.allocate(1, 40)                 # 3 blocks
    rec = pool.swap_out(1, ready=True)
    pool.check_invariants()
    # every live token is in exactly ONE place: the staging entry
    assert 1 not in pool.tables and 1 not in pool.lens
    assert rec.tokens == 40 and rec.n_blocks == 3
    assert pool.swap_state(1) == BlockState.SWAPPED_OUT
    assert pool.used_blocks == 0         # device blocks all freed
    assert pool.swapped_out_blocks == 3  # ... but the restore size is known
    ids, _payload = pool.swap_in(1)
    pool.check_invariants()
    assert len(ids) == 3 and pool.tables[1] == ids
    assert pool.lens[1] == 40
    assert pool.swap_state(1) is None    # staging entry gone: RESIDENT again
    assert pool.used_blocks == 3


def test_swapping_record_blocks_restore_until_finished():
    pool = mk_pool(n_blocks=8, block_size=16)
    pool.allocate(1, 20)
    rec = pool.swap_out(1)               # engine path: gather still in flight
    assert rec.state == BlockState.SWAPPING
    assert not pool.swap_ready(1) and not pool.can_swap_in(1)
    with pytest.raises(AssertionError):
        pool.swap_in(1)
    pool.finish_swap_out(1, payload=("k", "v"))
    assert pool.swap_ready(1) and pool.can_swap_in(1)
    ids, payload = pool.swap_in(1)
    assert payload == ("k", "v") and len(ids) == 2
    pool.check_invariants()


def test_swap_quota_released_and_recharged():
    """Satellite-spec behavior: swapped blocks release the tenant's quota
    (another same-tenant request can use it) and restore re-charges it —
    balanced across arbitrarily many cycles."""
    pool = mk_pool(n_blocks=32)
    pool.set_tenant_quota("t", 4)
    pool.register_request(1, tenant="t")
    pool.allocate(1, 64, tenant="t")     # the full quota
    assert pool.tenant_used_blocks("t") == 4
    assert not pool.can_allocate(2, 16, tenant="t")
    pool.swap_out(1, ready=True)
    assert pool.tenant_used_blocks("t") == 0      # quota released
    pool.register_request(2, tenant="t")
    pool.allocate(2, 16, tenant="t")              # headroom usable again
    assert not pool.can_swap_in(1)                # ... and restore now short
    pool.release(2)
    for _ in range(3):                            # balanced across cycles
        assert pool.can_swap_in(1)
        pool.swap_in(1)
        assert pool.tenant_used_blocks("t") == 4  # re-charged
        pool.swap_out(1, ready=True)
        assert pool.tenant_used_blocks("t") == 0
    pool.check_invariants()


def test_swap_preserves_prefix_cache_entries():
    """Swapping a victim out must not invalidate prefix-cache entries its
    sealed blocks created: a later same-prefix request still matches (the
    original blocks parked in the evictable LRU at swap-out)."""
    pool = mk_pool(cache=True)
    toks = list(range(48))
    pool.register_request(1, prompt_tokens=toks, prompt_len=48)
    pool.allocate(1, 48)
    pool.swap_out(1, ready=True)
    assert pool.cached_blocks == 3       # sealed blocks parked, not destroyed
    pool.register_request(2, prompt_tokens=toks, prompt_len=48)
    assert pool.match_prefix(2) == 32    # match never covers the whole prompt
    # the swapped request restores into PRIVATE fresh blocks (no aliasing
    # with req 2's re-acquired cached ones)
    ids, _ = pool.swap_in(1)
    assert not set(ids) & set(pool.tables[2])
    pool.check_invariants()


def test_double_swap_and_empty_swap_are_rejected():
    pool = mk_pool()
    with pytest.raises(AssertionError):
        pool.swap_out(1, ready=True)     # no blocks: nothing to stage
    pool.allocate(1, 10)
    pool.swap_out(1, ready=True)
    with pytest.raises(AssertionError):
        pool.swap_out(1, ready=True)     # already staged
    pool.drop_swap(1)
    assert pool.swap_state(1) is None
    pool.drop_swap(1)                    # idempotent
    pool.check_invariants()


def test_export_swap_inflight_requires_opt_in():
    """Detaching a record whose gather has not drained is only legal on the
    prefetch path (``allow_inflight=True``); the default still insists on
    SWAPPED_OUT."""
    pool = mk_pool(n_blocks=8, block_size=16)
    pool.register_request(1)
    pool.allocate(1, 20)
    rec = pool.swap_out(1)               # SWAPPING: gather in flight
    assert rec.state == BlockState.SWAPPING
    with pytest.raises(AssertionError):
        pool.export_swap(1)
    rec2, reg = pool.export_swap(1, allow_inflight=True)
    assert rec2 is rec and pool.swap_state(1) is None
    pool.check_invariants()


def test_finalize_record_is_location_transparent():
    """Prefetch handoff lifecycle: a SWAPPING record exported from the source
    pool and adopted by a destination pool is finalized IN PLACE by the
    source drain (``finalize_record`` on the shared record object) — the
    destination's ``swap_ready`` gate flips without the source pool ever
    seeing the record again.  Payload arity is layout-dependent (two tensors
    split, one fused); the pool must not care."""
    for payload in (("k", "v"), ("kv",)):          # split / fused layouts
        src, dst = mk_pool(), mk_pool()
        src.register_request(1)
        src.allocate(1, 40)
        rec = src.swap_out(1)                      # gather still in flight
        exported, reg = src.export_swap(1, allow_inflight=True)
        dst.import_swap(1, exported, reg)
        assert dst.swap_state(1) == BlockState.SWAPPING
        assert not dst.swap_ready(1)               # restore must wait
        KVBlockPool.finalize_record(rec, payload)  # source drain lands
        assert dst.swap_ready(1)
        ids, got = dst.swap_in(1)
        assert got == payload and len(ids) == 3
        assert src.swap_state(1) is None           # source holds nothing
        src.check_invariants()
        dst.check_invariants()


def test_swap_in_raises_when_pool_exhausted():
    pool = mk_pool(n_blocks=4, block_size=16)
    pool.allocate(1, 60)                 # all 4 blocks
    pool.swap_out(1, ready=True)
    pool.allocate(2, 60)                 # pool refilled by someone else
    assert not pool.can_swap_in(1)
    with pytest.raises(MemoryError):
        pool.swap_in(1)
    pool.release(2)
    ids, _ = pool.swap_in(1)
    assert len(ids) == 4
    pool.check_invariants()


# ---------------------------------------------------------------------------
# property tests: pool invariants under random op sequences
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["alloc", "release", "match"]),
            st.integers(min_value=0, max_value=7),     # req id
            st.integers(min_value=1, max_value=40),    # token count
        ),
        max_size=60,
    ),
    cache=st.booleans(),
)
def test_pool_invariants_hold_under_random_ops(ops, cache):
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=16, block_size=8, bytes_per_token=4, enable_prefix_cache=cache,
    ))
    prompts = {rid: list(range(rid * 100, rid * 100 + 40)) for rid in range(8)}
    for op, rid, n in ops:
        if op == "alloc":
            if pool.can_allocate(rid, n):
                pool.allocate(rid, n)
        elif op == "release":
            pool.release(rid)
        else:
            if rid not in pool.tables:
                pool.register_request(rid, prompt_tokens=prompts[rid],
                                      prompt_len=40)
                pool.match_prefix(rid)
        pool.check_invariants()
        assert pool.used_blocks + pool.cached_blocks + len(pool.free_blocks) \
            == pool.cfg.n_blocks


@settings(max_examples=40, deadline=None)
@given(
    seq=st.lists(st.integers(min_value=1, max_value=30), min_size=1, max_size=20),
)
def test_alloc_release_cycle_conserves_blocks(seq):
    pool = mk_pool(n_blocks=64, block_size=16)
    for i, n in enumerate(seq):
        if pool.can_allocate(i, n):
            pool.allocate(i, n)
    for i in range(len(seq)):
        pool.release(i)
        pool.release(i)                  # double release must be harmless
    assert pool.used_blocks == 0
    assert len(pool.free_blocks) == 64
    pool.check_invariants()


@settings(max_examples=60, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["alloc", "release", "match", "tick"]),
            st.integers(min_value=0, max_value=7),     # req id
            st.integers(min_value=1, max_value=40),    # token count / ticks
        ),
        max_size=60,
    ),
    cache_max=st.one_of(st.none(), st.integers(min_value=0, max_value=6)),
    ttl=st.one_of(st.none(), st.floats(min_value=0.1, max_value=2.0)),
)
def test_block_table_invariants_under_random_ops(ops, cache_max, ttl):
    """The paged engine addresses physical pages straight through the pool's
    tables, so the block-table invariants are load-bearing: every live token
    maps into exactly one block slot, no block is referenced by two live
    tables unless it is sealed (prefix-shared), tables never alias a block
    twice, and the bounded cache never exceeds its cap.  Shared prompts are
    deliberately drawn from TWO prefix families so matches collide."""
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=24, block_size=8, bytes_per_token=4, enable_prefix_cache=True,
        cache_max_blocks=cache_max, cache_ttl_s=ttl,
    ))
    # two shared prefix families -> rids 0-3 and 4-7 can share blocks
    prompts = {rid: list(range((rid // 4) * 1000, (rid // 4) * 1000 + 40))
               for rid in range(8)}
    now = 0.0
    for op, rid, n in ops:
        if op == "alloc":
            if rid not in pool._reg:
                pool.register_request(rid, prompt_tokens=prompts[rid], prompt_len=40)
            if pool.can_allocate(rid, n):
                pool.allocate(rid, n)
        elif op == "release":
            pool.release(rid)
        elif op == "tick":
            now += n * 0.05
            pool.advance_clock(now)
        else:
            if rid not in pool.tables:
                pool.register_request(rid, prompt_tokens=prompts[rid], prompt_len=40)
                pool.match_prefix(rid)
        pool.check_invariants()
        # explicit restatement of the paged-engine contract (check_invariants
        # also asserts these; keep the load-bearing ones visible here)
        holders = {}
        for req_id, table in pool.tables.items():
            assert len(set(table)) == len(table)
            for bid in table:
                holders.setdefault(bid, []).append(req_id)
        for bid, hs in holders.items():
            if len(hs) > 1:
                assert bid in pool._hash_of, (bid, hs)   # shared => sealed


@settings(max_examples=60, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["alloc", "release", "match", "swap_out",
                             "finish", "swap_in", "drop"]),
            st.integers(min_value=0, max_value=7),     # req id
            st.integers(min_value=1, max_value=40),    # token count
        ),
        max_size=80,
    ),
    cache=st.booleans(),
    quota=st.one_of(st.none(), st.integers(min_value=2, max_value=10)),
)
def test_swap_lifecycle_invariants_under_random_ops(ops, cache, quota):
    """The tentpole's conservation law, fuzzed: every live request's tokens
    are tracked by exactly one of {block table, staging entry}; swapped
    requests pin no device blocks and no tenant quota; block conservation
    and quota balance hold through arbitrary interleavings of allocation,
    release, prefix matching, and swap-out/finish/swap-in/drop cycles."""
    pool = KVBlockPool(KVPoolConfig(
        n_blocks=16, block_size=8, bytes_per_token=4, enable_prefix_cache=cache,
    ))
    if quota is not None:
        pool.set_tenant_quota("t", quota)
    prompts = {rid: list(range(rid * 100, rid * 100 + 40)) for rid in range(8)}
    for op, rid, n in ops:
        swapped = pool.swap_state(rid) is not None
        if op == "alloc" and not swapped:
            if rid not in pool._reg:
                pool.register_request(rid, tenant="t",
                                      prompt_tokens=prompts[rid], prompt_len=40)
            if pool.can_allocate(rid, n, tenant="t"):
                pool.allocate(rid, n, tenant="t")
        elif op == "release" and not swapped:
            pool.release(rid)
        elif op == "match" and not swapped:
            if rid not in pool.tables:
                pool.register_request(rid, tenant="t",
                                      prompt_tokens=prompts[rid], prompt_len=40)
                pool.match_prefix(rid)
        elif op == "swap_out" and not swapped and pool.tables.get(rid):
            pool.swap_out(rid, ready=bool(n % 2))
        elif op == "finish" and swapped:
            pool.finish_swap_out(rid, payload=("k", rid))
        elif op == "swap_in" and pool.can_swap_in(rid, tenant="t"):
            ids, _ = pool.swap_in(rid, tenant="t")
            assert pool.lens[rid] <= len(ids) * pool.cfg.block_size
        elif op == "drop" and swapped:
            pool.drop_swap(rid)
        pool.check_invariants()
        # conservation: staged requests hold no device blocks, so the three
        # device populations still cover the whole pool
        assert pool.used_blocks + pool.cached_blocks + len(pool.free_blocks) \
            == pool.cfg.n_blocks
        # tracked-in-exactly-one-place, stated explicitly
        for rid2 in pool.swapped_requests():
            assert rid2 not in pool.tables and rid2 not in pool.lens
        # quota never exceeds the cap, and swapped tokens never count
        if quota is not None:
            assert pool.tenant_used_blocks("t") <= quota


def test_pool_for_model_prefix_cache_flag():
    from repro.configs import tiny_config
    pool = pool_for_model(tiny_config("qwen1.5-0.5b"), n_blocks=64,
                          enable_prefix_cache=True)
    assert pool.cfg.enable_prefix_cache
    assert pool.cfg.bytes_per_token > 0
