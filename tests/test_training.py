"""Training substrate: loss decreases, grad-accum consistency, optimizer
moment dtypes, checkpoint-restart determinism, data pipeline resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.models.model import build_model
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_step import (
    TrainConfig, init_train_state, loss_and_grad, make_train_step,
)


def _setup(arch="qwen1.5-0.5b", n_micro=1, moment_dtype="float32"):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, weight_decay=0.01, grad_clip_norm=1.0),
        n_microbatches=n_micro, moment_dtype=moment_dtype,
    )
    params, opt = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, global_batch=4, seq_len=32))
    return model, tcfg, params, opt, data


def test_loss_decreases_over_steps():
    model, tcfg, params, opt, data = _setup()
    step = jax.jit(make_train_step(model, tcfg))
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_grad_accum_matches_single_batch():
    """n_microbatches=4 must give (numerically close) grads to n=1."""
    model, tcfg1, params, _, data = _setup(n_micro=1)
    tcfg4 = TrainConfig(optimizer=tcfg1.optimizer, n_microbatches=4)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    _, _, g1 = loss_and_grad(model, params, batch, tcfg1)
    _, _, g4 = loss_and_grad(model, params, batch, tcfg4)
    # not bit-identical (per-microbatch mean vs global token mean under the
    # loss mask) but must be close
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        na = np.asarray(a, np.float32)
        nb = np.asarray(b, np.float32)
        denom = np.abs(na).max() + 1e-6
        assert np.abs(na - nb).max() / denom < 0.05


def test_bf16_moments_update_params():
    model, tcfg, params, opt, data = _setup(moment_dtype="bfloat16")
    assert all(m.dtype == jnp.bfloat16 for m in jax.tree.leaves(opt.mu))
    step = jax.jit(make_train_step(model, tcfg))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    p2, o2, m = step(params, opt, batch)
    # params moved, moments stayed bf16
    assert all(mm.dtype == jnp.bfloat16 for mm in jax.tree.leaves(o2.mu))
    moved = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip_norm=1e-3)
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, grads, opt, params)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_warmup_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    from repro.training.optimizer import _schedule
    assert float(_schedule(cfg, jnp.float32(0))) == pytest.approx(0.1)
    assert float(_schedule(cfg, jnp.float32(9))) == pytest.approx(1.0)
    assert float(_schedule(cfg, jnp.float32(100))) < 1e-6 + 1e-3


def test_data_pipeline_deterministic_resume():
    d = SyntheticLM(DataConfig(vocab_size=1000, global_batch=2, seq_len=16, seed=3))
    a = d.batch_at(17)
    b = d.batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = d.iterate(start_step=17)
    c = next(it)
    np.testing.assert_array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    full = d.batch_at(5)
    assert full["tokens"].shape == full["labels"].shape


def test_train_driver_checkpoint_restart(tmp_path):
    """launch/train.py end-to-end: a run killed at step 3 and resumed must
    reproduce the uninterrupted run's losses (deterministic data + state)."""
    from repro.launch.train import train
    # ground truth: uninterrupted 6 steps
    _, losses_full = train("qwen1.5-0.5b", steps=6, global_batch=2, seq_len=16,
                           log_every=100)
    # interrupted at 3, then resumed to 6
    d = str(tmp_path / "ck")
    train("qwen1.5-0.5b", steps=3, global_batch=2, seq_len=16,
          ckpt_dir=d, ckpt_every=100, log_every=100)     # final save at 3
    _, losses_tail = train("qwen1.5-0.5b", steps=6, global_batch=2, seq_len=16,
                           ckpt_dir=d, ckpt_every=100, resume=True,
                           log_every=100)
    np.testing.assert_allclose(losses_full[3:], losses_tail, rtol=2e-2)
