"""Swap-out preemption: device<->host KV block migration.

The acceptance bar is GREEDY OUTPUT BIT-IDENTITY: a run under
``preemption_mode="swap"`` must produce exactly the tokens of the same
workload under ``"recompute"`` AND of an unconstrained run (pool big enough
that nobody is ever evicted) — in both KV layouts, in both loop modes, with
real forced preemptions.  Plus the lifecycle regression the pipelined loop
makes subtle: a victim whose pages are still being copied out (SWAPPING)
must never re-bind a slot in that same round.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import tiny_config
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import BlockState, KVBlockPool, KVPoolConfig
from repro.engine.simulator import run_policy
from repro.engine.workload import shared_prefix
from repro.kernels.swap import swap_gather_pages, swap_scatter_pages


def _two_wave_shared_prefix(seed=5, n=12, new_tokens=10):
    """Two deterministic waves (t=0 and far behind): forces concurrency ->
    KV preemption on a small pool, with round structure independent of
    wall-clock timing so output comparisons are exact."""
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
    return reqs


def _serve_pressured(*, mode: str, pipelined: bool, paged: bool,
                     n_blocks: int = 11, use_pallas: bool = False,
                     kv_layout: str = "split", buffering_depth: int = 1):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=paged, pipelined=pipelined,
                                      use_pallas=use_pallas,
                                      kv_layout=kv_layout,
                                      buffering_depth=buffering_depth,
                                      preemption_mode=mode, seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    reqs = _two_wave_shared_prefix()
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    assert not pool.swapped_requests()      # nothing left staged at exit
    return res, sched, pool, reqs


# ---------------------------------------------------------------------------
# greedy parity: swap vs recompute vs unconstrained
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipelined", [True, False], ids=["pipelined", "sync"])
@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense"])
def test_swap_outputs_identical_to_recompute_and_unconstrained(paged, pipelined):
    res_s, sched_s, _, reqs_s = _serve_pressured(
        mode="swap", pipelined=pipelined, paged=paged)
    res_r, sched_r, _, reqs_r = _serve_pressured(
        mode="recompute", pipelined=pipelined, paged=paged)
    res_u, sched_u, _, reqs_u = _serve_pressured(
        mode="recompute", pipelined=pipelined, paged=paged, n_blocks=400)
    # the pressure actually bit, and swap mode actually swapped
    assert sched_s.stats.swap_preemptions > 0
    assert sched_s.stats.swap_restores == sched_s.stats.swap_preemptions
    assert sched_r.stats.preemptions > 0 and sched_r.stats.swap_preemptions == 0
    assert sched_u.stats.preemptions == 0
    assert res_s.report.n_finished == res_r.report.n_finished == \
        res_u.report.n_finished == len(reqs_s)
    assert any(t != 0 for out in res_s.outputs.values() for t in out)
    # req_ids are globally assigned: compare by workload POSITION
    for a, b, c in zip(reqs_s, reqs_r, reqs_u):
        assert res_s.outputs[a.req_id] == res_r.outputs[b.req_id]
        assert res_s.outputs[a.req_id] == res_u.outputs[c.req_id]
    # swap victims kept their progress: no prompt folding happened for them
    swapped = [r for r in reqs_s if r.swap_preemptions > 0]
    assert swapped
    for r in swapped:
        assert r.folded_tokens == 0 or r.preemptions > r.swap_preemptions


def test_swap_with_pallas_kernels_matches_dense_oracle():
    """The whole stack: pallas gather/scatter swap kernels + paged attention
    kernels + pipelined loop vs the dense sync pure-jnp oracle."""
    res_k, sched_k, _, reqs_k = _serve_pressured(
        mode="swap", pipelined=True, paged=True, use_pallas=True)
    res_o, _, _, reqs_o = _serve_pressured(
        mode="recompute", pipelined=False, paged=False)
    assert sched_k.stats.swap_preemptions > 0
    for a, b in zip(reqs_k, reqs_o):
        assert res_k.outputs[a.req_id] == res_o.outputs[b.req_id]


# ---------------------------------------------------------------------------
# fused KV layout + double-buffered DMA: swap parity must survive both knobs
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def split_swap_baseline():
    """One pressured split-layout sync swap run shared by the layout/depth
    parity matrix below."""
    res, sched, _, reqs = _serve_pressured(
        mode="swap", pipelined=False, paged=True)
    assert sched.stats.swap_preemptions > 0
    return res, reqs


@pytest.mark.parametrize("pipelined", [True, False], ids=["pipelined", "sync"])
@pytest.mark.parametrize("depth", [1, 2])
def test_fused_layout_swap_outputs_bit_identical(split_swap_baseline,
                                                 pipelined, depth):
    """Greedy outputs under the fused head-interleaved pool, at every
    buffering depth, in both loop modes, must be bit-identical to the split
    layout through real forced swap preemptions — the pool layout and the
    DMA schedule are pure data movement."""
    base_res, base_reqs = split_swap_baseline
    res, sched, _, reqs = _serve_pressured(
        mode="swap", pipelined=pipelined, paged=True,
        kv_layout="fused", buffering_depth=depth)
    assert sched.stats.swap_preemptions > 0
    assert sched.stats.swap_restores == sched.stats.swap_preemptions
    for a, b in zip(reqs, base_reqs):
        assert res.outputs[a.req_id] == base_res.outputs[b.req_id]


def test_fused_swap_with_pallas_kernels_matches_dense_oracle():
    """Deepest stack: fused layout + depth-2 double buffering + pallas swap
    and attention kernels + pipelined loop vs the dense sync jnp oracle."""
    res_k, sched_k, _, reqs_k = _serve_pressured(
        mode="swap", pipelined=True, paged=True, use_pallas=True,
        kv_layout="fused", buffering_depth=2)
    res_o, _, _, reqs_o = _serve_pressured(
        mode="recompute", pipelined=False, paged=False)
    assert sched_k.stats.swap_preemptions > 0
    for a, b in zip(reqs_k, reqs_o):
        assert res_k.outputs[a.req_id] == res_o.outputs[b.req_id]


# ---------------------------------------------------------------------------
# SWAPPING lifecycle: a mid-flight victim never re-binds in the same round
# ---------------------------------------------------------------------------


def test_swapping_victim_never_rebinds_in_swap_round():
    """Regression for the serve()/releaser contract: the victim's slot frees
    via the swapper inside schedule(), and while its device→host copy is in
    flight (SWAPPING) the scheduler must defer it WITHOUT consulting the
    slot binder — same-round re-binding would scatter a restore into pages
    whose gather has not drained."""
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=True, pipelined=True,
                                      preemption_mode="swap", seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=11, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )

    # interleaved event log: binds and swap-outs with (round, seq) order —
    # a bind BEFORE the swap in the same round is the normal schedule flow
    # (the victim was scheduled, then preempted for someone older); a bind
    # AFTER its swap-out event is the forbidden mid-flight re-bind
    events = []                      # (seq, kind, round_idx, req_id)
    seq = [0]
    real_acquire = eng.acquire_slot
    real_swap_out = eng.swap_out

    def spy_acquire(req):
        ok = real_acquire(req)
        if ok:
            seq[0] += 1
            events.append((seq[0], "bind", sched._round - 1, req.req_id))
        return ok

    def spy_swap_out(req):
        real_swap_out(req)
        seq[0] += 1
        events.append((seq[0], "swap", sched._round - 1, req.req_id))

    # serve() attaches these attributes as the binder/swapper hooks
    eng.acquire_slot = spy_acquire
    eng.swap_out = spy_swap_out

    batches = []
    real_schedule = sched.schedule

    def spy_schedule(now):
        b = real_schedule(now)
        batches.append(b)
        return b

    sched.schedule = spy_schedule

    reqs = _two_wave_shared_prefix()
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    assert res.report.n_finished == len(reqs)
    assert sched.stats.swap_preemptions > 0

    # 1) after a swap-out event, the victim is never bound again in that
    # same round (its gather is still in flight until the round drains)
    swap_events = [(s, rnd, rid) for s, kind, rnd, rid in events
                   if kind == "swap"]
    assert swap_events
    for s, rnd, rid in swap_events:
        rebinds = [e for e in events
                   if e[1] == "bind" and e[2] == rnd and e[3] == rid
                   and e[0] > s]
        assert not rebinds, (
            f"req {rid} re-bound a slot after its swap-out in round {rnd}"
        )
    # 2) every restore happened in a strictly later round than its swap-out
    swap_rounds = {}
    restore_rounds = {}
    for b in batches:
        for r in b.swapped_out:
            swap_rounds.setdefault(r.req_id, []).append(b.round_idx)
        for r in b.restored:
            restore_rounds.setdefault(r.req_id, []).append(b.round_idx)
    for rid, rounds in restore_rounds.items():
        for swap_rnd, rest_rnd in zip(sorted(swap_rounds[rid]), sorted(rounds)):
            assert rest_rnd > swap_rnd, (rid, swap_rnd, rest_rnd)


def test_swapping_record_defers_restore_until_finalized():
    """Scheduler-level unit: a SWAPPING record (gather not drained) is not
    restorable; finish_swap_out flips it and the next round restores."""
    pool = KVBlockPool(KVPoolConfig(n_blocks=8, block_size=16,
                                    bytes_per_token=4))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=4),
        kv_pool=pool,
    )
    sched.attach_swap(mode="swap")   # pool-accounting path, manual control
    req = Request(prompt_len=40, max_new_tokens=4)
    pool.allocate(req.req_id, 40)
    req.prefill_done = 40
    req.generated = 1
    req.output_tokens = [7]
    req.state = RequestState.DECODING
    # swap it out with an in-flight (not ready) record, as the engine would
    rec = pool.swap_out(req.req_id)
    assert rec.state == BlockState.SWAPPING
    req.swap_preempt()
    sched.queue.add(req)
    batch = sched.schedule(0.0)
    assert req not in [r for r, _ in batch.prefill_chunks]
    assert not batch.restored and sched.stats.swap_deferrals == 1
    assert req.req_id not in pool.tables        # still staged
    sched.on_batch_done(batch, 0.01)
    pool.finish_swap_out(req.req_id, payload=("k", "v"))
    batch = sched.schedule(0.02)
    assert [r.req_id for r in batch.restored] == [req.req_id]
    assert req.state == RequestState.DECODING and req.needs_replay
    assert pool.lens[req.req_id] == 40
    pool.check_invariants()


# ---------------------------------------------------------------------------
# swap kernels in isolation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True], ids=["jnp", "pallas"])
@pytest.mark.parametrize("H", [2, 4], ids=["split", "fused"])
def test_swap_gather_scatter_roundtrip(use_pallas, H, rng):
    # H=4 is the fused head-interleaved pool shape (2*Hkv on the head axis):
    # the swap kernels must be shape-generic over the trailing dims
    L, P, bs, hd = 2, 9, 8, 16
    pages = jnp.asarray(rng.normal(size=(L, P, bs, H, hd)).astype(np.float32))
    ids = jnp.asarray(np.array([5, 2, 7], np.int32))
    staged = swap_gather_pages(pages, ids, use_pallas=use_pallas)
    assert staged.shape == (L, 3, bs, H, hd)
    np.testing.assert_array_equal(np.asarray(staged), np.asarray(pages[:, ids]))
    # restore into different pages; untouched pages must be bit-identical.
    # NOTE: scatter DONATES the page pool (in-place restore) — snapshot the
    # reference before the call, as the engine's cache rebinding does.
    new_ids = jnp.asarray(np.array([1, 4, 6], np.int32))
    ref = np.asarray(pages.at[:, new_ids].set(staged))
    out = swap_scatter_pages(pages, new_ids, staged, use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_swap_scatter_duplicate_sink_ids_only_touch_sink(rng):
    """Padding entries point at the sink page: duplicate writes there must
    leave every real page untouched."""
    L, P, bs, H, hd = 1, 6, 4, 1, 8
    sink = P - 1
    pages = jnp.asarray(rng.normal(size=(L, P, bs, H, hd)).astype(np.float32))
    pages_before = np.asarray(pages)            # scatter donates `pages`
    staged = -jnp.ones((L, 3, bs, H, hd), jnp.float32)
    ids = jnp.asarray(np.array([2, sink, sink], np.int32))
    out = swap_scatter_pages(pages, ids, staged, use_pallas=True)
    keep = [0, 1, 3, 4]
    np.testing.assert_array_equal(np.asarray(out[:, keep]),
                                  pages_before[:, keep])
    np.testing.assert_array_equal(np.asarray(out[:, 2]),
                                  np.asarray(staged[:, 0]))


# ---------------------------------------------------------------------------
# cost-model decision + simulator integration
# ---------------------------------------------------------------------------


def test_cost_model_prefers_recompute_for_tiny_contexts():
    """With real byte weights a short context recomputes cheaper than two
    PCIe transfers; a long context flips the decision (quadratic attention
    FLOPs vs linear bytes)."""
    cm = CostModel(CostModelConfig(noise_std=0.0))
    bpt = 2 * 32 * 8 * 128 * 2          # a plausible mid-size model
    assert cm.recompute_cost_ms(8) < cm.swap_cost_ms(8, bpt)
    assert cm.swap_cost_ms(4096, bpt) < cm.recompute_cost_ms(4096)


def test_scheduler_cost_decision_respects_mode_and_model():
    pool = KVBlockPool(KVPoolConfig(n_blocks=64, block_size=16,
                                    bytes_per_token=4))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=4),
        kv_pool=pool,
    )
    victim = Request(prompt_len=64, max_new_tokens=4)
    pool.allocate(victim.req_id, 64)
    # recompute mode: never swap
    assert not sched._should_swap(victim)
    # swap mode, no cost model: always swap
    sched.attach_swap(mode="swap")
    assert sched._should_swap(victim)
    # swap mode + cost model where recompute is cheap (tiny context, huge
    # per-byte cost): fall back to recompute
    sched.attach_swap(mode="swap", cost_model=CostModel(
        CostModelConfig(noise_std=0.0, c_swap_ms_per_mb=1e9,
                        c_swap_fixed_ms=1e9)))
    assert not sched._should_swap(victim)


def test_simulator_swap_mode_finishes_and_reports():
    def wl():
        return shared_prefix(n_requests=16, n_prefixes=2, prefix_len=48,
                             suffix_range=(8, 16), max_new_tokens=24,
                             inter_arrival_s=0.002, seed=5)

    def mk_pool():
        return KVBlockPool(KVPoolConfig(n_blocks=20, block_size=16,
                                        bytes_per_token=4))

    cfg = SchedulerConfig(policy="aging", token_budget=128, max_seqs=16)
    swap = run_policy(wl(), cfg, kv_pool=mk_pool(), preemption_mode="swap")
    rec = run_policy(wl(), cfg, kv_pool=mk_pool(), preemption_mode="recompute")
    assert swap.report.n_finished == rec.report.n_finished == 16
    assert swap.scheduler_stats.swap_preemptions > 0
    assert swap.scheduler_stats.swap_restores == \
        swap.scheduler_stats.swap_preemptions
    assert rec.scheduler_stats.swap_preemptions == 0
    assert swap.memory.swap_preemptions > 0
    assert swap.memory.swapped_out_tokens == swap.memory.swapped_in_tokens
    # a swapped victim never recomputes: strictly fewer total scheduled
    # prefill tokens than the recompute run (which re-prefills contexts)
    assert swap.scheduler_stats.scheduled_prefill_tokens < \
        rec.scheduler_stats.scheduled_prefill_tokens


# ---------------------------------------------------------------------------
# request-lifecycle units
# ---------------------------------------------------------------------------


def test_swap_preempt_keeps_progress_and_resume_replays():
    r = Request(prompt_len=4, max_new_tokens=8, prompt_tokens=[1, 2, 3, 4])
    r.state = RequestState.DECODING
    r.prefill_done = 4
    r.receive_token(9, 1.0)
    r.swap_preempt()
    assert r.state == RequestState.WAITING and r.swapped
    assert r.prefill_done == 4 and r.prompt_tokens == [1, 2, 3, 4]
    assert r.folded_tokens == 0 and r.remaining_prefill == 0
    r.resume()
    assert r.state == RequestState.DECODING
    assert r.needs_replay and not r.swapped


def test_recompute_preempt_clears_replay_flag():
    """A restored request that gets recompute-preempted later must not replay
    a stale token over its freshly re-prefilled context."""
    r = Request(prompt_len=4, max_new_tokens=8, prompt_tokens=[1, 2, 3, 4])
    r.state = RequestState.DECODING
    r.prefill_done = 4
    r.receive_token(9, 1.0)
    r.swap_preempt()
    r.resume()
    assert r.needs_replay
    r.preempt()
    assert not r.needs_replay and not r.swapped
    assert r.prompt_tokens == [1, 2, 3, 4, 9]   # folded, recompute semantics


def test_mid_prefill_swap_resumes_chunking():
    r = Request(prompt_len=40, max_new_tokens=4)
    r.state = RequestState.PREFILLING
    r.prefill_done = 24
    r.swap_preempt()
    assert r.remaining_prefill == 16            # progress survived
    r.resume()
    assert r.state == RequestState.WAITING      # chunk flow continues
    assert not r.needs_replay                   # prefill-completing round samples
