"""Unified adaptive controller (paper §5 future work) tests."""

from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.apc import APCConfig
from repro.core.lprs import LPRSConfig
from repro.core.predictor import AnalyticPredictor
from repro.core.request import Request
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.simulator import ServingSimulator
from repro.engine.workload import WorkloadSpec, sharegpt_like


def mk_sched(**kw):
    pred = AnalyticPredictor()
    return ChunkedPrefillScheduler(
        SchedulerConfig(
            policy="aging", alpha=1.0, beta=-0.1, token_budget=512,
            max_seqs=32, lprs=LPRSConfig(target_latency_ms=50.0),
            apc=APCConfig(c_max=4, l_min=64), **kw,
        ),
        predictor=pred,
    )


def test_target_tracks_observed_latency():
    sched = mk_sched()
    ctl = AdaptiveController(sched, AdaptiveConfig(adjust_every=10))
    sched.submit(Request(prompt_len=5000, max_new_tokens=2, arrival_time=0.0))
    for i in range(30):
        b = sched.schedule(float(i))
        if b.is_empty():
            sched.submit(Request(prompt_len=5000, max_new_tokens=2,
                                 arrival_time=float(i)))
            continue
        ctl.observe(b, latency_ms=200.0, now=float(i))  # rounds run at 200ms
        sched.on_batch_done(b, float(i))
    # T* moved from 50 toward the observed 200 ms
    assert sched.cfg.lprs.target_latency_ms > 50.0


def test_starvation_raises_wait_weight():
    sched = mk_sched()
    ctl = AdaptiveController(sched, AdaptiveConfig(
        adjust_every=5, starvation_bound_s=1.0,
    ))
    ratio0 = sched.cfg.alpha / abs(sched.cfg.beta)
    # one ancient request stuck in the queue
    sched.submit(Request(prompt_len=100_000, max_new_tokens=1, arrival_time=0.0))
    for i in range(10):
        b = sched.schedule(100.0 + i)
        ctl.observe(b, latency_ms=10.0, now=100.0 + i)
        sched.on_batch_done(b, 100.0 + i)
        sched.submit(Request(prompt_len=100_000, max_new_tokens=1,
                             arrival_time=100.0 + i))
    ratio1 = sched.cfg.alpha / abs(sched.cfg.beta)
    assert ratio1 > ratio0


def test_rekey_preserves_queue_membership():
    sched = mk_sched()
    ctl = AdaptiveController(sched)
    reqs = [Request(prompt_len=p, max_new_tokens=1, arrival_time=0.0)
            for p in (10, 2000, 300)]
    for r in reqs:
        sched.submit(r)
    sched.cfg = sched.cfg.__class__(**{**sched.cfg.__dict__, "beta": -5.0}) \
        if False else sched.cfg
    ctl._rekey_queue()
    ids = {r.req_id for r in sched.queue.requests()}
    assert ids == {r.req_id for r in reqs}


def test_adaptive_end_to_end_no_regression():
    """Adaptive controller on a phase-shifting workload completes everything
    and does not blow up latency vs the static scheduler."""
    def workload():
        a = sharegpt_like(WorkloadSpec(n_requests=60, inter_arrival_s=0.02,
                                       max_context=64, seed=1))
        b = sharegpt_like(WorkloadSpec(n_requests=60, inter_arrival_s=0.05,
                                       max_context=512, seed=2))
        for i, r in enumerate(b):
            r.arrival_time += 1.5
        return a + b

    results = {}
    for label in ("static", "adaptive"):
        sched = mk_sched()
        ctl = AdaptiveController(sched, AdaptiveConfig(adjust_every=20)) \
            if label == "adaptive" else None
        sim = ServingSimulator(sched, CostModel(CostModelConfig(noise_std=0.0)))
        if ctl is not None:
            orig = sim.sched.on_batch_done

            def hooked(batch, now, _o=orig, _c=ctl):
                _c.observe(batch, _c._last_lat, now)
                _o(batch, now)

            # wire latency through the simulator loop
            orig_cost = sim.cost.batch_latency_ms

            def cost_hook(batch, **kw):
                ms = orig_cost(batch, **kw)
                ctl._last_lat = ms
                return ms

            sim.cost.batch_latency_ms = cost_hook
            sim.sched.on_batch_done = hooked
        res = sim.run(workload())
        assert res.report.n_finished == 120
        results[label] = res.report.e2e["mean"]
    # adaptive within 25% of static on this benign workload (sanity; gains
    # appear on drifting workloads, see benchmarks)
    assert results["adaptive"] <= results["static"] * 1.25
