"""Fault-tolerant fleet: replica failure recovery, handoff timeouts, and the
deterministic chaos harness.

The acceptance bar is the repo's usual one — GREEDY OUTPUT BIT-IDENTITY —
extended to partial failure: under ANY seeded fault plan, every submitted
request terminates exactly once (finished, quarantined, or shed with a
recorded reason), no KV block / slot / handoff byte leaks anywhere in the
fleet, the shared VTC's charge balances to tokens actually executed by
surviving work, and requests untouched by the faults produce exactly the
tokens of the fault-free run.  A decode replica killed while its handoff
records are still host-staged recovers them decode-resumable: ZERO
re-prefilled tokens on the decode pool.
"""
import pytest

from repro.configs import tiny_config
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.disagg import DisaggConfig, build_disagg, serve_disagg
from repro.disagg.handoff import KVHandoffStore
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import shared_prefix
from repro.robustness import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    HealthConfig,
    HealthState,
    InjectedFault,
    ReplicaHealth,
    RobustnessConfig,
)
from repro.tenancy import FairnessConfig, TenantSpec

FAIRNESS = FairnessConfig(tenants=(
    TenantSpec(name="a", weight=1.0), TenantSpec(name="b", weight=1.0),
))


def _two_wave(seed=5, n=12, new_tokens=10, tenants=False):
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
        if tenants:
            r.tenant = "a" if i % 2 == 0 else "b"
    return reqs


def _build_fleet(*, robustness=None, n_decode=2, pipelined=True,
                 fairness=None, n_blocks=64):
    cfg = tiny_config("qwen1.5-0.5b")
    return build_disagg(
        cfg,
        cfg=DisaggConfig(n_prefill=1, n_decode=n_decode,
                         robustness=robustness),
        engine_cfg=EngineConfig(n_slots=6, max_context=128, paged_kv=True,
                                pipelined=pipelined, preemption_mode="swap",
                                nan_guard=robustness is not None, seed=3),
        sched_cfg=SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6,
                                  fairness=fairness),
        n_blocks=n_blocks, block_size=16,
    )


def _serve_colocated(reqs, *, robustness=None, pipelined=True, fairness=None,
                     nan_guard=None, n_blocks=64):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(
        n_slots=6, max_context=128, paged_kv=True, pipelined=pipelined,
        preemption_mode="swap",
        nan_guard=(robustness is not None) if nan_guard is None else nan_guard,
        seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6,
                        fairness=fairness))
    res = serve(reqs, sched, eng, kv_pool=pool, robustness=robustness)
    return res, sched, eng, pool


def _assert_all_terminal(reqs):
    """Exactly-once termination: every request ends FINISHED, and a request
    that was never served carries a recorded shed reason."""
    for r in reqs:
        assert r.state == RequestState.FINISHED, r.req_id
        if r.finish_time is None:
            assert r.shed_reason is not None, r.req_id


def _assert_fleet_clean(router):
    """No leaks anywhere: block refcounts, swap staging, handoff bytes."""
    router.check_invariants()
    for rs in router.replicas:
        assert not rs.engine.slot_of, (rs.name, rs.engine.slot_of)


def _charge_identity(schedulers):
    """charged == Σ executed tokens + first-token bonuses, NET of crash /
    quarantine refunds — the invariant that says failures never double-bill
    or phantom-bill a tenant."""
    fair = [s.fairness for s in schedulers if s.fairness is not None]
    if not fair:
        return
    vtc = fair[0].vtc
    executed = sum(s.stats.scheduled_prefill_tokens
                   + s.stats.scheduled_decode_tokens for s in schedulers)
    bonuses = sum(f.first_token_charges for f in fair)
    charged = sum(vtc.actual_tokens(t) for t in vtc.tenants())
    assert charged == executed + bonuses, (charged, executed, bonuses)


# ---------------------------------------------------------------------------
# unit: injector determinism and scoping
# ---------------------------------------------------------------------------


def test_injector_nth_scoping():
    plan = FaultPlan(specs=(
        FaultSpec(site="replica_step_crash", nth=2, replica="decode0"),
        FaultSpec(site="handoff_drop", nth=1, req_id=7),
    ))
    inj = FaultInjector(plan)
    # global invocations on other replicas do not advance decode0's count
    assert inj.fire("replica_step_crash", replica="prefill0") is None
    assert inj.fire("replica_step_crash", replica="decode0") is None
    spec = inj.fire("replica_step_crash", replica="decode0")
    assert spec is not None and spec.nth == 2
    # consumed: never fires again
    assert inj.fire("replica_step_crash", replica="decode0") is None
    # req scoping
    assert inj.fire("handoff_drop", req_id=3) is None
    assert inj.fire("handoff_drop", req_id=7) is not None
    assert inj.count() == 2


def test_injector_repeat_and_raise():
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(site="replica_step_crash", nth=2, repeat=True),)))
    inj.fire("replica_step_crash")
    for _ in range(3):
        with pytest.raises(InjectedFault):
            inj.maybe_raise("replica_step_crash")
    assert inj.count("replica_step_crash") == 3


def test_fuzz_plan_is_seed_deterministic():
    a = FaultPlan.fuzz(11, n_faults=5, replicas=("prefill0", "decode0"))
    b = FaultPlan.fuzz(11, n_faults=5, replicas=("prefill0", "decode0"))
    c = FaultPlan.fuzz(12, n_faults=5, replicas=("prefill0", "decode0"))
    assert a == b
    assert a != c
    for s in a.specs:
        assert s.nth >= 1


# ---------------------------------------------------------------------------
# unit: health state machine
# ---------------------------------------------------------------------------


def test_health_suspect_dead_and_probation():
    h = ReplicaHealth(HealthConfig(suspect_after=1, dead_after=3, probation=2))
    assert h.observe("round") is HealthState.HEALTHY
    assert h.observe("error", error=RuntimeError("x")) is HealthState.SUSPECT
    # probation: two clean productive steps recover
    assert h.observe("round") is HealthState.SUSPECT
    assert h.observe("drained") is HealthState.HEALTHY
    # three consecutive errors kill
    h.observe("error")
    h.observe("error")
    assert h.observe("error") is HealthState.DEAD
    assert h.is_dead and not h.accepts_work
    # terminal: nothing revives it
    assert h.observe("round") is HealthState.DEAD
    assert h.transitions[-1] == (HealthState.SUSPECT, HealthState.DEAD)


def test_health_stall_detection_requires_busy():
    h = ReplicaHealth(HealthConfig(suspect_after=1, dead_after=2,
                                   stall_after=3))
    for _ in range(10):
        h.observe("starved", busy=False)   # empty replica: not a stall
    assert h.state is HealthState.HEALTHY
    for _ in range(3):
        h.observe("starved", busy=True)
    assert h.state is HealthState.SUSPECT
    # "idle" is neutral either way
    h2 = ReplicaHealth(HealthConfig(stall_after=0))   # disabled
    for _ in range(20):
        h2.observe("starved", busy=True)
    assert h2.state is HealthState.HEALTHY


# ---------------------------------------------------------------------------
# unit: handoff store TTL + byte ledger
# ---------------------------------------------------------------------------


class _Rec:
    def __init__(self, tokens):
        self.tokens = tokens


def test_handoff_store_ttl_and_byte_ledger():
    store = KVHandoffStore(ttl_s=1.0)
    store.put(1, _Rec(10), None, src="p0", bytes_per_token=4, now=0.0)
    store.put(2, _Rec(20), None, src="p0", bytes_per_token=4, now=0.5)
    assert store.stats.resident_bytes == 120
    assert store.expire(0.9) == []
    assert store.expire(1.2) == [1]          # only the older entry reaps
    assert store.stats.expired == 1 and store.stats.expired_bytes == 40
    store.take(2)
    # ledger balance: put - taken - dropped - expired == resident (== 0 now)
    store.check_invariants()
    # no TTL configured -> expire is a no-op
    s2 = KVHandoffStore()
    s2.put(3, _Rec(5), None, now=0.0)
    assert s2.expire(1e9) == []
    s2.drop(3)
    s2.check_invariants()


# ---------------------------------------------------------------------------
# sim router: bounded retries shed terminally
# ---------------------------------------------------------------------------


def test_sim_router_max_retries_sheds():
    from repro.engine.router import Router, RouterConfig

    cfg = RouterConfig(scheduler=SchedulerConfig(policy="fcfs",
                                                 token_budget=64),
                       max_retries=1)
    router = Router(cfg, n_replicas=3)
    reqs = [Request(req_id=i, prompt_len=64, max_new_tokens=16,
                    arrival_time=0.0) for i in range(6)]

    # kill two replicas in sequence: every request replays once (allowed),
    # then anything still unfinished on the second dead replica sheds
    def kill0(r):
        r.kill_replica(0)

    def kill1(r):
        r.kill_replica(1)

    router.run(reqs, fault_at={0.05: kill0, 0.3: kill1})
    assert all(r.state == RequestState.FINISHED
               for r in router.journal.values())
    for r in router.shed_failed:
        assert r.shed_reason == "replica_failure"
    # the replay bound held: nobody exceeded max_retries + 1 placements
    assert all(k <= cfg.max_retries + 1 for k in router._replays.values())


# ---------------------------------------------------------------------------
# flags-off / empty-plan bit-identity
# ---------------------------------------------------------------------------


def test_empty_plan_is_bit_identical_colocated():
    """The fault-tolerance wrapper itself (try/except + injector probes with
    an empty plan) must not perturb a single token."""
    reqs_a = _two_wave()
    res_a, *_ = _serve_colocated(reqs_a, robustness=None, nan_guard=False)
    reqs_b = _two_wave()
    res_b, *_ = _serve_colocated(
        reqs_b, robustness=RobustnessConfig(injector=FaultInjector()),
        nan_guard=False)
    for a, b in zip(reqs_a, reqs_b):
        assert res_a.outputs[a.req_id] == res_b.outputs[b.req_id]
    assert res_b.robustness.crash_unwinds == 0
    assert res_b.robustness.faults_fired == 0


def test_empty_plan_is_bit_identical_disagg():
    reqs_a = _two_wave()
    res_a = serve_disagg(reqs_a, _build_fleet())
    reqs_b = _two_wave()
    router = _build_fleet(robustness=RobustnessConfig(
        injector=FaultInjector()))
    res_b = serve_disagg(reqs_b, router)
    for a, b in zip(reqs_a, reqs_b):
        assert res_a.outputs[a.req_id] == res_b.outputs[b.req_id]
    assert res_b.robustness.replicas_died == 0
    _assert_fleet_clean(router)


# ---------------------------------------------------------------------------
# tentpole: replica death -> failover
# ---------------------------------------------------------------------------


def test_kill_decode_mid_handoff_zero_reprefill():
    """Deterministic kill of 1-of-2 decode replicas while handoff records
    are still host-staged: the staged requests re-place decode-resumable
    (zero re-prefilled tokens anywhere in the decode pool), everything else
    retries through the preempt fold, nothing is lost, and survivors'
    outputs are bit-identical to the fault-free fleet."""
    reqs_base = _two_wave()
    base = serve_disagg(reqs_base, _build_fleet())
    base_out = [list(base.outputs[r.req_id]) for r in reqs_base]

    plan = FaultPlan(specs=(FaultSpec(site="replica_step_crash", nth=3,
                                      replica="decode0", repeat=True),))
    rcfg = RobustnessConfig(health=HealthConfig(dead_after=1),
                            injector=FaultInjector(plan))
    reqs = _two_wave()
    router = _build_fleet(robustness=rcfg)
    res = serve_disagg(reqs, router)

    rb = res.robustness
    assert rb.replicas_died == 1
    assert rb.recovered_resumable > 0          # host-staged KV survived
    assert rb.shed_replica_failure == 0        # nobody was lost
    _assert_all_terminal(reqs)
    # the headline invariant: decode replicas NEVER prefilled a token — all
    # recoveries placed on the decode pool resumed from staged KV
    assert sum(rs.sched.stats.scheduled_prefill_tokens
               for rs in router.decode) == 0
    # full-output identity, shed-free run: failover is invisible in tokens
    for i, r in enumerate(reqs):
        assert res.outputs[r.req_id] == base_out[i]
    _assert_fleet_clean(router)


def test_kill_prefill_replica_degrades_to_colocated():
    """The only prefill replica dies: the fleet degrades — waiting work
    re-places onto the decode pool (colocated prefill) and later arrivals
    route straight there.  Every request still terminates."""
    plan = FaultPlan(specs=(FaultSpec(site="replica_step_crash", nth=2,
                                      replica="prefill0", repeat=True),))
    rcfg = RobustnessConfig(health=HealthConfig(dead_after=1),
                            injector=FaultInjector(plan))
    reqs = _two_wave()
    router = _build_fleet(robustness=rcfg)
    res = serve_disagg(reqs, router)
    assert res.robustness.replicas_died == 1
    assert res.robustness.colocated_fallbacks > 0
    _assert_all_terminal(reqs)
    assert sum(1 for r in reqs if r.finish_time is not None) > 0
    _assert_fleet_clean(router)


def test_handoff_drop_retries_then_sheds():
    """A persistently failing transfer for one request: each attempt drops,
    the request re-prefills, and past max_retries it sheds terminally with
    shed_reason='replica_failure' — while every other request is served
    bit-identically to the fault-free run."""
    reqs_base = _two_wave()
    base = serve_disagg(reqs_base, _build_fleet())
    base_out = [list(base.outputs[r.req_id]) for r in reqs_base]

    reqs = _two_wave()
    victim = reqs[2].req_id
    plan = FaultPlan(specs=(FaultSpec(site="handoff_drop", nth=1,
                                      req_id=victim, repeat=True),))
    rcfg = RobustnessConfig(max_retries=1, injector=FaultInjector(plan))
    router = _build_fleet(robustness=rcfg)
    res = serve_disagg(reqs, router)

    assert reqs[2].shed_reason == "replica_failure"
    assert res.robustness.shed_replica_failure == 1
    assert res.robustness.retries == 2          # allowed retry + the fatal one
    _assert_all_terminal(reqs)
    for i, r in enumerate(reqs):
        if r.req_id != victim:
            assert res.outputs[r.req_id] == base_out[i]
    _assert_fleet_clean(router)


def test_handoff_stall_reaped_by_ttl():
    """A staged record that is never adopted (stall fault) must not wedge
    the fleet: the TTL reaps it, bytes are accounted as expired, and the
    request recovers through the re-prefill path."""
    reqs = _two_wave()
    victim = reqs[0].req_id
    plan = FaultPlan(specs=(FaultSpec(site="handoff_stall", nth=1,
                                      req_id=victim),))
    rcfg = RobustnessConfig(handoff_ttl_s=0.05, injector=FaultInjector(plan))
    router = _build_fleet(robustness=rcfg)
    res = serve_disagg(reqs, router)
    assert res.robustness.expired_handoffs == 1
    assert router.store.stats.expired == 1
    assert router.store.stats.expired_bytes > 0
    _assert_all_terminal(reqs)
    assert reqs[0].finish_time is not None     # recovered, not lost
    _assert_fleet_clean(router)


def test_handoff_stall_without_ttl_fails_fast():
    """No TTL configured: the stalled record is dropped immediately instead
    of parking forever (the quiesce check would otherwise never clear)."""
    reqs = _two_wave()
    plan = FaultPlan(specs=(FaultSpec(site="handoff_stall", nth=1),))
    rcfg = RobustnessConfig(injector=FaultInjector(plan))
    router = _build_fleet(robustness=rcfg)
    serve_disagg(reqs, router)
    _assert_all_terminal(reqs)
    _assert_fleet_clean(router)


# ---------------------------------------------------------------------------
# satellite: serve-loop exception safety (crash between dispatch and drain)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("crash_call", [2, 5])
def test_crash_during_drain_unwinds_clean(monkeypatch, crash_call):
    """Kill the engine INSIDE drain — after the round dispatched, before its
    tokens were delivered.  The crash cleanup must roll the torn round back
    (charges refunded, slots/blocks released or requeued), and the recompute
    retry must regenerate the identical tokens."""
    reqs_base = _two_wave()
    base, *_ = _serve_colocated(reqs_base, nan_guard=False)

    reqs = _two_wave()
    calls = {"n": 0}
    real_drain = JAXEngine.drain

    def flaky_drain(self, inflight):
        calls["n"] += 1
        if calls["n"] == crash_call:
            raise RuntimeError("injected drain crash")
        return real_drain(self, inflight)

    monkeypatch.setattr(JAXEngine, "drain", flaky_drain)
    res, sched, eng, pool = _serve_colocated(
        reqs, robustness=RobustnessConfig(), nan_guard=False)
    assert res.robustness.crash_unwinds == 1
    _assert_all_terminal(reqs)
    assert all(r.shed_reason is None for r in reqs)
    for a, b in zip(reqs_base, reqs):
        assert base.outputs[a.req_id] == res.outputs[b.req_id]
    pool.check_invariants()
    assert not eng.slot_of


def test_step_crash_colocated_recovers_identically():
    """The seeded step-crash site (exception before the round body): the
    round never ran, so cleanup is pure requeue — outputs bit-identical."""
    reqs_base = _two_wave()
    base, *_ = _serve_colocated(reqs_base, nan_guard=False)
    reqs = _two_wave()
    plan = FaultPlan(specs=(FaultSpec(site="replica_step_crash", nth=4),))
    res, sched, eng, pool = _serve_colocated(
        reqs, robustness=RobustnessConfig(injector=FaultInjector(plan)),
        nan_guard=False)
    assert res.robustness.faults_fired == 1
    _assert_all_terminal(reqs)
    for a, b in zip(reqs_base, reqs):
        assert base.outputs[a.req_id] == res.outputs[b.req_id]
    pool.check_invariants()
    assert not eng.slot_of


# ---------------------------------------------------------------------------
# satellite: NaN/Inf quarantine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipelined", [True, False], ids=["pipelined", "sync"])
def test_nan_quarantine_sheds_victim_only(pipelined):
    """Inject non-finite KV into one decoding request: it quarantines
    (terminal, shed_reason='numerics', clean prefix delivered), its poisoned
    token's charge refunds, and every OTHER request's outputs stay
    bit-identical to the fault-free run."""
    reqs_base = _two_wave(tenants=True)
    base, *_ = _serve_colocated(reqs_base, pipelined=pipelined,
                                fairness=FAIRNESS, nan_guard=False)

    reqs = _two_wave(tenants=True)
    victim = reqs[1].req_id
    plan = FaultPlan(specs=(FaultSpec(site="nan_logits", nth=2,
                                      req_id=victim),))
    res, sched, eng, pool = _serve_colocated(
        reqs, robustness=RobustnessConfig(injector=FaultInjector(plan)),
        pipelined=pipelined, fairness=FAIRNESS)

    assert reqs[1].shed_reason == "numerics"
    assert res.robustness.quarantined == 1
    # the victim kept its clean prefix — shorter than the full decode
    assert len(res.outputs[victim]) < len(base.outputs[reqs_base[1].req_id])
    _assert_all_terminal(reqs)
    for i, r in enumerate(reqs):
        if r.req_id != victim:
            assert res.outputs[r.req_id] == base.outputs[reqs_base[i].req_id]
    pool.check_invariants()
    _charge_identity([sched])


# ---------------------------------------------------------------------------
# satellite: chaos property suite
# ---------------------------------------------------------------------------

CHAOS_SITES = ("replica_step_crash", "slow_round_ms", "handoff_drop",
               "handoff_stall", "swap_gather_fail", "host_oom")


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("pipelined", [True, False], ids=["pipelined", "sync"])
def test_chaos_disagg_invariants(seed, pipelined):
    """Fuzzed fault plans over the 1P+2D fleet.  Whatever fires, four
    invariants hold: exactly-once termination, zero leaks, the VTC charge
    identity, and bit-identical outputs for requests the faults did not
    touch (non-shed, non-quarantined)."""
    reqs_base = _two_wave(tenants=True)
    base = serve_disagg(reqs_base, _build_fleet(pipelined=pipelined,
                                                fairness=FAIRNESS))
    base_out = [list(base.outputs[r.req_id]) for r in reqs_base]

    plan = FaultPlan.fuzz(seed, n_faults=4, sites=CHAOS_SITES, max_nth=20,
                          replicas=("prefill0", "decode0", "decode1"))
    rcfg = RobustnessConfig(health=HealthConfig(dead_after=2),
                            max_retries=3, handoff_ttl_s=0.05,
                            injector=FaultInjector(plan))
    reqs = _two_wave(tenants=True)
    router = _build_fleet(robustness=rcfg, pipelined=pipelined,
                          fairness=FAIRNESS)
    res = serve_disagg(reqs, router)

    _assert_all_terminal(reqs)                            # 1: exactly once
    _assert_fleet_clean(router)                           # 2: no leaks
    _charge_identity([rs.sched for rs in router.replicas])  # 3: VTC identity
    affected = {r.req_id for r in reqs if r.shed_reason is not None}
    for i, r in enumerate(reqs):                          # 4: survivor identity
        if r.req_id not in affected and r.handoffs <= 1 and not r.folded_tokens:
            assert res.outputs[r.req_id] == base_out[i], r.req_id


@pytest.mark.parametrize("seed", [4, 5])
def test_chaos_colocated_invariants(seed):
    """The same fuzz harness against the single fault-tolerant replica:
    crashes and numerics quarantine in place, no fleet to fail over to."""
    plan = FaultPlan.fuzz(seed, n_faults=3,
                          sites=("replica_step_crash", "nan_logits",
                                 "slow_round_ms"),
                          max_nth=15)
    reqs = _two_wave(tenants=True)
    res, sched, eng, pool = _serve_colocated(
        reqs, robustness=RobustnessConfig(injector=FaultInjector(plan)),
        fairness=FAIRNESS)
    _assert_all_terminal(reqs)
    pool.check_invariants()
    assert not eng.slot_of
    _charge_identity([sched])
