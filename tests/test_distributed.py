"""Distribution substrate: sharding spec sanitization, checkpoint round-trip
+ async + elastic resharding, gradient compression, router fault tolerance,
HLO cost analyzer ground truths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.scheduler import SchedulerConfig
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import (
    compressed_psum, compression_ratio, dequantize_int8, quantize_int8,
)
from repro.distributed.sharding import sanitize_spec, spec_for_param
from repro.engine.router import Router, RouterConfig
from repro.engine.workload import WorkloadSpec, sharegpt_like
from repro.launch.hlo_cost import analyze_hlo, parse_hlo


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def _mesh22():
    devs = np.array(jax.devices()[:1] * 4).reshape(2, 2)
    return Mesh(devs, ("data", "model")) if False else None


def test_sanitize_drops_nondividing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake axis sizes via a tiny mesh is degenerate; emulate with math mesh
    # -> use the real helper against a 1x1 mesh: everything divides
    spec = sanitize_spec(mesh, ("data", "model"), (8, 8))
    assert spec == P("data", "model")


def test_sanitize_spec_math():
    """Check the divisibility logic against a mocked mesh shape."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    mesh = FakeMesh()
    # 8 kv heads cannot shard over model=16 -> dropped
    assert sanitize_spec(mesh, (None, None, "model", None), (1, 1, 8, 64)) == P(
        None, None, None, None
    )
    # 96 heads shard fine
    assert sanitize_spec(mesh, (None, None, "model", None), (1, 1, 96, 64)) == P(
        None, None, "model", None
    )
    # tuple axis: batch 256 over ("data", "model") uses both
    assert sanitize_spec(mesh, (("data", "model"),), (256,)) == P(("data", "model"))
    # tuple axis partial: 32 over ("data","model") keeps data only
    assert sanitize_spec(mesh, (("data", "model"),), (32,)) == P("data")
    # same axis never used twice
    assert sanitize_spec(mesh, ("model", "model"), (32, 32)) == P("model", None)


def test_spec_for_param_rules():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    mesh = FakeMesh()
    # stacked attention projection (L, d, H, hd): d over data, H over model
    assert spec_for_param("layers/attn/wq", (16, 4096, 32, 128), mesh,
                          fsdp=True) == P(None, "data", "model", None)
    # ffn w_gate (stacked): (L, D, F) -> F over model, D over data (fsdp)
    assert spec_for_param("layers/ffn/w_gate", (32, 4096, 14336), mesh,
                          fsdp=True) == P(None, "data", "model")
    # experts (stacked) (L, E, D, F): E over model (EP)
    assert spec_for_param("layers/moe/w_gate", (32, 128, 4096, 4864), mesh,
                          fsdp=True) == P(None, "model", "data", None)
    # norms replicated
    assert spec_for_param("layers/attn_norm", (32, 4096), mesh, fsdp=True) == P(
        None, None
    )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "emb": jax.random.normal(k, (32, 8), jnp.bfloat16),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(7, st, blocking=True)
    step, back = mgr.restore(st)
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    mgr.wait()
    assert mgr.list_steps() == [3, 4]      # GC kept last 2
    step, _ = mgr.restore(st)
    assert step == 4
    mgr.close()


def test_checkpoint_restore_with_resharding(tmp_path):
    """Restore under different shardings (elastic TP resize path)."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    mgr.save(1, st, blocking=True)
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import NamedSharding
    sh = {
        "w": NamedSharding(mesh, P(None, "model")),
        "emb": NamedSharding(mesh, P("model", None)),
        "nested": {"b": NamedSharding(mesh, P())},
    }
    _, back = mgr.restore(st, shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(st["w"]))


def test_checkpoint_namedtuple_state(tmp_path):
    from repro.training.optimizer import adamw_init
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, (params, opt), blocking=True)
    step, (p2, o2) = mgr.restore((params, opt))
    assert step == 3
    assert int(o2.step) == 0
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.standard_normal((1000,)) * 3.0, jnp.float32)
    q, s = quantize_int8(x, jax.random.PRNGKey(0))
    back = dequantize_int8(q.astype(jnp.int32), s, x.shape, x.size)
    err = np.abs(np.asarray(back - x))
    # max error <= scale/2 per block (+stochastic half-step)
    assert err.max() <= float(s.max())
    assert compression_ratio() < 0.27


def test_quantization_is_unbiased(rng):
    """Stochastic rounding: mean dequant error -> 0 over many draws."""
    x = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
    errs = []
    for i in range(64):
        q, s = quantize_int8(x, jax.random.PRNGKey(i))
        back = dequantize_int8(q.astype(jnp.int32), s, x.shape, x.size)
        errs.append(np.asarray(back - x))
    assert np.abs(np.mean(errs)) < 5e-3


def test_compressed_psum_single_device():
    """axis of size 1: compressed psum == identity up to quantization."""
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("dp",))
    grads = {"w": jnp.linspace(-1, 1, 512).reshape(2, 256)}

    def f(g):
        out, err = compressed_psum(g, "dp", jax.random.PRNGKey(0))
        return out, err

    fm = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()))
    out, err = fm(grads)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(grads["w"]),
                               atol=2e-2)
    # error feedback holds the residual
    assert np.abs(np.asarray(err["w"])).max() <= 2e-2


# ---------------------------------------------------------------------------
# router fault tolerance
# ---------------------------------------------------------------------------


def test_router_failover_completes_all():
    r = Router(RouterConfig(
        scheduler=SchedulerConfig(policy="aging", token_budget=256, max_seqs=32)
    ), n_replicas=3)
    reqs = sharegpt_like(WorkloadSpec(n_requests=40, inter_arrival_s=0.05, seed=2))
    r.run(reqs, fault_at={1.0: lambda rt: rt.kill_replica(0)})
    fin = sum(1 for q in r.journal.values() if q.state.value == "finished")
    assert fin == 40
    assert any("DIED" in e for e in r.events)
    assert any("replayed" in e for e in r.events) or True  # may have none in flight


def test_router_elastic_add_remove():
    r = Router(RouterConfig(
        scheduler=SchedulerConfig(policy="fcfs", token_budget=256, max_seqs=32)
    ), n_replicas=2)
    reqs = sharegpt_like(WorkloadSpec(n_requests=30, inter_arrival_s=0.05, seed=3))
    r.run(reqs, fault_at={
        0.5: lambda rt: rt.add_replica(),
        1.5: lambda rt: rt.remove_replica(1),
    })
    fin = sum(1 for q in r.journal.values() if q.state.value == "finished")
    assert fin == 30


def test_router_straggler_detection():
    r = Router(RouterConfig(
        straggler_factor=0.5, straggler_window=1.0,
        scheduler=SchedulerConfig(policy="fcfs", token_budget=256, max_seqs=32),
    ), n_replicas=1)
    r.add_replica(speed=0.05)          # 20x slower replica
    reqs = sharegpt_like(WorkloadSpec(n_requests=60, inter_arrival_s=0.02, seed=4))
    r.run(reqs)
    fin = sum(1 for q in r.journal.values() if q.state.value == "finished")
    assert fin == 60
    assert any("STRAGGLER" in e for e in r.events)


def test_replay_preserves_seniority():
    """Replayed requests keep their original arrival time -> Aging rank."""
    r = Router(RouterConfig(
        scheduler=SchedulerConfig(policy="aging", token_budget=64, max_seqs=8)
    ), n_replicas=2)
    reqs = sharegpt_like(WorkloadSpec(n_requests=10, inter_arrival_s=0.01, seed=5))
    arrivals = {q.req_id: q.arrival_time for q in reqs}
    r.run(reqs, fault_at={0.05: lambda rt: rt.kill_replica(0)})
    for rid, q in r.journal.items():
        assert q.arrival_time == pytest.approx(arrivals[rid])


# ---------------------------------------------------------------------------
# HLO cost analyzer ground truths
# ---------------------------------------------------------------------------


def test_hlo_cost_scan_matmul_exact():
    L_, M, K, N = 7, 32, 64, 48

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    w = jax.ShapeDtypeStruct((L_, K, K), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    rep = analyze_hlo(comp.as_text())
    dot_flops = L_ * 2 * M * K * K
    assert rep.flops == pytest.approx(dot_flops, rel=0.05)
    assert rep.n_while_loops >= 1


def test_hlo_cost_counts_collectives_with_trips():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # single-device psum lowers away; validate parser on synthetic HLO text
    text = """
HloModule test, num_partitions=4

%body (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %p = (s32[], f32[16,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,16]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[16,16]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[16,16]{1,0}) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[16,16])) -> pred[] {
  %p = (s32[], f32[16,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[16,16]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[16,16]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[16,16]{1,0} get-tuple-element(%w), index=1
}
"""
    rep = analyze_hlo(text)
    # all-reduce volume: 2x operand bytes x 5 trips
    assert rep.collective_bytes["all-reduce"] == 2 * 16 * 16 * 4 * 5
    assert rep.n_collective_ops == 5


def test_hlo_parser_computations():
    text = """
ENTRY %m (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %t = f32[4]{0} tanh(%a)
}
"""
    comps, entry = parse_hlo(text)
    assert entry == "m"
    assert comps["m"].ops[-1].opcode == "tanh"
