"""Pipelined serve loop correctness: the overlapped schedule/execute pipeline
(device-resident token feedback, async one-round-late readback) must produce
greedy outputs BIT-IDENTICAL to the synchronous engine — in both KV layouts,
under forced mid-pipeline KV preemption (token folds patched one round late)
and across prefix-cache restores — plus the one-round-lag bookkeeping
(``Request.patch_token``) in isolation.
"""
import pytest

from repro.configs import tiny_config
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.workload import (
    WorkloadSpec, attach_prompt_tokens, shared_prefix, sharegpt_like,
)


def _two_wave_shared_prefix(seed=5):
    """shared_prefix in two deterministic waves: wave 1 all at t=0 (forces
    concurrency -> KV preemption on a small pool), wave 2 far behind it (the
    idle-gap jump admits it atomically AFTER wave 1 sealed its prefix blocks,
    so the prefix-restore path is exercised deterministically)."""
    reqs = shared_prefix(n_requests=12, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=10,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < 6 else 60.0
    return reqs


def _serve_adversarial(*, pipelined: bool, paged: bool):
    """Shared-prefix waves on a pool too small for the concurrent working
    set: forced preemptions (mid-pipeline when pipelined) + prefix-cache
    restores, the pipeline's two hardest token-visibility cases."""
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=paged, pipelined=pipelined,
                                      seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=11, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    reqs = _two_wave_shared_prefix()
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    return res, sched, pool, reqs


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense"])
def test_pipelined_greedy_outputs_identical_with_preemption(paged):
    """The acceptance criterion: pipelined vs synchronous greedy outputs are
    bit-identical per request — including tokens that were folded into a
    recompute prompt by a preemption BEFORE their device value had drained
    (patch_token fixes the folded copy before it is restaged)."""
    res_p, sched_p, pool_p, reqs_p = _serve_adversarial(pipelined=True,
                                                        paged=paged)
    res_s, sched_s, pool_s, reqs_s = _serve_adversarial(pipelined=False,
                                                        paged=paged)
    # the adversarial conditions actually happened, in both modes
    assert sched_p.stats.preemptions > 0 and sched_s.stats.preemptions > 0
    assert pool_p.stats.hit_tokens > 0 and pool_s.stats.hit_tokens > 0
    assert res_p.report.n_finished == res_s.report.n_finished == 12
    # comparison is over REAL sampled ids, not undrained placeholders
    assert any(t != 0 for out in res_p.outputs.values() for t in out)
    # req_ids are globally assigned: match requests by workload position
    for rp, rs in zip(reqs_p, reqs_s):
        assert res_p.outputs[rp.req_id] == res_s.outputs[rs.req_id], (
            rp.req_id, rs.req_id,
        )
    # folded prompts were patched too: recompute prompts carry no stale zeros
    folded = [r for r in reqs_p if r.folded_tokens > 0]
    assert folded, "preemption should have folded delivered tokens"
    for r in folded:
        base = r.prompt_len - r.folded_tokens
        assert r.prompt_tokens[base:base + r.folded_tokens] == \
            r.output_tokens[:r.folded_tokens]


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense"])
def test_pipelined_plain_workload_matches_sync(paged):
    """No-preemption path: a plain mixed workload through both loop modes."""
    cfg = tiny_config("qwen1.5-0.5b")

    def run(pipelined):
        eng = JAXEngine(cfg, EngineConfig(n_slots=8, max_context=256,
                                          paged_kv=paged, pipelined=pipelined))
        # t=0 arrivals: round structure decoupled from wall-clock timing, so
        # the bit-identity comparison is deterministic
        reqs = sharegpt_like(WorkloadSpec(
            n_requests=6, inter_arrival_s=0.0, max_context=100,
            max_new_tokens=8, seed=7,
        ))
        attach_prompt_tokens(reqs, cfg.vocab_size)
        sched = ChunkedPrefillScheduler(
            SchedulerConfig(policy="fcfs", token_budget=48, max_seqs=8)
        )
        return serve(reqs, sched, eng), reqs

    res_p, reqs_p = run(True)
    res_s, reqs_s = run(False)
    assert res_p.report.n_finished == res_s.report.n_finished == 6
    for rp, rs in zip(reqs_p, reqs_s):
        assert res_p.outputs[rp.req_id] == res_s.outputs[rs.req_id]
    # the pipeline measured its host bubbles
    assert res_p.host_bubble_ms and all(b >= 0 for b in res_p.host_bubble_ms)


def test_pipelined_pages_per_tile_kernel_engine_e2e():
    """Pipelined + paged + Pallas kernels with multi-page tiles: end-to-end
    greedy outputs must match the synchronous dense-oracle engine (ties the
    whole stack together: tiles are data movement, the pipeline is
    scheduling)."""
    cfg = tiny_config("qwen1.5-0.5b")

    def run(paged, pipelined, use_pallas, ppt):
        eng = JAXEngine(cfg, EngineConfig(
            n_slots=4, max_context=128, paged_kv=paged, pipelined=pipelined,
            use_pallas=use_pallas, pages_per_tile=ppt, kv_block_size=16,
        ))
        reqs = sharegpt_like(WorkloadSpec(
            n_requests=3, inter_arrival_s=0.0, max_context=48,
            max_new_tokens=4, seed=9,
        ))
        attach_prompt_tokens(reqs, cfg.vocab_size)
        sched = ChunkedPrefillScheduler(
            SchedulerConfig(policy="fcfs", token_budget=32, max_seqs=4)
        )
        return serve(reqs, sched, eng), reqs

    res_t, reqs_t = run(True, True, True, 2)     # tiled, pipelined, kernels
    res_s, reqs_s = run(False, False, False, 1)  # dense, sync, oracle
    assert res_t.report.n_finished == res_s.report.n_finished == 3
    for rt, rs in zip(reqs_t, reqs_s):
        assert res_t.outputs[rt.req_id] == res_s.outputs[rs.req_id]


# ---------------------------------------------------------------------------
# one-round-lag bookkeeping in isolation
# ---------------------------------------------------------------------------


def test_patch_token_plain():
    r = Request(prompt_len=4, max_new_tokens=3, prompt_tokens=[1, 2, 3, 4])
    r.state = RequestState.DECODING
    r.receive_token(0, 1.0)          # placeholder: device value not drained
    r.patch_token(0, 17)
    assert r.output_tokens == [17]


def test_patch_token_fixes_folded_prompt():
    """A preemption can fold a still-undrained placeholder into the recompute
    prompt; the late patch must fix BOTH copies."""
    r = Request(prompt_len=4, max_new_tokens=8, prompt_tokens=[1, 2, 3, 4])
    r.state = RequestState.DECODING
    r.prefill_done = 4
    r.receive_token(9, 1.0)          # round k-1: real id already drained
    r.receive_token(0, 2.0)          # round k: placeholder, still in flight
    r.preempt()                      # folds [9, 0] into the prompt
    assert r.prompt_tokens == [1, 2, 3, 4, 9, 0]
    r.patch_token(1, 23)             # round k drains
    assert r.output_tokens == [9, 23]
    assert r.prompt_tokens == [1, 2, 3, 4, 9, 23]
    assert r.prompt_len == 6 and r.folded_tokens == 2
