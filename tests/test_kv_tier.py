"""Tiered KV hierarchy: host-tier budget/LRU, swap-in prefetch, partial
swap-in, and INT8-quantized host pages.

The acceptance bar mirrors the swap-preemption suite: GREEDY OUTPUT
BIT-IDENTITY.  Runs with the full hierarchy engaged (prefetched restores,
a host byte budget that demotes staged victims to recompute, tail-only
partial swap-ins, int8 host pages) must produce exactly the tokens of an
unconstrained run — in both KV layouts and both loop modes.  On top of
parity, every tier keeps an exact byte ledger and every live token lives
in exactly ONE of {device table, host staging, handoff store}.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import HealthCheck, given, settings, st
from repro.configs import tiny_config
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.disagg.handoff import KVHandoffStore
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import (
    HostTier,
    KVBlockPool,
    KVPoolConfig,
)
from repro.engine.workload import shared_prefix
from repro.kernels.ref import dequantize_pages, quantize_pages
from repro.kernels.swap import swap_gather_pages_q8, swap_scatter_pages_q8


# ---------------------------------------------------------------------------
# harnesses
# ---------------------------------------------------------------------------


def _two_wave_shared_prefix(seed=5, n=12, new_tokens=10):
    reqs = shared_prefix(n_requests=n, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=new_tokens,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < n // 2 else 60.0
    return reqs


def _serve_tiered(*, mode: str = "swap", pipelined: bool = False,
                  paged: bool = True, n_blocks: int = 11,
                  token_budget: int = 96,
                  use_pallas: bool = False, kv_layout: str = "split",
                  host_max_bytes=None, host_kv_dtype: str = "auto",
                  swap_prefetch_depth: int = 0, partial_restore_after=None):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=paged, pipelined=pipelined,
                                      use_pallas=use_pallas,
                                      kv_layout=kv_layout,
                                      preemption_mode=mode, seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=n_blocks, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True,
                                    host_max_bytes=host_max_bytes,
                                    host_kv_dtype=host_kv_dtype))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=token_budget, max_seqs=6,
                        swap_prefetch_depth=swap_prefetch_depth,
                        partial_restore_after=partial_restore_after)
    )
    reqs = _two_wave_shared_prefix()
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    assert not pool.swapped_requests()      # nothing left staged at exit
    if pool.host is not None:
        pool.host.check_invariants()
        assert pool.host.stats.resident_bytes == 0
    return res, sched, pool, reqs


_BASELINE = {}


def _baseline_outputs():
    """Unconstrained greedy reference (no preemption pressure at all),
    memoized: every hierarchy configuration must reproduce these tokens."""
    if "res" not in _BASELINE:
        res, sched, _, reqs = _serve_tiered(mode="recompute", n_blocks=400)
        assert sched.stats.preemptions == 0
        _BASELINE["res"], _BASELINE["reqs"] = res, reqs
    return _BASELINE["res"], _BASELINE["reqs"]


def _assert_parity(res, reqs):
    res_u, reqs_u = _baseline_outputs()
    assert res.report.n_finished == len(reqs)
    assert any(t != 0 for out in res.outputs.values() for t in out)
    for a, b in zip(reqs, reqs_u):
        assert res.outputs[a.req_id] == res_u.outputs[b.req_id]


def _decode_victim(pool, *, prompt_len=80, arrival=1.0, ready=True):
    """Stage a decode-resumable victim exactly as a swap preemption would:
    device lens = prompt + generated - 1, record staged, request marked."""
    r = Request(prompt_len=prompt_len, max_new_tokens=4, arrival_time=arrival,
                prompt_tokens=list(range(prompt_len)))
    pool.register_request(r.req_id, prompt_tokens=r.prompt_tokens,
                          prompt_len=prompt_len)
    pool.allocate(r.req_id, prompt_len)
    r.prefill_done = prompt_len
    r.generated = 1
    r.output_tokens = [7]
    r.state = RequestState.DECODING
    pool.swap_out(r.req_id, ready=ready)
    r.swap_preempt()
    return r


def _drive(sched, now):
    b = sched.schedule(now)
    sched.on_batch_done(b, now)
    return b


# ---------------------------------------------------------------------------
# HostTier: the byte ledger itself
# ---------------------------------------------------------------------------


def test_host_tier_ledger_closes_and_tracks_peak():
    t = HostTier(max_bytes=1000)
    t.charge(400)
    t.charge(500)
    assert t.stats.resident_bytes == 900 and t.stats.peak_bytes == 900
    t.release(400)
    t.charge(100)
    st_ = t.stats
    assert st_.put_bytes - st_.freed_bytes == st_.resident_bytes == 600
    assert st_.peak_bytes == 900          # high-water mark survives releases
    t.check_invariants()
    t.release(600)
    assert t.stats.resident_bytes == 0
    t.check_invariants()


def test_host_tier_charge_asserts_over_budget():
    t = HostTier(max_bytes=100)
    assert t.can_fit(100) and not t.can_fit(101)
    t.charge(80)
    with pytest.raises(AssertionError):
        t.charge(21)


def test_host_tier_release_asserts_underflow():
    t = HostTier()
    t.charge(10)
    with pytest.raises(AssertionError):
        t.release(11)


def test_host_tier_eviction_causes_counted_separately():
    t = HostTier()
    t.note_eviction("swap")
    t.note_eviction("swap")
    t.note_eviction("handoff")
    assert t.stats.evictions == 3
    assert t.stats.swap_evictions == 2
    assert t.stats.handoff_evictions == 1


def test_unbounded_tier_fits_everything():
    t = HostTier(max_bytes=None)
    assert t.can_fit(1 << 40)


# ---------------------------------------------------------------------------
# pool x tier: budget, LRU demotion, int8 byte halving, cache credit
# ---------------------------------------------------------------------------


def _acct_pool(**kw):
    cfg = dict(n_blocks=32, block_size=16, bytes_per_token=4)
    cfg.update(kw)
    return KVBlockPool(KVPoolConfig(**cfg))


def test_host_budget_evicts_oldest_staged_record():
    pool = _acct_pool(host_max_bytes=400)   # one 80-token record (320 B)
    v1 = _decode_victim(pool, arrival=0.0)
    assert pool.host.stats.resident_bytes == 320
    v2 = _decode_victim(pool, arrival=0.5)  # demotes v1: stage-time LRU
    assert pool.swap_state(v1.req_id) is None
    assert pool.swap_state(v2.req_id) is not None
    assert pool.host.stats.swap_evictions == 1
    assert pool.host.stats.resident_bytes == 320
    pool.check_invariants()


def test_host_can_stage_gates_the_budget():
    pool = _acct_pool(host_max_bytes=400)
    assert pool.host_can_stage(100)         # 400 B > 100 tok * 4 B? no: gates
    _decode_victim(pool)
    # the resident record is this pool's own -> evictable, so staging still
    # possible; what can never fit is a record larger than the whole budget
    assert pool.host_can_stage(80)
    assert not pool.host_can_stage(101)     # 404 B > budget even if emptied


def test_swap_out_never_evicts_its_own_fresh_record():
    pool = _acct_pool(host_max_bytes=400)
    v1 = _decode_victim(pool, arrival=0.0, prompt_len=48)   # 192 B resident
    v2 = _decode_victim(pool, arrival=0.5, prompt_len=80)   # needs 320 B
    # v1 (older) was demoted, the NEW record survived
    assert pool.swap_state(v1.req_id) is None
    assert pool.swap_state(v2.req_id) is not None


def test_int8_halves_host_bytes_and_charge():
    pool = _acct_pool(host_kv_dtype="int8", host_max_bytes=10_000)
    assert pool.host_bytes_for(80) == 80 * 4 // 2
    v = _decode_victim(pool)
    rec = pool._swap[v.req_id]
    assert rec.quantized and rec.nbytes == 160
    assert pool.host.stats.resident_bytes == 160
    pool.check_invariants()


def test_quantized_resident_counts_full_toward_cache_credit():
    """An int8-staged token restores a usable token exactly like an fp one:
    resident_tokens (the SLO victim ranking / aging-credit input) must not
    discount the quantized tier."""
    pool = _acct_pool(host_kv_dtype="int8")
    v = _decode_victim(pool)
    assert pool.resident_tokens(v.req_id) == pool.swap_tokens(v.req_id) == 80


def test_attach_host_tier_rejects_populated_pool():
    pool = _acct_pool()
    _decode_victim(pool)
    with pytest.raises(AssertionError):
        pool.attach_host_tier(HostTier(max_bytes=1 << 20))


def test_shared_tier_export_import_is_net_zero():
    tier = HostTier(max_bytes=1000)
    src = _acct_pool(host_max_bytes=None)
    dst = _acct_pool(host_max_bytes=None)
    src.attach_host_tier(tier)
    dst.attach_host_tier(tier)
    store = KVHandoffStore(host_tier=tier)
    v = _decode_victim(src)
    assert tier.stats.resident_bytes == 320
    rec, reg = src.export_swap(v.req_id)
    store.put(v.req_id, rec, reg, src="p0", bytes_per_token=4)
    assert tier.stats.resident_bytes == 320     # store re-charged the release
    rec2, reg2 = store.take(v.req_id)
    dst.import_swap(v.req_id, rec2, reg2)
    assert tier.stats.resident_bytes == 320     # import re-charged the take
    assert tier.stats.put_bytes == 3 * 320      # three charges, two releases
    assert tier.stats.evictions == 0            # net-zero: nobody demoted
    got, _payload = dst.swap_in(v.req_id)
    assert tier.stats.resident_bytes == 0
    dst.release(v.req_id)
    src.check_invariants()
    dst.check_invariants()
    tier.check_invariants()


def test_private_tier_import_demotes_local_records_with_handoff_cause():
    src = _acct_pool(host_max_bytes=None)
    dst = _acct_pool(host_max_bytes=400)
    local = _decode_victim(dst, arrival=0.0)    # dst's own staged record
    v = _decode_victim(src, arrival=1.0)
    rec, reg = src.export_swap(v.req_id)
    dst.import_swap(v.req_id, rec, reg)         # must evict to fit
    assert dst.swap_state(local.req_id) is None
    assert dst.swap_state(v.req_id) is not None
    assert dst.host.stats.handoff_evictions == 1
    dst.check_invariants()


def test_handoff_store_budget_gate_and_ledger():
    store = KVHandoffStore(host_tier=HostTier(max_bytes=300))
    pool = _acct_pool()
    v = _decode_victim(pool)                    # 320 B record
    rec, reg = pool.export_swap(v.req_id)
    assert not store.can_stage(KVHandoffStore.record_bytes(rec, 4))
    store2 = KVHandoffStore(host_tier=HostTier(max_bytes=1000))
    assert store2.can_stage(320)
    store2.put(v.req_id, rec, reg, bytes_per_token=4)
    assert store2.host.stats.resident_bytes == 320
    store2.drop(v.req_id)
    assert store2.host.stats.resident_bytes == 0
    store2.check_invariants()


# ---------------------------------------------------------------------------
# scheduler: swap-in prefetch (leftover capacity only)
# ---------------------------------------------------------------------------


def _acct_sched(pool, **kw):
    cfg = dict(policy="fcfs", token_budget=64, max_seqs=4)
    cfg.update(kw)
    s = ChunkedPrefillScheduler(SchedulerConfig(**cfg), kv_pool=pool)
    s.attach_swap(mode="swap")
    return s


def test_prefetch_restores_with_leftover_capacity_only():
    pool = _acct_pool()
    sched = _acct_sched(pool, swap_prefetch_depth=1)
    big = Request(prompt_len=256, max_new_tokens=4, arrival_time=0.0)
    v = _decode_victim(pool, arrival=1.0)
    sched._swap_round[v.req_id] = sched._round   # as _preempt stamps
    sched.queue.add(big)
    sched.queue.add(v)
    b = sched.schedule(0.0)
    # the budget went to the older prefill; the victim was restored by the
    # END-of-round prefetch pass, not the pop path
    assert [(r.req_id, c) for r, c in b.prefill_chunks] == [(big.req_id, 64)]
    assert [r.req_id for r in b.restored] == [v.req_id]
    assert sched.stats.prefetched_restores == 1
    assert sched.stats.swap_restores == 1
    assert sched.stats.restore_wait_rounds == 1
    # decode-resumable: parked for next round's decode-first pass
    assert v.req_id in sched._decoding and v.needs_replay
    pool.check_invariants()


def test_prefetch_skips_inflight_records():
    """A SWAPPING record (gather not drained) must never be prefetched."""
    pool = _acct_pool()
    sched = _acct_sched(pool, swap_prefetch_depth=2)
    big = Request(prompt_len=256, max_new_tokens=4, arrival_time=0.0)
    v = _decode_victim(pool, arrival=1.0, ready=False)
    sched.queue.add(big)
    sched.queue.add(v)
    b = sched.schedule(0.0)
    assert not b.restored
    assert sched.stats.prefetched_restores == 0
    assert pool.swap_state(v.req_id) is not None


def test_prefetch_depth_zero_is_a_noop():
    pool = _acct_pool()
    sched = _acct_sched(pool)       # depth defaults to 0
    big = Request(prompt_len=256, max_new_tokens=4, arrival_time=0.0)
    v = _decode_victim(pool, arrival=1.0)
    sched.queue.add(big)
    sched.queue.add(v)
    b = sched.schedule(0.0)
    assert not b.restored and sched.stats.prefetched_restores == 0


def test_prefetch_respects_depth_and_oldest_first():
    pool = _acct_pool(n_blocks=64)
    sched = _acct_sched(pool, swap_prefetch_depth=1)
    big = Request(prompt_len=256, max_new_tokens=4, arrival_time=0.0)
    v1 = _decode_victim(pool, arrival=1.0)
    v2 = _decode_victim(pool, arrival=2.0)
    sched._swap_round[v1.req_id] = 0
    sched._swap_round[v2.req_id] = 5    # swapped later
    sched._round = 6
    sched.queue.add(big)
    sched.queue.add(v1)
    sched.queue.add(v2)
    b = sched.schedule(0.0)
    assert [r.req_id for r in b.restored] == [v1.req_id]    # oldest swap first
    assert pool.swap_state(v2.req_id) is not None           # depth respected


# ---------------------------------------------------------------------------
# scheduler: host demotion folds to recompute
# ---------------------------------------------------------------------------


def test_host_demotion_folds_victim_to_recompute():
    pool = _acct_pool(host_max_bytes=400)
    sched = _acct_sched(pool, token_budget=128)
    v1 = _decode_victim(pool, arrival=0.0)
    v2 = _decode_victim(pool, arrival=0.5)   # staging v2 demoted v1
    assert pool.swap_state(v1.req_id) is None
    sched.queue.add(v1)
    sched.queue.add(v2)
    b = sched.schedule(0.0)
    assert sched.stats.host_demotions == 1
    # v1 folded its delivered token into the prompt and re-prefills...
    assert not v1.swapped and v1.prompt_len == 81 and v1.folded_tokens == 1
    assert any(r.req_id == v1.req_id for r, _ in b.prefill_chunks)
    # ...while v2's intact record restored through the ordinary swap path
    assert [r.req_id for r in b.restored] == [v2.req_id]
    assert sched.stats.swap_restores == 1
    pool.check_invariants()


def test_demoted_victim_completes_via_recompute():
    pool = _acct_pool(host_max_bytes=400)
    sched = _acct_sched(pool, token_budget=128)
    v1 = _decode_victim(pool, arrival=0.0)
    _decode_victim(pool, arrival=0.5)
    sched.queue.add(v1)
    for t in range(10):
        if v1.state == RequestState.FINISHED:
            break
        _drive(sched, float(t))
    assert v1.state == RequestState.FINISHED
    assert v1.generated == v1.max_new_tokens
    pool.check_invariants()


def test_restore_backs_off_when_make_room_demotes_its_own_record():
    """_try_restore's room-making can swap-stage a younger block-holder whose
    host charge LRU-evicts the VERY record being restored.  The restore must
    detect the vanished record and defer — next round's demotion fold
    recomputes the request — never hit pool.swap_in's assert."""
    pool = _acct_pool(n_blocks=8, host_max_bytes=400)
    sched = _acct_sched(pool)
    a = _decode_victim(pool, arrival=0.0)       # 320 B staged (LRU-oldest)
    sched._swap_round[a.req_id] = sched._round
    # younger queued prefill holding 4 of 8 blocks: A's 5-block restore must
    # make room, and swap-staging B (256 B) overflows the 400 B budget
    b = Request(prompt_len=80, max_new_tokens=4, arrival_time=1.0,
                prompt_tokens=list(range(80)))
    pool.register_request(b.req_id, prompt_tokens=b.prompt_tokens,
                          prompt_len=80)
    pool.allocate(b.req_id, 64)
    b.prefill_done = 64
    sched.queue.add(a)
    sched.queue.add(b)
    batch = sched.schedule(0.0)
    # B's staging demoted A off the host tier mid-restore ...
    assert pool.host.stats.swap_evictions == 1
    assert pool.swap_state(a.req_id) is None and a.swapped
    # ... so A's restore backed off (deferral, not an assert); B — whose
    # record survived — restored through the ordinary pop path right after
    assert sched.stats.swap_deferrals == 1
    assert not b.swapped and pool.swap_state(b.req_id) is None
    assert sched.stats.swap_restores == 1
    sched.on_batch_done(batch, 0.0)
    # next round: the demotion fold converts A to an ordinary recompute
    sched.schedule(1.0)
    assert sched.stats.host_demotions == 1
    assert not a.swapped and a.prompt_len == 81 and a.folded_tokens == 1
    pool.check_invariants()


# ---------------------------------------------------------------------------
# scheduler: partial swap-in of the decode-hot tail
# ---------------------------------------------------------------------------


def _fragmented_victim():
    """8-block pool: victim staged (5 blocks of KV), an external holder pins
    5 blocks, so a full restore needs 5 free but only 3 exist."""
    pool = _acct_pool(n_blocks=8)
    sched = _acct_sched(pool, partial_restore_after=2)
    v = _decode_victim(pool)
    sched._swap_round[v.req_id] = sched._round
    sched.queue.add(v)
    hold = 9999
    pool.allocate(hold, 80)
    return pool, sched, v, hold


def test_partial_swap_in_shrinks_then_restores_tail():
    pool, sched, v, hold = _fragmented_victim()
    _drive(sched, 0.0)                       # deferral 1
    assert sched.stats.swap_deferrals == 1
    _drive(sched, 1.0)                       # deferral 2 -> shrink + fold
    assert pool.swap_tail_start(v.req_id) == 2
    assert pool.swap_tokens(v.req_id) == 80
    # the fold: prompt absorbs the delivered token; > 0 prompt tokens remain
    # past the staged record, so the completing round books fresh KV
    assert not v.swapped and v.prompt_len == 81 and v.prefill_done == 0
    # the shrink released the prefix's host bytes
    assert pool.host is None or True
    _drive(sched, 2.0)                       # prefix chunk, clipped at s=32
    assert v.prefill_done == 32
    _drive(sched, 3.0)                       # boundary: tail needs 3, 1 free
    assert v.prefill_done == 32 and pool.swap_tail_start(v.req_id) == 2
    pool.release(hold)                       # holder finishes
    b = _drive(sched, 4.0)
    assert sched.stats.partial_restores == 1
    assert sched.stats.tail_restored_tokens == 48
    assert pool.stats.partial_swap_ins == 1
    assert pool.swap_state(v.req_id) is None
    assert v.prefill_done == 81              # jumped past the tail + chunk
    assert [r.req_id for r in b.restored] == [v.req_id]
    for t in range(5, 12):
        if v.state == RequestState.FINISHED:
            break
        _drive(sched, float(t))
    assert v.state == RequestState.FINISHED
    pool.check_invariants()


def test_shrink_skipped_when_restore_is_slot_blocked():
    """Deferrals caused by slots (not memory) must NOT shrink: the full
    restore will succeed as soon as a slot frees, recompute would be waste."""
    pool = _acct_pool(n_blocks=32)
    sched = _acct_sched(pool, partial_restore_after=1)
    sched._slot_binder = lambda r: False     # no slot ever binds
    v = _decode_victim(pool)
    sched.queue.add(v)
    for t in range(4):
        _drive(sched, float(t))
    assert pool.swap_tail_start(v.req_id) == 0   # never shrunk
    assert v.swapped


def test_tail_abort_on_prefix_cache_jump():
    """If the prefix cache jumps prefill past the tail split point the staged
    tail no longer lines up: drop it and fall back to normal prefill."""
    pool, sched, v, hold = _fragmented_victim()
    _drive(sched, 0.0)
    _drive(sched, 1.0)                       # shrunk: s = 32
    assert pool.swap_tail_start(v.req_id) == 2
    v.prefill_done = 48                      # emulate a cache jump past s
    pool.allocate(v.req_id, 48)
    pool.release(hold)
    b = sched.schedule(2.0)
    assert pool.swap_state(v.req_id) is None     # record dropped
    assert sched.stats.tail_aborts == 1
    assert sched.stats.partial_restores == 0
    assert any(r.req_id == v.req_id for r, _ in b.prefill_chunks)
    sched.on_batch_done(b, 2.0)
    for t in range(3, 12):
        if v.state == RequestState.FINISHED:
            break
        _drive(sched, float(t))
    assert v.state == RequestState.FINISHED
    pool.check_invariants()


def test_preempting_tail_pending_victim_keeps_tail_valid():
    """Recompute-preempting a request mid-prefix-re-prefill releases only its
    device blocks; the staged tail stays byte-identical (token ids don't
    change on fold) so the restore later still succeeds."""
    pool, sched, v, hold = _fragmented_victim()
    _drive(sched, 0.0)
    _drive(sched, 1.0)
    _drive(sched, 2.0)                       # prefix_done = 32
    rec_tokens = pool.swap_tokens(v.req_id)
    pool.release(v.req_id)                   # what _preempt(recompute) does
    v.preempt()
    assert pool.swap_tail_start(v.req_id) == 2
    assert pool.swap_tokens(v.req_id) == rec_tokens
    pool.check_invariants()
    pool.release(hold)
    for t in range(3, 14):
        if v.state == RequestState.FINISHED:
            break
        _drive(sched, float(t))
    assert v.state == RequestState.FINISHED
    assert sched.stats.partial_restores == 1
    pool.check_invariants()


def test_should_swap_refuses_when_host_cannot_stage():
    """A tier pinned by co-tenants (shared tier) must push _should_swap to
    recompute — the stage-time reservation can never be allowed to assert."""
    tier = HostTier(max_bytes=600)
    pool = _acct_pool()
    pool.attach_host_tier(tier)
    tier.charge(400)                         # co-tenant pins most of the tier
    sched = _acct_sched(pool)
    v = Request(prompt_len=80, max_new_tokens=4, arrival_time=0.0)
    pool.allocate(v.req_id, 80)
    v.prefill_done = 80
    v.generated = 1
    v.state = RequestState.DECODING
    assert not sched._should_swap(v)         # 320 B > 200 B headroom
    tier.release(400)
    assert sched._should_swap(v)


# ---------------------------------------------------------------------------
# INT8 host pages: kernels vs oracle, error bounds
# ---------------------------------------------------------------------------


_SHAPES = [("split", 2), ("fused", 4)]      # H = Hkv vs 2*Hkv interleaved


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("layout,H", _SHAPES, ids=["split", "fused"])
def test_int8_roundtrip_error_bounded_per_page_per_head(rng, layout, H, dtype):
    pages = jnp.asarray(
        rng.standard_normal((2, 5, 8, H, 4)) * 3.0, dtype=dtype)
    q, scales = quantize_pages(pages)
    assert q.dtype == jnp.int8 and q.shape == pages.shape
    assert scales.shape == (2, 5, 1, H, 1)
    back = dequantize_pages(q, scales, dtype)
    # symmetric absmax: error is at most half a quantization step, per
    # element, with the step set per (layer, page, head)
    err = np.abs(np.asarray(pages, np.float32) - np.asarray(back, np.float32))
    bound = np.asarray(scales) * 0.5 + 1e-6
    if dtype == jnp.bfloat16:
        # the dequant result is re-cast to bf16: allow its relative step too
        bound = bound + np.abs(np.asarray(pages, np.float32)) * 2 ** -8
    assert (err <= bound).all()


def test_int8_quantize_zero_page_is_exact(rng):
    pages = jnp.zeros((1, 2, 4, 2, 4), jnp.float32)
    q, scales = quantize_pages(pages)
    assert not np.asarray(scales).any() or (np.asarray(scales) >= 0).all()
    assert (np.asarray(dequantize_pages(q, scales, jnp.float32)) == 0).all()


@pytest.mark.parametrize("layout,H", _SHAPES, ids=["split", "fused"])
def test_q8_pallas_gather_matches_oracle(rng, layout, H):
    pages = jnp.asarray(rng.standard_normal((2, 9, 8, H, 4)), jnp.float32)
    ids = jnp.asarray([7, 2, 5], jnp.int32)
    q_k, s_k = swap_gather_pages_q8(pages, ids, use_pallas=True,
                                    interpret=True)
    q_o, s_o = quantize_pages(pages[:, ids])
    assert (np.asarray(q_k) == np.asarray(q_o)).all()
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_o), rtol=1e-6)


@pytest.mark.parametrize("layout,H", _SHAPES, ids=["split", "fused"])
def test_q8_pallas_scatter_matches_oracle(rng, layout, H):
    pages = jnp.asarray(rng.standard_normal((2, 9, 8, H, 4)), jnp.float32)
    ids = jnp.asarray([1, 6, 3], jnp.int32)
    q, scales = quantize_pages(
        jnp.asarray(rng.standard_normal((2, 3, 8, H, 4)), jnp.float32))
    # oracle first: the pallas call donates (and deletes) `pages`
    out_o = pages.at[:, ids].set(dequantize_pages(q, scales, pages.dtype))
    out_k = swap_scatter_pages_q8(pages, ids, q, scales, use_pallas=True,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_o),
                               rtol=1e-6, atol=1e-6)


def test_q8_gather_scatter_roundtrip_restores_within_bound(rng):
    """Swap-out then swap-in through the fused int8 kernels: the restored
    pages sit within half a quantization step of the originals."""
    pages = jnp.asarray(rng.standard_normal((2, 9, 8, 2, 4)) * 2.0,
                        jnp.float32)
    ids = jnp.asarray([4, 0, 8], jnp.int32)
    q, scales = swap_gather_pages_q8(pages, ids, use_pallas=True,
                                     interpret=True)
    restored = swap_scatter_pages_q8(
        jnp.zeros_like(pages), ids, q, scales, use_pallas=True,
        interpret=True)
    err = np.abs(np.asarray(pages[:, ids]) - np.asarray(restored[:, ids]))
    assert (err <= np.asarray(scales) * 0.5 + 1e-6).all()


# ---------------------------------------------------------------------------
# end-to-end greedy parity: the hierarchy must be invisible in the tokens
# ---------------------------------------------------------------------------


def test_prefetch_parity_and_fewer_restore_rounds():
    # budget-starved rounds are where prefetch earns its keep: the pop loop
    # exhausts the token budget on queue-front prefills, the END-of-round
    # pass restores ready victims into the capacity the pop loop never saw
    res_p, sched_p, _, reqs_p = _serve_tiered(
        n_blocks=9, token_budget=64, swap_prefetch_depth=2)
    res_s, sched_s, _, _ = _serve_tiered(n_blocks=9, token_budget=64)
    assert sched_p.stats.swap_preemptions > 0
    assert sched_p.stats.prefetched_restores > 0
    # prefetch restores strictly earlier, never later
    assert sched_p.stats.restore_wait_rounds < sched_s.stats.restore_wait_rounds
    _assert_parity(res_p, reqs_p)


def test_host_lru_demotion_parity():
    # room for ~one staged record: concurrent swap-outs demote the oldest
    res, sched, pool, reqs = _serve_tiered(host_max_bytes=320)
    assert sched.stats.swap_preemptions > 0
    assert sched.stats.host_demotions > 0
    assert pool.host.stats.swap_evictions == sched.stats.host_demotions
    assert pool.host.stats.peak_bytes <= 320
    _assert_parity(res, reqs)


def test_int8_host_pages_parity():
    """The committed roundtrip-parity workload: int8 host pages must leave
    greedy outputs bit-identical (quantization error below every argmax
    margin on this workload — the logit-level bound is gated in
    bench_preemption)."""
    res, sched, pool, reqs = _serve_tiered(host_kv_dtype="int8")
    assert sched.stats.swap_preemptions > 0
    assert sched.stats.swap_restores > 0
    _assert_parity(res, reqs)


@pytest.mark.slow
@pytest.mark.parametrize("pipelined", [True, False], ids=["pipelined", "sync"])
@pytest.mark.parametrize("kv_layout", ["split", "fused"])
def test_full_hierarchy_parity_matrix(kv_layout, pipelined):
    """Acceptance gate: prefetch + host LRU + partial swap-in all engaged,
    both layouts x both loop modes, tokens bit-identical to unconstrained."""
    res, sched, pool, reqs = _serve_tiered(
        kv_layout=kv_layout, pipelined=pipelined, n_blocks=9, token_budget=64,
        swap_prefetch_depth=2, host_max_bytes=600, partial_restore_after=2)
    s = sched.stats
    assert s.swap_preemptions > 0
    assert pool.host.stats.peak_bytes > 0
    # the hierarchy actually engaged beyond plain swap (which knob fires
    # varies per layout/loop cell — the per-knob gates have dedicated tests)
    assert (s.prefetched_restores + s.partial_restores
            + s.host_demotions + s.tail_aborts) > 0
    _assert_parity(res, reqs)


@pytest.mark.slow
def test_int8_pallas_hierarchy_parity():
    """Full stack: int8 pallas swap kernels + paged attention + pipelined
    loop + host budget, vs the memoized unconstrained oracle."""
    res, sched, pool, reqs = _serve_tiered(
        pipelined=True, use_pallas=True, host_kv_dtype="int8",
        host_max_bytes=600, swap_prefetch_depth=2)
    assert sched.stats.swap_preemptions > 0
    _assert_parity(res, reqs)


# ---------------------------------------------------------------------------
# property: every live token in exactly one location
# ---------------------------------------------------------------------------


def _count_locations(rid, pools, store):
    n = 0
    for p in pools:
        if p.tables.get(rid):
            n += 1
        if p.swap_state(rid) is not None:
            n += 1
    if rid in store:
        n += 1
    return n


def _run_location_fuzz(ops, dtype):
    """Fuzzed allocate/swap/evict/demote/export/import/release cycles over
    two pools sharing one budget-tight host tier plus a handoff store: after
    every op, each live request's KV is in exactly one of {device table,
    host staging, handoff store} and all three ledgers close."""
    tier = HostTier(max_bytes=512)
    pools = [KVBlockPool(KVPoolConfig(n_blocks=24, block_size=16,
                                      bytes_per_token=4,
                                      host_kv_dtype=dtype))
             for _ in range(2)]
    for p in pools:
        p.attach_host_tier(tier)
    store = KVHandoffStore(host_tier=tier)
    next_rid = [10_000]
    live = {}        # rid -> ("device"|"host", pool_idx) | ("store", src_idx)

    def _check():
        for p in pools:
            p.check_invariants()
        tier.check_invariants()
        s = store.stats
        assert (s.put_bytes - s.taken_bytes - s.dropped_bytes
                - s.expired_bytes == s.resident_bytes)
        for rid in live:
            assert _count_locations(rid, pools, store) == 1, (
                f"req {rid} in {_count_locations(rid, pools, store)} places")
        # a demoted/evicted record vanishes entirely — no half-states
        stats_evictions = tier.stats.evictions
        assert stats_evictions >= 0

    def _sync_demotions():
        # evictions demote records silently: drop vanished rids from `live`
        for rid, (kind, pi) in list(live.items()):
            if kind == "host" and pools[pi].swap_state(rid) is None:
                del live[rid]

    for op, x in ops:
        pi = x % 2
        pool = pools[pi]
        if op == 0:                                   # allocate fresh
            tokens = 16 + (x % 6) * 16
            if pool.can_allocate(next_rid[0], tokens):
                rid = next_rid[0]
                next_rid[0] += 1
                pool.allocate(rid, tokens)
                live[rid] = ("device", pi)
        elif op == 1:                                 # swap out (may demote)
            cands = [r for r, (k, p) in live.items()
                     if k == "device" and p == pi]
            if cands:
                rid = cands[x % len(cands)]
                if pool.host_can_stage(pool.lens[rid]):
                    pool.swap_out(rid, ready=True)
                    live[rid] = ("host", pi)
                    _sync_demotions()
        elif op == 2:                                 # swap in
            cands = [r for r, (k, p) in live.items()
                     if k == "host" and p == pi]
            if cands:
                rid = cands[x % len(cands)]
                if pool.can_swap_in(rid):
                    pool.swap_in(rid)
                    live[rid] = ("device", pi)
        elif op == 3:                                 # drop staging
            cands = [r for r, (k, p) in live.items()
                     if k == "host" and p == pi]
            if cands:
                rid = cands[x % len(cands)]
                pool.drop_swap(rid)
                del live[rid]
        elif op == 4:                                 # export -> store
            cands = [r for r, (k, p) in live.items()
                     if k == "host" and p == pi]
            if cands and len(store) < 4:
                rid = cands[x % len(cands)]
                rec, reg = pool.export_swap(rid)
                store.put(rid, rec, reg, src=f"p{pi}",
                          bytes_per_token=pool.cfg.bytes_per_token)
                live[rid] = ("store", pi)
        elif op == 5:                                 # store -> other pool
            rids = store.req_ids()
            if rids:
                rid = rids[x % len(rids)]
                src = live[rid][1]
                dst = 1 - src
                rec, reg = store.take(rid)
                pools[dst].import_swap(rid, rec, reg)
                live[rid] = ("host", dst)
                _sync_demotions()
        elif op == 6:                                 # release device blocks
            cands = [r for r, (k, p) in live.items()
                     if k == "device" and p == pi]
            if cands:
                rid = cands[x % len(cands)]
                pool.release(rid)
                del live[rid]
        _check()

    # drain: everything still live must come home cleanly
    for rid, (kind, pi) in list(live.items()):
        if kind == "store":
            rec, reg = store.take(rid)
            pools[pi].import_swap(rid, rec, reg)
            live[rid] = ("host", pi)
            _sync_demotions()
    for rid, (kind, pi) in list(live.items()):
        if kind == "host":
            pools[pi].drop_swap(rid)
        else:
            pools[pi].release(rid)
    assert tier.stats.resident_bytes == 0
    store.check_invariants()
    for p in pools:
        p.check_invariants()


@pytest.mark.parametrize("dtype", ["auto", "int8"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_exactly_one_location_fuzz(seed, dtype):
    """Deterministic fuzz (always runs, no hypothesis needed): seeded op
    tapes through the same allocate/swap/evict/demote/handoff state machine."""
    r = np.random.default_rng(seed)
    ops = [(int(r.integers(0, 7)), int(r.integers(0, 1 << 30)))
           for _ in range(80)]
    _run_location_fuzz(ops, dtype)


@pytest.mark.slow
@settings(max_examples=50, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(ops=st.lists(st.tuples(st.integers(0, 6), st.integers(0, 1 << 30)),
                    max_size=40),
       dtype=st.sampled_from(["auto", "int8"]))
def test_exactly_one_location_property(ops, dtype):
    _run_location_fuzz(ops, dtype)


@pytest.mark.slow
@settings(max_examples=40, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(seed=st.integers(0, 2 ** 16), h=st.sampled_from([2, 4]),
       scale=st.floats(0.01, 100.0),
       use_bf16=st.booleans())
def test_int8_roundtrip_property(seed, h, scale, use_bf16):
    dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    r = np.random.default_rng(seed)
    pages = jnp.asarray(r.standard_normal((1, 3, 8, h, 4)) * scale, dtype)
    q, scales = quantize_pages(pages)
    back = dequantize_pages(q, scales, dtype)
    err = np.abs(np.asarray(pages, np.float32) - np.asarray(back, np.float32))
    bound = np.asarray(scales) * 0.5 + 1e-6
    if use_bf16:
        bound = bound + np.abs(np.asarray(pages, np.float32)) * 2 ** -8
    assert (err <= bound).all()
