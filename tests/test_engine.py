"""Real-execution engine integration: chunked_step correctness vs whole-
prompt prefill, the serve loop, KV pool accounting, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig
from repro.engine.sampler import SamplerConfig, sample_tokens
from repro.engine.workload import (
    WorkloadSpec,
    apc_heterogeneous,
    attach_prompt_tokens,
    sharegpt_like,
)
from repro.models.model import build_model


def test_chunked_step_equals_whole_prefill():
    """Splitting a prompt into chunks must produce the same final logits as
    prefilling it in one shot — the core correctness claim of chunked
    prefill (the schedule changes, the math must not)."""
    cfg = tiny_config("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1, cfg.vocab_size)

    # whole-shot reference
    ref_logits, _ = model.prefill(params, {"tokens": tokens})

    # chunked: 3 rounds of 16 via chunked_step
    impl = model.impl
    hd = cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    lens = jnp.zeros((B,), jnp.int32)
    C = 16
    for i in range(3):
        chunk = tokens[:, i * C:(i + 1) * C]
        logits, cache = impl.chunked_step(
            params, chunk, cache, lens, jnp.full((B,), C, jnp.int32)
        )
        lens = lens + C

    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.25, rtol=0.05,  # bf16 accumulation-order tolerance
    )
    # argmax (the sampled token) must agree
    assert (np.argmax(np.asarray(logits, np.float32), -1)
            == np.argmax(np.asarray(ref_logits, np.float32), -1)).all()


def test_chunked_step_mixed_decode_and_prefill():
    """One round advancing a decode slot (chunk 1) and a prefill slot
    (chunk 16) together — Sarathi's mixed batch."""
    cfg = tiny_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    impl = model.impl
    B, S = 2, 64
    hd = cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    lens = jnp.zeros((B,), jnp.int32)
    # slot 0: prefill 16 tokens; slot 1: idle
    toks = jnp.ones((B, 16), jnp.int32)
    logits, cache = impl.chunked_step(
        params, toks, cache, lens, jnp.array([16, 0], jnp.int32)
    )
    lens = lens + jnp.array([16, 0])
    # now slot 0 decodes (chunk 1), slot 1 prefills 8
    toks2 = jnp.ones((B, 8), jnp.int32)
    logits2, cache = impl.chunked_step(
        params, toks2, cache, lens, jnp.array([1, 8], jnp.int32)
    )
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("policy", ["fcfs", "aging"])
def test_serve_end_to_end(policy):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=8, max_context=256))
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=6, inter_arrival_s=0.01, max_context=100,
        max_new_tokens=8, seed=7,
    ))
    attach_prompt_tokens(reqs, cfg.vocab_size)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy=policy, token_budget=48, max_seqs=8)
    )
    res = serve(reqs, sched, eng, collect_samples=True)
    assert res.report.n_finished == 6
    assert all(len(res.outputs[r.req_id]) == r.max_new_tokens for r in reqs)
    feats, lats = res.samples
    assert feats.shape[1] == 16 and (lats > 0).all()


def test_serve_with_pallas_kernels():
    """Same serve loop with the Pallas chunked-prefill kernel (interpret)."""
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=4, max_context=128, use_pallas=True))
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=2, inter_arrival_s=0.01, max_context=48,
        max_new_tokens=4, seed=9,
    ))
    attach_prompt_tokens(reqs, cfg.vocab_size)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="aging", token_budget=32, max_seqs=4)
    )
    res = serve(reqs, sched, eng)
    assert res.report.n_finished == 2


# ---------------------------------------------------------------------------
# paged vs dense determinism (the tentpole's correctness claim)
# ---------------------------------------------------------------------------


def _two_wave_shared_prefix(seed=5):
    """shared_prefix in two deterministic waves: wave 1 all at t=0 (forces
    concurrency -> KV preemption on a small pool), wave 2 far behind it (the
    idle-gap jump admits it atomically AFTER wave 1 sealed its prefix blocks,
    so the prefix-restore path is exercised deterministically)."""
    from repro.engine.workload import shared_prefix
    reqs = shared_prefix(n_requests=12, n_prefixes=2, prefix_len=48,
                         suffix_range=(8, 16), max_new_tokens=10,
                         inter_arrival_s=0.0, vocab_size=512, seed=seed)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.0 if i < 6 else 60.0
    return reqs


def _serve_paged_or_dense(paged: bool):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=paged, seed=3))
    # 11 blocks cannot hold the shared prefixes plus 6 growing decode tails
    # (prefix sharing kicks in even within a wave: later binders hit the
    # first binder's sealed blocks): preemption forced
    pool = KVBlockPool(KVPoolConfig(n_blocks=11, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    reqs = _two_wave_shared_prefix()
    res = serve(reqs, sched, eng, kv_pool=pool)
    pool.check_invariants()
    return res, sched, pool, reqs


def test_paged_and_dense_greedy_outputs_identical_with_preemption():
    """Greedy-sampled outputs of the paged engine must be identical to the
    dense engine's on a shared-prefix workload — including after forced KV
    preemptions and across prefix-cache restores (paged restores are
    zero-copy: the matched pages are still resident)."""
    res_p, sched_p, pool_p, reqs_p = _serve_paged_or_dense(paged=True)
    res_d, sched_d, pool_d, reqs_d = _serve_paged_or_dense(paged=False)
    # the adversarial conditions actually happened, in both layouts
    assert sched_p.stats.preemptions > 0 and sched_d.stats.preemptions > 0
    assert pool_p.stats.hit_tokens > 0 and pool_d.stats.hit_tokens > 0
    assert res_p.report.n_finished == res_d.report.n_finished == 12
    # the comparison must be over REAL sampled ids, not placeholder zeros
    assert any(t != 0 for out in res_p.outputs.values() for t in out)
    # req_ids are globally assigned: match requests by workload position
    for rp, rd in zip(reqs_p, reqs_d):
        assert res_p.outputs[rp.req_id] == res_d.outputs[rd.req_id], (
            rp.req_id, rd.req_id,
        )


# ---------------------------------------------------------------------------
# warmup coverage: the first serving round never pays a cold compile
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_layout,depth",
                         [("split", 1), ("split", 2),
                          ("fused", 1), ("fused", 2)],
                         ids=["split-d1", "split-d2", "fused-d1", "fused-d2"])
def test_warmup_covers_every_configured_shape(kv_layout, depth):
    """After ``warmup(include_swap=True)`` a pressured serve — every chunk
    bucket, forced swap-outs and restores — must add ZERO new entries to the
    engine step's jit cache or the swap kernels', for every configured
    ``(kv_layout, buffering_depth)``: no serving round ever eats a cold XLA
    compile."""
    from repro.kernels.swap import swap_gather_pages, swap_scatter_pages

    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=6, max_context=128,
                                      paged_kv=True, pipelined=True,
                                      kv_layout=kv_layout,
                                      buffering_depth=depth,
                                      preemption_mode="swap", seed=3))
    pool = KVBlockPool(KVPoolConfig(n_blocks=11, block_size=16,
                                    bytes_per_token=4,
                                    enable_prefix_cache=True))
    # bind BEFORE warmup: the pool's geometry shapes the cache array
    eng.bind_kv_pool(pool)
    eng.warmup(include_swap=True)
    n_step = eng._step._cache_size()
    n_gather = swap_gather_pages._cache_size()
    n_scatter = swap_scatter_pages._cache_size()

    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=96, max_seqs=6)
    )
    reqs = _two_wave_shared_prefix()
    res = serve(reqs, sched, eng, kv_pool=pool)
    assert res.report.n_finished == len(reqs)
    assert sched.stats.swap_preemptions > 0        # pressure actually bit
    assert eng._step._cache_size() == n_step
    assert swap_gather_pages._cache_size() == n_gather
    assert swap_scatter_pages._cache_size() == n_scatter


# ---------------------------------------------------------------------------
# late slot binding (slot lifecycle regression)
# ---------------------------------------------------------------------------


def _rate_limited_setup(n_slots=2):
    from repro.tenancy import FairnessConfig, TenantSpec
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=n_slots, max_context=128))
    fc = FairnessConfig(
        tenants=(
            TenantSpec("limited", rate_tokens_per_s=200.0, burst_tokens=50.0),
            TenantSpec("free"),
        ),
        admission_policy="queue",
    )
    sched = ChunkedPrefillScheduler(SchedulerConfig(
        policy="fcfs", token_budget=64, max_seqs=n_slots, fairness=fc,
    ))
    limited = [Request(prompt_len=40, max_new_tokens=2, arrival_time=0.0,
                       tenant="limited") for _ in range(5)]
    free = [Request(prompt_len=16, max_new_tokens=4,
                    arrival_time=0.001 * (i + 1), tenant="free")
            for i in range(3)]
    return cfg, eng, sched, limited, free


def test_delayed_admissions_pin_no_slots():
    """Regression (ROADMAP slot-lifecycle bug): a rate-limited tenant's
    delayed backlog used to receive engine slots at admission and hold them
    while parked, exhausting ``n_slots``.  Slots now bind at first schedule,
    so the delay pen pins nothing and other tenants schedule immediately."""
    _cfg, eng, sched, limited, free = _rate_limited_setup()
    sched.attach_slot_binder(eng.acquire_slot, releaser=eng.release)
    for r in limited + free:
        assert sched.submit(r)          # over-budget ones are parked, not rejected
    delayed = [r for r in limited if sched.queue.is_delayed(r)]
    assert len(delayed) >= 3            # the backlog exceeds n_slots=2
    batch = sched.schedule(0.0)
    scheduled = {r.req_id for r, _ in batch.prefill_chunks}
    # the free tenant got a slot this very round, through the parked backlog
    assert scheduled & {r.req_id for r in free}
    # no delay-parked request holds an engine slot
    assert not any(r.req_id in eng.slot_of for r in delayed)
    assert len(eng.slot_of) <= 2


def test_zero_progress_deferral_unbinds_slot():
    """A request that binds a slot but cannot allocate a single KV token
    (pool held by a strictly-older request: no eligible victim) must NOT pin
    the slot while deferred — it unbinds and re-binds when it can run."""
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=2, max_context=128))
    pool = KVBlockPool(KVPoolConfig(n_blocks=4, block_size=16, bytes_per_token=4))
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=64, max_seqs=2), kv_pool=pool
    )
    eng.bind_kv_pool(pool)
    sched.attach_slot_binder(eng.acquire_slot, releaser=eng.release)
    old = Request(prompt_len=60, max_new_tokens=2, arrival_time=0.0)
    young = Request(prompt_len=32, max_new_tokens=2, arrival_time=1.0)
    sched.submit(old)
    sched.submit(young)
    batch = sched.schedule(0.0)
    # old's chunk takes the whole pool; young bound a slot, got a zero chunk
    # (no strictly-younger victim exists), and must have been unbound again
    assert [(r.req_id, c) for r, c in batch.prefill_chunks] == [(old.req_id, 60)]
    assert old.req_id in eng.slot_of
    assert young.req_id not in eng.slot_of
    assert len(eng.free_slots) == 1
    # drain: old finishes, its blocks free, young re-binds and completes
    now, rounds = 0.0, 0
    sched.on_batch_done(batch, 0.01)
    while sched.has_work() and rounds < 100:
        now += 0.01
        rounds += 1
        b = sched.schedule(now)
        if not b.is_empty():
            sched.on_batch_done(b, now)
    assert old.state == RequestState.FINISHED
    assert young.state == RequestState.FINISHED
    pool.check_invariants()


def test_rate_limited_backlog_does_not_starve_other_tenants_e2e():
    """End-to-end serve(): with 5 delayed requests from a rate-limited tenant
    against 2 engine slots, the unlimited tenant's requests all finish, and
    they get service ahead of the parked backlog's tail."""
    cfg, eng, sched, limited, free = _rate_limited_setup()
    reqs = limited + free
    attach_prompt_tokens(reqs, cfg.vocab_size)
    res = serve(reqs, sched, eng, max_rounds=6000)
    assert all(r.state == RequestState.FINISHED for r in free)
    assert res.report.n_finished == 8   # the backlog itself drains too
    assert max(r.ttft() for r in free) < max(r.ttft() for r in limited)


# ---------------------------------------------------------------------------
# KV pool
# ---------------------------------------------------------------------------


def test_kv_pool_alloc_release_cycle():
    pool = KVBlockPool(KVPoolConfig(n_blocks=10, block_size=16, bytes_per_token=4))
    assert pool.can_allocate(1, 100)          # 7 blocks
    pool.allocate(1, 100)
    assert pool.used_blocks == 7
    pool.allocate(1, 12)                      # fits in block 7
    assert pool.used_blocks == 7
    pool.allocate(1, 10)                      # crosses into block 8
    assert pool.used_blocks == 8
    assert not pool.can_allocate(2, 40)       # needs 3, only 2 free
    pool.release(1)
    assert pool.used_blocks == 0
    assert pool.can_allocate(2, 160)


def test_kv_pool_exhaustion_raises():
    pool = KVBlockPool(KVPoolConfig(n_blocks=2, block_size=16))
    with pytest.raises(MemoryError):
        pool.allocate(1, 100)


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------


def test_sampler_greedy():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, -1.0]])
    out = sample_tokens(logits, jax.random.PRNGKey(0), SamplerConfig())
    assert list(np.asarray(out)) == [1, 0]


def test_sampler_topk_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]] * 64)
    out = sample_tokens(
        logits, jax.random.PRNGKey(0),
        SamplerConfig(temperature=1.0, top_k=2),
    )
    assert set(np.asarray(out).tolist()) <= {1, 2}


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------


def test_sharegpt_like_is_skewed_and_seeded():
    spec = WorkloadSpec(n_requests=500, seed=4)
    a = sharegpt_like(spec)
    b = sharegpt_like(spec)
    assert [r.prompt_len for r in a] == [r.prompt_len for r in b]
    ps = np.asarray([r.prompt_len for r in a])
    assert np.percentile(ps, 50) < 60          # short median
    assert np.percentile(ps, 90) > 90          # heavy tail


def test_apc_heterogeneous_ratio():
    reqs = apc_heterogeneous(n_requests=500, seed=1)
    short = sum(1 for r in reqs if r.prompt_len <= 50)
    long_ = sum(1 for r in reqs if r.prompt_len >= 200)
    assert short + long_ == 500
    assert abs(short / 500 - 0.98) < 0.02      # 49:1
