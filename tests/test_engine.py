"""Real-execution engine integration: chunked_step correctness vs whole-
prompt prefill, the serve loop, KV pool accounting, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig, pool_for_model
from repro.engine.sampler import SamplerConfig, sample_tokens
from repro.engine.workload import (
    WorkloadSpec, apc_heterogeneous, attach_prompt_tokens, sharegpt_like,
    uniform_arrivals,
)
from repro.models.model import build_model


def test_chunked_step_equals_whole_prefill():
    """Splitting a prompt into chunks must produce the same final logits as
    prefilling it in one shot — the core correctness claim of chunked
    prefill (the schedule changes, the math must not)."""
    cfg = tiny_config("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1, cfg.vocab_size)

    # whole-shot reference
    ref_logits, _ = model.prefill(params, {"tokens": tokens})

    # chunked: 3 rounds of 16 via chunked_step
    impl = model.impl
    hd = cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    lens = jnp.zeros((B,), jnp.int32)
    C = 16
    for i in range(3):
        chunk = tokens[:, i * C:(i + 1) * C]
        logits, cache = impl.chunked_step(
            params, chunk, cache, lens, jnp.full((B,), C, jnp.int32)
        )
        lens = lens + C

    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.25, rtol=0.05,  # bf16 accumulation-order tolerance
    )
    # argmax (the sampled token) must agree
    assert (np.argmax(np.asarray(logits, np.float32), -1)
            == np.argmax(np.asarray(ref_logits, np.float32), -1)).all()


def test_chunked_step_mixed_decode_and_prefill():
    """One round advancing a decode slot (chunk 1) and a prefill slot
    (chunk 16) together — Sarathi's mixed batch."""
    cfg = tiny_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    impl = model.impl
    B, S = 2, 64
    hd = cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, B, S + 1, cfg.n_kv_heads, hd), jnp.bfloat16),
    }
    lens = jnp.zeros((B,), jnp.int32)
    # slot 0: prefill 16 tokens; slot 1: idle
    toks = jnp.ones((B, 16), jnp.int32)
    logits, cache = impl.chunked_step(
        params, toks, cache, lens, jnp.array([16, 0], jnp.int32)
    )
    lens = lens + jnp.array([16, 0])
    # now slot 0 decodes (chunk 1), slot 1 prefills 8
    toks2 = jnp.ones((B, 8), jnp.int32)
    logits2, cache = impl.chunked_step(
        params, toks2, cache, lens, jnp.array([1, 8], jnp.int32)
    )
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("policy", ["fcfs", "aging"])
def test_serve_end_to_end(policy):
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=8, max_context=256))
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=6, inter_arrival_s=0.01, max_context=100,
        max_new_tokens=8, seed=7,
    ))
    attach_prompt_tokens(reqs, cfg.vocab_size)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy=policy, token_budget=48, max_seqs=8)
    )
    res = serve(reqs, sched, eng, collect_samples=True)
    assert res.report.n_finished == 6
    assert all(len(res.outputs[r.req_id]) == r.max_new_tokens for r in reqs)
    feats, lats = res.samples
    assert feats.shape[1] == 16 and (lats > 0).all()


def test_serve_with_pallas_kernels():
    """Same serve loop with the Pallas chunked-prefill kernel (interpret)."""
    cfg = tiny_config("qwen1.5-0.5b")
    eng = JAXEngine(cfg, EngineConfig(n_slots=4, max_context=128, use_pallas=True))
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=2, inter_arrival_s=0.01, max_context=48,
        max_new_tokens=4, seed=9,
    ))
    attach_prompt_tokens(reqs, cfg.vocab_size)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="aging", token_budget=32, max_seqs=4)
    )
    res = serve(reqs, sched, eng)
    assert res.report.n_finished == 2


# ---------------------------------------------------------------------------
# KV pool
# ---------------------------------------------------------------------------


def test_kv_pool_alloc_release_cycle():
    pool = KVBlockPool(KVPoolConfig(n_blocks=10, block_size=16, bytes_per_token=4))
    assert pool.can_allocate(1, 100)          # 7 blocks
    pool.allocate(1, 100)
    assert pool.used_blocks == 7
    pool.allocate(1, 12)                      # fits in block 7
    assert pool.used_blocks == 7
    pool.allocate(1, 10)                      # crosses into block 8
    assert pool.used_blocks == 8
    assert not pool.can_allocate(2, 40)       # needs 3, only 2 free
    pool.release(1)
    assert pool.used_blocks == 0
    assert pool.can_allocate(2, 160)


def test_kv_pool_exhaustion_raises():
    pool = KVBlockPool(KVPoolConfig(n_blocks=2, block_size=16))
    with pytest.raises(MemoryError):
        pool.allocate(1, 100)


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------


def test_sampler_greedy():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, -1.0]])
    out = sample_tokens(logits, jax.random.PRNGKey(0), SamplerConfig())
    assert list(np.asarray(out)) == [1, 0]


def test_sampler_topk_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]] * 64)
    out = sample_tokens(
        logits, jax.random.PRNGKey(0),
        SamplerConfig(temperature=1.0, top_k=2),
    )
    assert set(np.asarray(out).tolist()) <= {1, 2}


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------


def test_sharegpt_like_is_skewed_and_seeded():
    spec = WorkloadSpec(n_requests=500, seed=4)
    a = sharegpt_like(spec)
    b = sharegpt_like(spec)
    assert [r.prompt_len for r in a] == [r.prompt_len for r in b]
    ps = np.asarray([r.prompt_len for r in a])
    assert np.percentile(ps, 50) < 60          # short median
    assert np.percentile(ps, 90) > 90          # heavy tail


def test_apc_heterogeneous_ratio():
    reqs = apc_heterogeneous(n_requests=500, seed=1)
    short = sum(1 for r in reqs if r.prompt_len <= 50)
    long_ = sum(1 for r in reqs if r.prompt_len >= 200)
    assert short + long_ == 500
    assert abs(short / 500 - 0.98) < 0.02      # 49:1
