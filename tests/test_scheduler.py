"""Integration + property tests for the full scheduling round (§3.1.3):
decode-first, budget conservation, APC interaction, request lifecycle."""
import pytest
from _hyp import given, settings, st

from repro.core.apc import APCConfig
from repro.core.lprs import LPRSConfig
from repro.core.predictor import AnalyticPredictor
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.simulator import run_policy
from repro.engine.workload import WorkloadSpec, sharegpt_like


def mk_sched(**kw):
    return ChunkedPrefillScheduler(SchedulerConfig(**kw))


# ---------------------------------------------------------------------------
# invariants of one scheduling round
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    prompts=st.lists(st.integers(1, 900), min_size=1, max_size=30),
    budget=st.integers(8, 1024),
    max_seqs=st.integers(1, 64),
    policy=st.sampled_from(["fcfs", "sjf", "aging"]),
)
def test_round_respects_budget_and_seqs(prompts, budget, max_seqs, policy):
    sched = mk_sched(policy=policy, token_budget=budget, max_seqs=max_seqs)
    for i, p in enumerate(prompts):
        sched.submit(Request(prompt_len=p, max_new_tokens=4, arrival_time=i * 0.01))
    batch = sched.schedule(now=10.0)
    assert batch.total_tokens <= budget
    assert batch.n_seqs <= max_seqs
    for req, c in batch.prefill_chunks:
        assert 1 <= c <= req.remaining_prefill


def test_decode_first_reserves_budget():
    """Ongoing decodes are admitted before any prefill (§3.1.3)."""
    sched = mk_sched(policy="fcfs", token_budget=8, max_seqs=16)
    # drive 6 requests through their full prefill so they decode
    for i in range(6):
        sched.submit(Request(prompt_len=4, max_new_tokens=8, arrival_time=0.0))
    for _ in range(4):
        b = sched.schedule(now=1.0)
        sched.on_batch_done(b, now=1.0)
    assert len(sched.decoding) > 0
    n_decoding = len(sched.decoding)
    sched.submit(Request(prompt_len=100, max_new_tokens=4, arrival_time=2.0))
    batch = sched.schedule(now=2.0)
    assert batch.decode_tokens == min(n_decoding, 8)
    # prefill only gets the residual
    assert batch.prefill_tokens <= 8 - batch.decode_tokens


def test_request_lifecycle_to_completion():
    sched = mk_sched(policy="aging", token_budget=64, max_seqs=4)
    req = Request(prompt_len=150, max_new_tokens=3, arrival_time=0.0)
    sched.submit(req)
    now = 0.0
    for _ in range(50):
        if req.state == RequestState.FINISHED:
            break
        b = sched.schedule(now)
        now += 0.01
        sched.on_batch_done(b, now)
    assert req.state == RequestState.FINISHED
    assert req.prefill_done == 150
    assert req.generated == 3
    assert sum(req.chunks) == 150
    assert req.ttft() is not None and req.e2e_latency() is not None
    # chunked prefill: 150 tokens under a 64 budget takes >= 3 chunks
    assert len(req.chunks) >= 3


def test_unfinished_prefill_returns_to_queue_with_updated_priority():
    sched = mk_sched(policy="aging", alpha=1.0, beta=-0.01,
                     token_budget=64, max_seqs=4)
    req = Request(prompt_len=500, max_new_tokens=2, arrival_time=0.0)
    sched.submit(req)
    b = sched.schedule(0.0)
    assert b.prefill_chunks[0][0] is req
    sched.on_batch_done(b, 0.1)
    assert req.state == RequestState.PREFILLING
    assert req in sched.queue
    assert req.remaining_prefill == 500 - b.prefill_chunks[0][1]


def test_apc_caps_active_prefills_per_round():
    """With LPRS choosing small chunks (so the budget is NOT the binding
    constraint), the activity cap limits concurrent unfinished prefills."""
    pred = AnalyticPredictor(c0=2.0, c_prefill=0.05, c_decode=0.0)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(
            policy="fcfs", token_budget=4096, max_seqs=32,
            lprs=LPRSConfig(target_latency_ms=10.0, search_delta=32),
            apc=APCConfig(c_max=2, l_min=32),
        ),
        predictor=pred,
    )
    for i in range(10):
        sched.submit(Request(prompt_len=2000, max_new_tokens=2, arrival_time=0.0))
    b = sched.schedule(0.0)
    # unfinished prefills in the batch never exceed the cap
    active = sum(1 for req, c in b.prefill_chunks if req.remaining_prefill > c)
    assert active <= 2
    # once the round saturates the latency target, LPRS proposes fragment
    # chunks and APC intervenes (Table 10's intervention counters)
    st_ = sched.stats.apc
    assert st_.blocked_by_cap + st_.blocked_by_min_chunk >= 1


def test_lprs_scheduler_integration():
    pred = AnalyticPredictor(c0=2.0, c_prefill=0.05, c_decode=0.1)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="aging", token_budget=2048, max_seqs=8,
                        lprs=LPRSConfig(target_latency_ms=20.0, search_delta=32)),
        predictor=pred,
    )
    sched.submit(Request(prompt_len=4000, max_new_tokens=2, arrival_time=0.0))
    b = sched.schedule(0.0)
    assert len(b.prefill_chunks) == 1
    c = b.prefill_chunks[0][1]
    # analytic: 2 + 0.05c <= 20  =>  c <= 360
    assert c <= 360
    assert c >= 360 - 32 - 1


def test_lprs_requires_predictor():
    with pytest.raises(ValueError):
        ChunkedPrefillScheduler(
            SchedulerConfig(lprs=LPRSConfig()), predictor=None
        )


# ---------------------------------------------------------------------------
# end-to-end conservation over the simulator
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(5, 40),
    policy=st.sampled_from(["fcfs", "sjf", "aging"]),
    budget=st.sampled_from([64, 256, 1024]),
)
def test_all_requests_complete_and_conserve_tokens(n, policy, budget):
    from repro.core.scheduler import SchedulerConfig

    reqs = sharegpt_like(WorkloadSpec(n_requests=n, inter_arrival_s=0.01, seed=n))
    res = run_policy(reqs, SchedulerConfig(policy=policy, token_budget=budget,
                                           max_seqs=32))
    assert res.report.n_finished == n
    for r in reqs:
        assert r.prefill_done == r.prompt_len
        assert sum(r.chunks) == r.prompt_len
        assert r.generated == r.max_new_tokens
        assert r.finish_time >= r.arrival_time
        # TTFT <= E2E, prefill time <= TTFT (first token == prefill done)
        assert r.ttft() <= r.e2e_latency() + 1e-9
        assert r.prefill_e2e() <= r.ttft() + 1e-9
    # scheduler stats conserve scheduled tokens
    st_ = res.scheduler_stats
    assert st_.scheduled_prefill_tokens == sum(r.prompt_len for r in reqs)
    assert st_.scheduled_decode_tokens == sum(r.max_new_tokens - 1 for r in reqs)
