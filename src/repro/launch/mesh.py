"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — critical because the dry-run needs
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` set *before* the
first jax device query, while smoke tests/benches must see 1 CPU device.

Mesh shapes (TPU v5e pods, 256 chips each):
  single-pod:  (16, 16)      axes ("data", "model")
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")

The "model" axis carries TP / EP / (serving) 2D weight sharding; "data"
carries DP / FSDP / sequence-sharded KV; "pod" is pure data parallelism over
pods (DCN-connected), matching the paper's centralized-scheduler +
SPMD-worker deployment scaled to multi-pod.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (hillclimb sweeps over layouts)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_model: Optional[int] = None) -> Mesh:
    """Tiny mesh over whatever devices exist (CPU tests: 1 device)."""
    n = len(jax.devices())
    nm = n_model or 1
    return jax.make_mesh((n // nm, nm), ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link
