"""Trip-count-aware cost analysis over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` (a) reports per-device numbers
for SPMD programs and (b) counts ``while`` bodies ONCE, ignoring trip counts.
Our models scan over layers (and microbatches, seq chunks), so XLA's own
numbers undercount FLOPs/bytes/collectives by ~n_layers.  This module parses
``compiled.as_text()`` and walks the call graph with while-loop trip counts
multiplied through, producing per-device:

  * flops             — dot/conv exact, elementwise/reduce ~1 flop/element
  * hbm_bytes         — per-op operand+result traffic; fusions count only
                        their boundary tensors; dynamic-slice counts the
                        slice, not the sliced buffer (weight streaming via
                        scan is therefore counted once per iteration)
  * collective bytes  — per collective type, trip-count multiplied, using a
                        fixed link-traffic convention:
                          all-gather          -> result bytes
                          reduce-scatter      -> operand bytes
                          all-reduce          -> 2 x operand bytes (ring)
                          all-to-all          -> operand bytes
                          collective-permute  -> operand bytes

All quantities are PER DEVICE (the SPMD module is the per-device program);
roofline terms divide by per-chip peaks directly.

The parser is validated in tests/test_hlo_cost.py against programs with
analytically known costs (scan-of-matmul etc.).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

# ops that are aliases/bookkeeping, not data movement
_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "bitcast", "constant",
    "partition-id", "replica-id", "after-all", "opt-barrier", "domain",
    "get-dimension-size", "iota",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TRANSCENDENTAL = {"exp", "expm1", "log", "log1p", "tanh", "rsqrt", "sqrt",
                   "power", "sine", "cosine", "logistic", "erf", "atan2",
                   "cbrt", "divide"}


@dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


def parse_shapes(s: str) -> List[Shape]:
    """All tensor shapes appearing in an HLO type string (tuples flattened)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        out.append(Shape(dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _total_bytes(shapes: List[Shape]) -> int:
    return sum(s.bytes for s in shapes)


@dataclass
class Op:
    name: str
    opcode: str
    result: List[Shape]
    operands: List[str]
    attrs: str

    def attr_call(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", self.attrs)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    by_name: Dict[str, Op] = field(default_factory=dict)

    def shape_of(self, operand: str) -> List[Shape]:
        op = self.by_name.get(operand)
        return op.result if op else []


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _split_type_rest(s: str) -> Tuple[str, str]:
    """'(s32[], f32[2]{0}) tuple(...)' -> (type_str, rest)."""
    s = s.strip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[: i + 1], s[i + 1:].strip()
    m = re.match(r"^([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*(.*)$", s)
    if m:
        return m.group(1), m.group(2)
    # scalar without brackets shouldn't happen in HLO; bail
    parts = s.split(None, 1)
    return parts[0], parts[1] if len(parts) > 1 else ""


def _matching_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and ("->" in line):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        type_str, tail = _split_type_rest(rest)
        # strip metadata (can contain parens/braces)
        meta = tail.find(", metadata=")
        if meta >= 0:
            tail = tail[:meta]
        om = re.match(r"^([\w\-]+)\s*\(", tail)
        if not om:
            continue
        opcode = om.group(1)
        p0 = tail.find("(")
        p1 = _matching_paren(tail, p0)
        operand_str = tail[p0 + 1 : p1]
        attrs = tail[p1 + 1 :]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        if opcode == "parameter":
            # preserve the parameter index (lives in the operand slot)
            attrs = f"parameter({operand_str}){attrs}"
        op = Op(name, opcode, parse_shapes(type_str), operands, attrs)
        cur.ops.append(op)
        cur.by_name[name] = op
    return comps, entry


@dataclass
class CostReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )
    n_collective_ops: int = 0
    n_while_loops: int = 0
    unknown_ops: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def merge_scaled(self, other: "CostReport", k: float) -> None:
        self.flops += k * other.flops
        self.hbm_bytes += k * other.hbm_bytes
        for c in _COLLECTIVES:
            self.collective_bytes[c] += k * other.collective_bytes[c]
        self.n_collective_ops += int(k * other.n_collective_ops)
        self.n_while_loops += other.n_while_loops
        for o, n in other.unknown_ops.items():
            self.unknown_ops[o] = self.unknown_ops.get(o, 0) + n


class HloCostAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: Dict[str, CostReport] = {}

    # -- helpers -------------------------------------------------------------

    def trip_count(self, cond_name: str) -> int:
        """Max scalar int constant in the while condition computation.

        jax scans lower to (i < N) loops with i0=0, step 1, so the loop-bound
        constant IS the trip count.  The condition may delegate the compare to
        a fused computation, but the bound constant is materialized in the
        condition region itself.  Falls back to 1 if unparseable.
        """
        vals = self._const_values.get(cond_name, [])
        return max(vals) if vals else 1

    # -- flops per op --------------------------------------------------------

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_elems = sum(s.elems for s in op.result)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        lhs_shapes = comp.shape_of(op.operands[0]) if op.operands else []
        if not m or not lhs_shapes:
            return 2.0 * out_elems
        lhs = lhs_shapes[0]
        k = 1
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs.dims):
                k *= lhs.dims[int(d)]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        out_elems = sum(s.elems for s in op.result)
        if len(op.operands) < 2:
            return 2.0 * out_elems
        kshapes = comp.shape_of(op.operands[1])
        if not kshapes:
            return 2.0 * out_elems
        kelems = kshapes[0].elems
        # per output element: kernel_elems / out_features macs
        out_feat = 1
        for s in op.result:
            if s.dims:
                out_feat = s.dims[-1]
        return 2.0 * out_elems * max(1, kelems // max(out_feat, 1))

    # -- analysis ------------------------------------------------------------

    def analyze_computation(self, name: str, *, fused: bool = False) -> CostReport:
        key = f"{name}|fused={fused}"
        if key in self._memo:
            return self._memo[key]
        rep = CostReport()
        comp = self.comps.get(name)
        if comp is None:
            self._memo[key] = rep
            return rep
        for op in comp.ops:
            oc = op.opcode
            out_elems = sum(s.elems for s in op.result)
            out_bytes = _total_bytes(op.result)
            operand_bytes = sum(
                _total_bytes(comp.shape_of(o)) for o in op.operands
            )

            if oc in _FREE_OPS:
                continue

            if oc in _COLLECTIVES or any(oc == c + "-start" for c in _COLLECTIVES):
                base = oc.replace("-start", "")
                if base == "all-gather":
                    vol = out_bytes
                elif base == "all-reduce":
                    vol = 2 * operand_bytes
                else:
                    vol = operand_bytes
                rep.collective_bytes[base] += vol
                rep.n_collective_ops += 1
                if not fused:
                    rep.hbm_bytes += out_bytes + operand_bytes
                continue
            if oc.endswith("-done") or oc in ("copy-start", "copy-done"):
                continue

            if oc == "while":
                body = op.attr_call("body")
                cond = op.attr_call("condition")
                trips = self.trip_count(cond) if cond else 1
                rep.n_while_loops += 1
                body_rep = self.analyze_computation(body) if body else CostReport()
                rep.merge_scaled(body_rep, trips)
                continue

            if oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", op.attrs)
                names = []
                if branches:
                    names = re.findall(r"%?([\w.\-]+)", branches[0])
                else:
                    tc = op.attr_call("true_computation")
                    fc = op.attr_call("false_computation")
                    names = [n for n in (tc, fc) if n]
                subs = [self.analyze_computation(n) for n in names]
                if subs:
                    biggest = max(subs, key=lambda r: r.flops)
                    rep.merge_scaled(biggest, 1.0)
                continue

            if oc in ("call", "async-start"):
                callee = op.attr_call("to_apply") or op.attr_call("calls")
                if callee:
                    rep.merge_scaled(self.analyze_computation(callee), 1.0)
                continue

            if oc == "fusion":
                callee = op.attr_call("calls")
                inner = (
                    self.analyze_computation(callee, fused=True)
                    if callee
                    else CostReport()
                )
                rep.flops += inner.flops
                for c in _COLLECTIVES:
                    rep.collective_bytes[c] += inner.collective_bytes[c]
                rep.n_collective_ops += inner.n_collective_ops
                if not fused:
                    # boundary traffic only; slice-only params count slice
                    # size; in-place-update fusions (root = DUS, i.e. scan ys
                    # collection) count the UPDATE, not the aliased buffer
                    eff_out = self._fusion_output_bytes(op, callee, out_bytes)
                    rep.hbm_bytes += eff_out + self._fusion_operand_bytes(
                        comp, op, callee
                    )
                continue

            # plain ops ------------------------------------------------------
            if oc == "dot":
                rep.flops += self._dot_flops(comp, op)
            elif oc == "convolution":
                rep.flops += self._conv_flops(comp, op)
            elif oc in ("reduce", "reduce-window", "select-and-scatter"):
                rep.flops += max(operand_bytes // 4, out_elems)
            elif oc == "sort":
                n = max(out_elems, 1)
                rep.flops += n * max(1, int(math.log2(n)))
            elif oc in _TRANSCENDENTAL:
                rep.flops += 4 * out_elems
            elif oc in ("add", "subtract", "multiply", "maximum", "minimum",
                        "and", "or", "xor", "not", "negate", "abs", "compare",
                        "select", "clamp", "floor", "ceil", "round",
                        "reduce-precision", "exponential",
                        "exponential-minus-one", "sign", "shift-left",
                        "shift-right-logical", "shift-right-arithmetic",
                        "remainder", "is-finite"):
                rep.flops += out_elems
            elif oc == "convert":
                # dtype converts are free on TPU (MXU consumes bf16 natively
                # with f32 accumulation; XLA-CPU materializes upcasts that
                # TPU-XLA fuses).  Count the write, not compute.
                if not fused:
                    rep.hbm_bytes += out_bytes
                continue
            elif oc in ("dynamic-slice", "slice", "gather"):
                pass  # movement only
            elif oc in ("dynamic-update-slice", "scatter"):
                pass
            elif oc in ("broadcast", "reshape", "transpose", "copy", "pad",
                        "concatenate", "reverse", "rev", "map",
                        "rng", "rng-bit-generator", "custom-call",
                        "infeed", "outfeed", "cholesky", "triangular-solve",
                        "send", "recv", "send-done", "recv-done"):
                pass
            else:
                rep.unknown_ops[oc] = rep.unknown_ops.get(oc, 0) + 1

            if not fused:
                if oc in ("dynamic-slice", "slice", "gather"):
                    rep.hbm_bytes += 2 * out_bytes  # read slice + write
                elif oc in ("dynamic-update-slice", "scatter"):
                    upd = (
                        _total_bytes(comp.shape_of(op.operands[1]))
                        if len(op.operands) > 1
                        else out_bytes
                    )
                    rep.hbm_bytes += 2 * upd
                elif oc in ("broadcast", "reshape", "transpose"):
                    rep.hbm_bytes += out_bytes + min(operand_bytes, out_bytes)
                else:
                    rep.hbm_bytes += out_bytes + operand_bytes

        self._memo[key] = rep
        return rep

    def _dus_update_bytes(self, inner: Computation) -> Optional[int]:
        """If the computation's root is a dynamic-update-slice (or a tuple of
        them), return the summed update-operand bytes; else None."""
        if not inner.ops:
            return None
        root = inner.ops[-1]
        roots = [root]
        if root.opcode == "tuple":
            roots = [inner.by_name[o] for o in root.operands if o in inner.by_name]
        upd = 0
        any_dus = False
        for r in roots:
            if r.opcode == "dynamic-update-slice" and len(r.operands) > 1:
                any_dus = True
                upd += _total_bytes(inner.shape_of(r.operands[1]))
            elif r.opcode == "bitcast" and r.operands:
                src = inner.by_name.get(r.operands[0])
                if src is not None and src.opcode == "dynamic-update-slice":
                    any_dus = True
                    upd += _total_bytes(inner.shape_of(src.operands[1]))
                else:
                    upd += _total_bytes(r.result)
            else:
                upd += _total_bytes(r.result)
        return upd if any_dus else None

    def _fusion_output_bytes(self, op: Op, callee: str, out_bytes: int) -> int:
        inner = self.comps.get(callee or "")
        if inner is None:
            return out_bytes
        dus = self._dus_update_bytes(inner)
        return dus if dus is not None else out_bytes

    def _fusion_operand_bytes(self, comp: Computation, op: Op, callee: str) -> int:
        """Operand traffic of a fusion: parameters consumed only via
        dynamic-slice/gather count as the slice size; parameters that are
        only the TARGET of a dynamic-update-slice (in-place buffers, aliased
        with the output) count as zero reads."""
        inner = self.comps.get(callee or "")
        total = 0
        for idx, oname in enumerate(op.operands):
            full = _total_bytes(comp.shape_of(oname))
            if inner is None:
                total += full
                continue
            pname = None
            for iop in inner.ops:
                if iop.opcode == "parameter" and re.search(
                    rf"parameter\({idx}\)", iop.attrs
                ):
                    pname = iop.name
                    break
            if pname is None:
                total += full
                continue
            uses = [iop for iop in inner.ops if pname in iop.operands]
            if uses and all(
                u.opcode in ("dynamic-slice", "slice", "gather") for u in uses
            ):
                total += sum(_total_bytes(u.result) for u in uses)
            elif uses and all(
                u.opcode == "dynamic-update-slice" and u.operands
                and u.operands[0] == pname
                for u in uses
            ):
                total += 0   # pure in-place target, aliased with output
            else:
                total += full
        return total

    # -- entry ---------------------------------------------------------------

    _const_values: Dict[str, List[int]] = {}

    def analyze(self) -> CostReport:
        return self.analyze_computation(self.entry)


def _collect_const_values(text: str) -> Dict[str, List[int]]:
    """computation name -> list of scalar int constants defined inside."""
    out: Dict[str, List[int]] = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        m = _COMP_HDR.match(s)
        if cur is None and m and "->" in s:
            cur = m.group(1)
            out[cur] = []
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        cm = re.search(r"=\s*[su]\d+\[\]\s*constant\((-?\d+)\)", s)
        if cm:
            out[cur].append(int(cm.group(1)))
    return out


def analyze_hlo(text: str) -> CostReport:
    an = HloCostAnalyzer(text)
    an._const_values = _collect_const_values(text)
    return an.analyze()


def bytes_breakdown(text: str, top: int = 15) -> List[Tuple[str, float]]:
    """Top HBM-traffic ops (opcode + shape), trip-count scaled — the perf
    loop's profile for memory-bound cells."""
    an = HloCostAnalyzer(text)
    an._const_values = _collect_const_values(text)
    contrib: Dict[str, float] = {}

    def walk(comp_name: str, scale: float):
        comp = an.comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE_OPS or oc in _COLLECTIVES:
                continue
            if oc == "while":
                body, cond = op.attr_call("body"), op.attr_call("condition")
                if body:
                    walk(body, scale * (an.trip_count(cond) if cond else 1))
                continue
            if oc in ("call", "conditional"):
                callee = op.attr_call("to_apply") or op.attr_call("true_computation")
                if callee:
                    walk(callee, scale)
                continue
            out_bytes = _total_bytes(op.result)
            operand_bytes = sum(_total_bytes(comp.shape_of(o)) for o in op.operands)
            if oc == "fusion":
                callee = op.attr_call("calls")
                b = (an._fusion_output_bytes(op, callee, out_bytes)
                     + an._fusion_operand_bytes(comp, op, callee))
            elif oc in ("dynamic-slice", "slice", "gather"):
                b = 2 * out_bytes
            elif oc in ("dynamic-update-slice", "scatter"):
                upd = (_total_bytes(comp.shape_of(op.operands[1]))
                       if len(op.operands) > 1 else out_bytes)
                b = 2 * upd
            elif oc == "convert":
                b = out_bytes
            else:
                b = out_bytes + operand_bytes
            key = f"{oc} {op.result[0].dims if op.result else ()}"
            contrib[key] = contrib.get(key, 0.0) + b * scale

    walk(an.entry, 1.0)
    return sorted(contrib.items(), key=lambda kv: -kv[1])[:top]


def flop_breakdown(text: str, top: int = 15) -> List[Tuple[str, float]]:
    """Top FLOP-contributing ops (opcode + result shape), trip-count scaled.

    Debug tool for the perf loop: shows where compiled FLOPs actually go.
    """
    an = HloCostAnalyzer(text)
    an._const_values = _collect_const_values(text)

    contrib: Dict[str, float] = {}

    def walk(comp_name: str, scale: float):
        comp = an.comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = op.attr_call("body")
                cond = op.attr_call("condition")
                trips = an.trip_count(cond) if cond else 1
                if body:
                    walk(body, scale * trips)
            elif oc == "fusion":
                callee = op.attr_call("calls")
                if callee:
                    walk(callee, scale)
            elif oc in ("call", "conditional"):
                callee = op.attr_call("to_apply") or op.attr_call(
                    "true_computation"
                )
                if callee:
                    walk(callee, scale)
            elif oc == "dot":
                f = an._dot_flops(comp, op) * scale
                key = f"dot {op.result[0].dims if op.result else ()} <- {op.name}"
                contrib[key] = contrib.get(key, 0.0) + f
            elif oc in _TRANSCENDENTAL or oc in (
                "add", "subtract", "multiply", "maximum", "minimum", "select",
                "compare", "convert", "reduce",
            ):
                f = sum(s.elems for s in op.result) * (
                    4 if oc in _TRANSCENDENTAL else 1
                ) * scale
                key = f"{oc} {op.result[0].dims if op.result else ()}"
                contrib[key] = contrib.get(key, 0.0) + f

    walk(an.entry, 1.0)
    return sorted(contrib.items(), key=lambda kv: -kv[1])[:top]
