"""Training driver: ``python -m repro.launch.train --arch llama3.2-1b-tiny``.

End-to-end: config -> mesh -> sharded init -> data pipeline -> jitted
train_step loop with checkpoint/restart.  On CPU this trains the tiny
configs (examples/quickstart); under a TPU runtime the same driver runs the
full configs on the production mesh — nothing here is CPU-specific.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax

from repro.configs import get_config, tiny_config
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.sharding import (
    axis_rules, default_rules, param_specs, shardings_for,
)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (
    TrainConfig, init_train_state, make_train_step,
)


def train(
    arch: str,
    *,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 128,
    lr: float = 3e-4,
    tiny: bool = True,
    production_mesh: bool = False,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    resume: bool = False,
    log_every: int = 10,
    n_microbatches: int = 1,
    seed: int = 0,
):
    cfg = tiny_config(arch) if tiny else get_config(arch)
    model = build_model(cfg)
    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    use_fsdp = cfg.sharding == "fsdp_tp"
    rules = default_rules(mesh, fsdp=use_fsdp)

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=lr, weight_decay=0.1, grad_clip_norm=1.0,
                              warmup_steps=max(1, steps // 20), total_steps=steps),
        n_microbatches=n_microbatches,
    )
    data = SyntheticLM(DataConfig(cfg.vocab_size, global_batch, seq_len, seed=seed))

    with mesh, axis_rules(mesh, rules):
        params, opt_state = init_train_state(model, jax.random.PRNGKey(seed), tcfg)
        pshard = shardings_for(param_specs(params, mesh, fsdp=use_fsdp), mesh)
        params = jax.tree.map(jax.device_put, params, pshard)

        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

        ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start = 0
        if ckpt and resume and ckpt.latest_step() is not None:
            start, (params, opt_state) = ckpt.restore((params, opt_state))
            print(f"resumed from step {start}")

        losses = []
        t0 = time.time()
        for step in range(start, steps):
            batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                tok_s = global_batch * seq_len * (step - start + 1) / max(dt, 1e-9)
                print(
                    f"step {step:5d} loss {losses[-1]:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} tok/s {tok_s:,.0f}"
                )
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))
        if ckpt:
            ckpt.save(steps, (params, opt_state), blocking=True)
            ckpt.wait()
            ckpt.close()
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true", help="full (non-tiny) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)
    _, losses = train(
        args.arch, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr, tiny=not args.full,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
        n_microbatches=args.microbatches,
    )
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
