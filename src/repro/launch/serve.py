"""Serving driver: ``python -m repro.launch.serve --arch qwen1.5-0.5b
--policy aging --lprs --apc``.

Full paper stack on real execution: chunked-prefill engine + Aging/FCFS/SJF
ordering + LPRS latency-targeted chunking (training its predictor on this
machine's own profiled latencies) + APC activity control.
"""
from __future__ import annotations

import argparse
import json


from repro.configs import get_config, tiny_config
from repro.core.apc import APCConfig
from repro.core.lprs import LPRSConfig
from repro.core.predictor import LatencyPredictor, PredictorConfig, bucket_and_downsample
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.core.slo import SLOConfig
from repro.tenancy.tenants import FairnessConfig, TenantSpec
from repro.engine.engine import EngineConfig, JAXEngine, serve
from repro.engine.kv_cache import pool_for_model
from repro.engine.workload import (
    WorkloadSpec,
    attach_prompt_tokens,
    sharegpt_like,
)


def profile_and_train_predictor(
    model_cfg, engine: JAXEngine, *, n_requests: int = 48,
    budget: int = 128, epochs: int = 120, seed: int = 0,
) -> LatencyPredictor:
    """The paper's offline profiling pipeline (§3.2.1) on REAL latencies:
    run the static token-budget scheduler, record (features, wall ms),
    bucket + downsample, train the MLP."""
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=n_requests, inter_arrival_s=0.005, max_context=256,
        max_new_tokens=32, seed=seed,
    ))
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=seed)
    sched = ChunkedPrefillScheduler(
        SchedulerConfig(policy="fcfs", token_budget=budget,
                        max_seqs=engine.cfg.n_slots)
    )
    res = serve(reqs, sched, engine, collect_samples=True)
    feats, lats = res.samples
    keep, wts = bucket_and_downsample(feats[:, 12])  # scheduled_tokens col
    pred = LatencyPredictor(PredictorConfig(epochs=epochs))
    pred.fit(feats[keep], lats[keep], sample_weights=wts)
    print(f"predictor trained on {len(keep)} real samples: "
          f"{pred.evaluate(feats, lats)}")
    return pred


def robustness_from_args(args):
    """--failover / --chaos-seed -> a RobustnessConfig (or None: every serve
    path stays bit-identical to the fault-oblivious code)."""
    if not (args.failover or args.chaos_seed is not None):
        return None
    from repro.robustness import FaultInjector, FaultPlan, RobustnessConfig

    injector = None
    if args.chaos_seed is not None:
        plan = FaultPlan.fuzz(args.chaos_seed, n_faults=args.chaos_faults)
        injector = FaultInjector(plan)
    return RobustnessConfig(
        max_retries=args.max_retries,
        handoff_ttl_s=args.handoff_ttl if args.handoff_ttl > 0 else None,
        injector=injector,
    )


def run_disagg(args):
    """--disagg: build a prefill pool + decode pool fleet and serve the same
    workload through the cross-replica KV handoff path."""
    from repro.disagg import (
        DisaggConfig, HandoffCostConfig, build_disagg, serve_disagg,
    )

    model_cfg = get_config(args.arch) if args.full else tiny_config(args.arch)
    router = build_disagg(
        model_cfg,
        cfg=DisaggConfig(
            n_prefill=args.n_prefill,
            n_decode=args.n_decode,
            min_handoff_tokens=args.min_handoff_tokens,
            cost=HandoffCostConfig() if args.handoff_cost else None,
            robustness=robustness_from_args(args),
        ),
        engine_cfg=EngineConfig(
            n_slots=16, max_context=512, use_pallas=args.pallas,
            paged_kv=not args.dense_kv, pipelined=not args.sync_engine,
            pages_per_tile=args.pages_per_tile,
            kv_layout=args.kv_layout, buffering_depth=args.buffering_depth,
            preemption_mode=args.preemption_mode,
            nan_guard=args.nan_guard,
        ),
        sched_cfg=SchedulerConfig(
            policy=args.policy, alpha=args.alpha, beta=args.beta,
            token_budget=args.token_budget, max_seqs=16,
            apc=APCConfig(c_max=4, l_min=16) if args.apc else None,
        ),
        n_blocks=args.kv_blocks,
        prefix_cache=args.prefix_cache,
    )
    reqs = sharegpt_like(WorkloadSpec(
        n_requests=args.n_requests, inter_arrival_s=args.interval,
        max_context=256, max_new_tokens=48, seed=1,
    ))
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=1)
    res = serve_disagg(reqs, router)
    router.check_invariants()

    if res.robustness is not None:
        rb = res.robustness
        print(f"  fault tolerance: died={rb.replicas_died} "
              f"failovers={rb.failovers} resumable={rb.recovered_resumable} "
              f"reprefill={rb.requeued_reprefill} "
              f"shed={rb.shed_replica_failure} "
              f"quarantined={rb.quarantined} faults_fired={rb.faults_fired}")
        for ev in rb.events:
            print(f"    {ev}")

    row = res.report.row()
    print(f"\n=== {args.arch} | DISAGG {args.n_prefill}P+{args.n_decode}D "
          f"policy={args.policy} kv={'dense' if args.dense_kv else 'paged'} "
          f"loop={'sync' if args.sync_engine else 'pipelined'} "
          f"cost={'model' if args.handoff_cost else 'always'} ===")
    print(f"finished {res.report.n_finished}/{res.report.n_total} "
          f"in {res.wall_s:.2f}s  ({res.rounds} rounds over "
          f"{len(router.replicas)} replicas)")
    print(f"  handoffs={res.handoffs} colocated={res.colocated} "
          f"dropped={res.dropped_handoffs} "
          f"moved={res.bytes_moved / 2**20:.1f} MiB")
    decode_prefill_tokens = sum(
        rs.sched.stats.scheduled_prefill_tokens for rs in router.decode)
    print(f"  decode-pool prefill tokens scheduled: {decode_prefill_tokens} "
          f"(handoffs resume decode-only)")
    for k, v in row.items():
        print(f"  {k:16s} {v*1e3 if 'e2e' in k or 'ttft' in k or 'prefill' in k or 'tpot' in k else v:10.2f}"
              + (" ms" if any(t in k for t in ("e2e", "ttft", "prefill", "tpot")) else ""))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "report": row, "rounds": res.rounds, "wall_s": res.wall_s,
                "handoffs": res.handoffs, "colocated": res.colocated,
                "dropped_handoffs": res.dropped_handoffs,
                "bytes_moved": res.bytes_moved,
                "decode_prefill_tokens": decode_prefill_tokens,
            }, f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--policy", default="aging", choices=["fcfs", "sjf", "aging"])
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--beta", type=float, default=-0.01)
    ap.add_argument("--token-budget", type=int, default=128)
    ap.add_argument("--n-requests", type=int, default=32)
    ap.add_argument("--interval", type=float, default=0.02)
    ap.add_argument("--lprs", action="store_true")
    ap.add_argument("--target-ms", type=float, default=0.0,
                    help="LPRS target latency (0 = auto from profiling median)")
    ap.add_argument("--apc", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="run the Pallas kernels (interpret mode on CPU)")
    ap.add_argument("--dense-kv", action="store_true",
                    help="dense slot-indexed KV cache instead of the paged "
                         "block-table layout (A/B baseline; outputs are "
                         "identical under greedy sampling)")
    ap.add_argument("--sync-engine", action="store_true",
                    help="synchronous round loop instead of the overlapped "
                         "schedule/execute pipeline (A/B baseline; outputs "
                         "are identical under greedy sampling)")
    ap.add_argument("--pages-per-tile", type=int, default=1,
                    help="physical pages gathered per paged-attention K/V "
                         "tile (MXU efficiency at small page sizes)")
    ap.add_argument("--kv-layout", default="split",
                    choices=["split", "fused"],
                    help="paged KV pool layout: 'split' keeps separate K and "
                         "V pools; 'fused' interleaves K/V on the head axis "
                         "so one gather per page feeds both operands "
                         "(greedy outputs are identical)")
    ap.add_argument("--buffering-depth", type=int, default=1,
                    help="page-DMA buffering depth in the paged attention "
                         "kernels: depth N issues tile t+N-1's gather before "
                         "waiting on tile t, overlapping copies with compute "
                         "(greedy outputs are identical at any depth)")
    ap.add_argument("--preemption-mode", default="recompute",
                    choices=["recompute", "swap"],
                    help="KV-pressure eviction strategy: 'recompute' discards "
                         "the victim's KV and re-prefills it; 'swap' stages "
                         "it host-side and restores it on re-schedule "
                         "(chosen per victim by the transfer-vs-FLOPs cost "
                         "model; greedy outputs are identical either way)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the hash-based KV prefix cache (block-aligned "
                         "prompt reuse; hits skip the matched prefill compute)")
    ap.add_argument("--kv-blocks", type=int, default=2048,
                    help="KV pool size in blocks")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: separate prefill and decode "
                         "replica pools with cross-replica KV handoff "
                         "(greedy outputs are identical to single-engine)")
    ap.add_argument("--n-prefill", type=int, default=1,
                    help="prefill-pool replicas (with --disagg)")
    ap.add_argument("--n-decode", type=int, default=1,
                    help="decode-pool replicas (with --disagg)")
    ap.add_argument("--min-handoff-tokens", type=int, default=0,
                    help="prompts with fewer resident KV tokens than this "
                         "never migrate (with --disagg)")
    ap.add_argument("--handoff-cost", action="store_true",
                    help="price each handoff against colocated contention "
                         "instead of always migrating (with --disagg)")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="time-to-first-token SLO in seconds for the serving "
                         "tenant (0 = off).  Setting either SLO enables the "
                         "SLO tier: deadline-aware LPRS targets, urgency-"
                         "ordered batching, SLO-weighted victim selection, "
                         "and load shedding of infeasible deadlines")
    ap.add_argument("--e2e-slo", type=float, default=0.0,
                    help="end-to-end completion SLO in seconds for the "
                         "serving tenant (0 = off; see --ttft-slo)")
    ap.add_argument("--failover", action="store_true",
                    help="fault-tolerant serving: replica health tracking, "
                         "crash unwinds, and (with --disagg) failover of a "
                         "dead replica's requests onto survivors")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="re-placements per request across replica failures "
                         "before a terminal shed (with --failover)")
    ap.add_argument("--handoff-ttl", type=float, default=0.0,
                    help="reap staged handoff records older than this many "
                         "seconds (0 = no TTL; with --failover)")
    ap.add_argument("--nan-guard", action="store_true",
                    help="per-round finite-logits check: requests whose "
                         "logits go NaN/Inf are quarantined (terminal shed "
                         "reason 'numerics') instead of poisoning the batch")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="fuzz a deterministic fault plan from this seed and "
                         "inject it (implies --failover)")
    ap.add_argument("--chaos-faults", type=int, default=3,
                    help="number of faults in the fuzzed plan (--chaos-seed)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    if args.disagg:
        return run_disagg(args)

    model_cfg = get_config(args.arch) if args.full else tiny_config(args.arch)
    engine = JAXEngine(model_cfg, EngineConfig(
        n_slots=16, max_context=512, use_pallas=args.pallas,
        paged_kv=not args.dense_kv, pipelined=not args.sync_engine,
        pages_per_tile=args.pages_per_tile,
        kv_layout=args.kv_layout, buffering_depth=args.buffering_depth,
        preemption_mode=args.preemption_mode,
        nan_guard=args.nan_guard,
    ))

    predictor = None
    lprs_cfg = None
    if args.lprs:
        predictor = profile_and_train_predictor(model_cfg, engine)
        target = args.target_ms
        if target <= 0:
            target = 30.0
        lprs_cfg = LPRSConfig(target_latency_ms=target, search_delta=32)

    fairness_cfg = None
    slo_cfg = None
    if args.ttft_slo > 0 or args.e2e_slo > 0:
        # SLO tier: the workload's single "default" tenant carries the
        # deadlines; fairness is required (the tracker lives on its registry)
        fairness_cfg = FairnessConfig(tenants=(TenantSpec(
            "default",
            ttft_slo_s=args.ttft_slo if args.ttft_slo > 0 else None,
            e2e_slo_s=args.e2e_slo if args.e2e_slo > 0 else None,
        ),))
        slo_cfg = SLOConfig()

    sched = ChunkedPrefillScheduler(
        SchedulerConfig(
            policy=args.policy, alpha=args.alpha, beta=args.beta,
            token_budget=args.token_budget, max_seqs=16,
            lprs=lprs_cfg,
            apc=APCConfig(c_max=4, l_min=16) if args.apc else None,
            fairness=fairness_cfg,
            slo=slo_cfg,
        ),
        predictor=predictor,
    )

    reqs = sharegpt_like(WorkloadSpec(
        n_requests=args.n_requests, inter_arrival_s=args.interval,
        max_context=256, max_new_tokens=48, seed=1,
    ))
    attach_prompt_tokens(reqs, model_cfg.vocab_size, seed=1)
    kv_pool = pool_for_model(model_cfg, n_blocks=args.kv_blocks,
                             enable_prefix_cache=args.prefix_cache)
    res = serve(reqs, sched, engine, kv_pool=kv_pool, collect_samples=False,
                robustness=robustness_from_args(args))

    row = res.report.row()
    print(f"\n=== {args.arch} | policy={args.policy} lprs={args.lprs} "
          f"apc={args.apc} pallas={args.pallas} "
          f"kv={'dense' if args.dense_kv else 'paged'}"
          f"{'' if args.dense_kv else f'/{args.kv_layout}/d{args.buffering_depth}'} "
          f"loop={'sync' if args.sync_engine else 'pipelined'} "
          f"prefix_cache={args.prefix_cache} "
          f"preempt={args.preemption_mode} ===")
    print(f"finished {res.report.n_finished}/{res.report.n_total} "
          f"in {res.wall_s:.2f}s  ({res.rounds} rounds)")
    if res.robustness is not None:
        rb = res.robustness
        print(f"  fault tolerance: crash_unwinds={rb.crash_unwinds} "
              f"quarantined={rb.quarantined} faults_fired={rb.faults_fired}")
    for k, v in row.items():
        print(f"  {k:16s} {v*1e3 if 'e2e' in k or 'ttft' in k or 'prefill' in k or 'tpot' in k else v:10.2f}"
              + (" ms" if any(t in k for t in ("e2e", "ttft", "prefill", "tpot")) else ""))
    mem = res.memory
    if mem is not None:
        print(f"  kv: hit_rate={mem.cache_hit_rate:.2%} "
              f"hit_tokens={mem.cache_hit_tokens} evictions={mem.evictions} "
              f"preemptions={mem.preemptions} cached_blocks={mem.cached_blocks}")
        if mem.swap_preemptions:
            print(f"  swap: {mem.swap_preemptions} victims staged "
                  f"({mem.swapped_out_tokens} tokens out, "
                  f"{mem.swapped_in_tokens} restored over "
                  f"{mem.swap_restores} swap-ins)")
    if res.slo is not None:
        for t, rep in res.slo.per_tenant.items():
            print(f"  slo[{t}]: attained={rep.attained} "
                  f"violated={rep.violated} shed={rep.shed} "
                  f"attainment={rep.attainment:.2%} "
                  f"p50_ttft_slack={rep.ttft_slack_s['p50'] * 1e3:.1f} ms")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"report": row, "rounds": res.rounds, "wall_s": res.wall_s,
                       "memory": mem.row() if mem is not None else None,
                       "slo": res.slo.row() if res.slo is not None else None}, f)


if __name__ == "__main__":
    main()
