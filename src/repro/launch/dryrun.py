import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input shape) cell
# on the production meshes and extract roofline terms from the compiled HLO.
#
# MUST be run as a module entry point (``python -m repro.launch.dryrun``) or
# imported before anything else touches jax — the XLA_FLAGS line above runs
# before any jax import so 512 host platform devices exist.
#
# Usage:
#   python -m repro.launch.dryrun                      # all cells, single-pod
#   python -m repro.launch.dryrun --multi-pod          # all cells, 2-pod mesh
#   python -m repro.launch.dryrun --arch mixtral-8x7b --shape decode_32k
#   python -m repro.launch.dryrun --json out.json      # machine-readable record

import argparse
import json
import sys
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config, applicable_shapes
from repro.configs.base import ModelConfig, ShapeSpec, SHAPES_BY_NAME
from repro.distributed.sharding import (
    axis_rules,
    default_rules,
    param_specs,
    sanitize_spec,
    shardings_for,
)
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    mesh_chips,
)
from repro.models.model import Model, build_model
from repro.training.train_step import (
    default_train_config,
    init_train_state_shape,
    make_train_step,
)

from repro.launch.hlo_cost import analyze_hlo

# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


@dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    error: Optional[str] = None
    lower_s: float = 0.0
    compile_s: float = 0.0
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    bytes_per_device: float = 0.0
    peak_memory_mb: float = 0.0
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    collective_term_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    roofline_fraction: float = 0.0

    def bound_s(self) -> float:
        return max(self.compute_term_s, self.memory_term_s, self.collective_term_s)


def model_flops_for(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def roofline(res: CellResult, n_chips: int) -> None:
    res.compute_term_s = res.hlo_flops / (n_chips * PEAK_FLOPS_BF16)
    res.memory_term_s = res.hlo_bytes / (n_chips * HBM_BW)
    res.collective_term_s = res.coll_bytes / (n_chips * ICI_BW_PER_LINK)
    terms = {
        "compute": res.compute_term_s,
        "memory": res.memory_term_s,
        "collective": res.collective_term_s,
    }
    res.dominant = max(terms, key=terms.get)
    res.useful_flops_ratio = res.model_flops / res.hlo_flops if res.hlo_flops else 0.0
    ideal = res.model_flops / (n_chips * PEAK_FLOPS_BF16)
    res.roofline_fraction = ideal / res.bound_s() if res.bound_s() > 0 else 0.0


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def batch_input_shardings(model: Model, shape: ShapeSpec, mesh, rules) -> Any:
    """NamedShardings for the input batch dict of this cell."""
    specs = model.input_specs(shape)

    def spec_of(name: str, s: jax.ShapeDtypeStruct) -> NamedSharding:
        batch_ax = rules["batch"]
        if name in ("tokens", "labels", "loss_mask", "lens"):
            p = sanitize_spec(mesh, (batch_ax,) + (None,) * (len(s.shape) - 1), s.shape)
        elif name in ("frames", "patch_embeds"):
            p = sanitize_spec(mesh, (batch_ax, None, None), s.shape)
        else:
            p = P()
        return NamedSharding(mesh, p)

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        return spec_of(name, tree)

    return walk(specs)


def cache_shardings(
    model: Model, shape: ShapeSpec, mesh, rules, *, phase: str = "decode"
) -> Any:
    """KV cache: batch over data; heads over model when divisible.  When
    heads do not divide the model axis, the layout is phase-optimal
    (DistServe-style): the *decode* input cache shards the LENGTH over model
    (flash-decode partial softmax -> small all-reduces), while the *prefill*
    output cache shards HEAD_DIM over model — a pure local slice on write,
    which keeps GSPMD from back-propagating a seq-resharding into the
    flash-attention block loop.  Recurrent (SSM / xLSTM) states: batch over
    data + the widest divisible trailing dim over model."""
    struct = model.cache_struct(shape.global_batch, shape.seq_len)
    batch_ax = rules["batch"]
    model_sz = mesh.shape["model"]

    batch_shards = 1
    for a in (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax):
        batch_shards *= mesh.shape[a]

    hd_sharded = []  # records whether any prefill kv leaf went hd-sharded

    def kv_spec(shp, dtype_bytes=2):
        # (L, B, S, H, hd)
        L_, B, S, H, hd = shp
        if H % model_sz == 0:
            return sanitize_spec(mesh, (None, batch_ax, None, "model", None), shp)
        if phase == "prefill":
            # output cache: avoid resharding the scan's ys when it fits;
            # hd-sharding (pure local slice on write) only under capacity
            # pressure.  4 GB/device budget for one of k/v.
            per_dev = L_ * max(1, B // batch_shards) * S * H * hd * dtype_bytes
            if per_dev <= 4 * 2**30 or hd % model_sz != 0:
                return sanitize_spec(mesh, (None, batch_ax, None, None, None), shp)
            hd_sharded.append(True)
            return sanitize_spec(mesh, (None, batch_ax, None, None, "model"), shp)
        return sanitize_spec(mesh, (None, batch_ax, "model", None, None), shp)

    def state_spec(shp):
        spec = [None] * len(shp)
        b_idx = None
        for i, d in enumerate(shp):
            if d == shape.global_batch:
                spec[i] = batch_ax
                b_idx = i
                break
        # widest trailing dim divisible by the model axis (skip the batch dim)
        best, best_d = None, 0
        for i in range(len(shp) - 1, (b_idx if b_idx is not None else -1), -1):
            if spec[i] is None and shp[i] % model_sz == 0 and shp[i] > best_d:
                best, best_d = i, shp[i]
        if best is not None:
            spec[best] = "model"
        return sanitize_spec(mesh, tuple(spec), shp)

    def one(path_keys, s: jax.ShapeDtypeStruct) -> NamedSharding:
        shp = s.shape
        name = path_keys[0] if path_keys else ""
        if (name in ("k", "v") or name.startswith("self")
                or name.startswith("cross")):
            p = kv_spec(shp)
        elif name == "kv_pos":
            p = sanitize_spec(mesh, (None, batch_ax, None), shp)
        else:  # recurrent / conv state of any nesting
            p = state_spec(shp)
        return NamedSharding(mesh, p)

    def keystr(kp) -> list:
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
        return out

    shardings = jax.tree_util.tree_map_with_path(
        lambda kp, s: one(keystr(kp), s), struct
    )
    # rules so the model constrains COLLECTED kv to the cache layout at the
    # collection point (local slice) instead of GSPMD back-propagating it
    cache_rules = (
        {"cache_hd": "model", "cache_heads": None} if hd_sharded else {}
    )
    return shardings, cache_rules


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    fsdp: Optional[bool] = None,
    shard_seq: bool = False,
    verbose: bool = True,
    extra_rules: Optional[Dict[str, Any]] = None,
    return_compiled: bool = False,
) -> CellResult:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name, ok=False)
    n_chips = mesh_chips(mesh)

    model = build_model(cfg)
    use_fsdp = fsdp if fsdp is not None else (cfg.sharding == "fsdp_tp")
    rules = default_rules(mesh, shard_seq=shard_seq, fsdp=use_fsdp)
    if extra_rules:
        rules.update(extra_rules)

    batch_shards = 1
    batch_ax = rules["batch"]
    for a in (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax):
        batch_shards *= mesh.shape[a]

    t0 = time.time()
    try:
        with mesh, axis_rules(mesh, rules):
            if shape.kind == "train":
                tcfg = default_train_config(
                    cfg.param_count(), batch_shards=batch_shards,
                    global_batch=shape.global_batch,
                )
                pshape, oshape = init_train_state_shape(model, tcfg)
                pspecs = param_specs(pshape, mesh, fsdp=use_fsdp)
                pshard = shardings_for(pspecs, mesh)
                oshard = jax.tree.map(
                    lambda s: NamedSharding(mesh, P())
                    if s.ndim == 0
                    else None,  # filled below
                    oshape,
                )
                # moments shard like params; step replicated
                mu_shard = shardings_for(param_specs(oshape.mu, mesh, fsdp=use_fsdp), mesh)
                nu_shard = shardings_for(param_specs(oshape.nu, mesh, fsdp=use_fsdp), mesh)
                oshard = type(oshape)(step=NamedSharding(mesh, P()), mu=mu_shard, nu=nu_shard)
                bshard = batch_input_shardings(model, shape, mesh, rules)

                step = make_train_step(model, tcfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(pshard, oshard, bshard),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1),
                )
                args = (pshape, oshape, model.input_specs(shape))
            elif shape.kind == "prefill":
                pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
                pshard = shardings_for(param_specs(pshape, mesh, fsdp=use_fsdp), mesh)
                bshard = batch_input_shardings(model, shape, mesh, rules)
                cshard, cache_rules = cache_shardings(
                    model, shape, mesh, rules, phase="prefill"
                )
                rules.update(cache_rules)

                def prefill_step(params, batch):
                    return model.prefill(params, batch)

                jitted = jax.jit(
                    prefill_step,
                    in_shardings=(pshard, bshard),
                    out_shardings=(None, cshard),
                )
                args = (pshape, model.input_specs(shape))
            else:  # decode
                pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
                pshard = shardings_for(param_specs(pshape, mesh, fsdp=use_fsdp), mesh)
                specs = model.input_specs(shape)
                cshard, _ = cache_shardings(model, shape, mesh, rules, phase="decode")
                tok_shard = NamedSharding(
                    mesh, sanitize_spec(mesh, (rules["batch"], None), specs["tokens"].shape)
                )
                lens_shard = NamedSharding(
                    mesh, sanitize_spec(mesh, (rules["batch"],), specs["lens"].shape)
                )

                def serve_step(params, tokens, cache, lens):
                    return model.decode(params, tokens, cache, lens)

                jitted = jax.jit(
                    serve_step,
                    in_shardings=(pshard, tok_shard, cshard, lens_shard),
                    out_shardings=(None, cshard),
                    donate_argnums=(2,),
                )
                args = (pshape, specs["tokens"], model.cache_struct(
                    shape.global_batch, shape.seq_len), specs["lens"])

            lowered = jitted.lower(*args)
            res.lower_s = time.time() - t0

            t1 = time.time()
            compiled = lowered.compile()
            res.compile_s = time.time() - t1

            # trip-count-aware per-device costs from the optimized HLO text
            # (XLA's own cost_analysis counts while bodies once — useless for
            # scan-over-layers programs; see launch/hlo_cost.py)
            rep = analyze_hlo(compiled.as_text())
            res.hlo_flops = rep.flops * n_chips        # global, per spec formula
            res.hlo_bytes = rep.hbm_bytes * n_chips
            res.coll_bytes = rep.total_collective_bytes * n_chips
            res.coll_breakdown = {k: int(v) for k, v in rep.collective_bytes.items()}
            res.coll_breakdown["n_ops"] = rep.n_collective_ops

            mem = compiled.memory_analysis()
            per_dev = (
                getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            )
            args_bytes = getattr(mem, "argument_size_in_bytes", 0)
            res.bytes_per_device = float(per_dev)
            res.peak_memory_mb = float(per_dev + args_bytes) / 2**20

            res.model_flops = model_flops_for(cfg, shape)
            roofline(res, n_chips)
            res.ok = True
    except Exception as e:  # noqa: BLE001 — record per-cell failures
        res.error = f"{type(e).__name__}: {e}"
        compiled = None
        if verbose:
            import traceback
            traceback.print_exc()
    if return_compiled:
        return res, (compiled if res.ok else None)
    return res


def lower_chunked_serve(
    arch: str,
    mesh,
    *,
    n_slots: int = 128,
    chunk: int = 256,
    max_context: int = 8192,
    verbose: bool = False,
) -> CellResult:
    """Lower the paper's ACTUAL execution unit — one mixed chunked-prefill
    round (decode slots advance 1 token, prefill slots by their chunk) —
    on the production mesh.  This is the `chunked_step` the serving engine
    jits; proving it compiles sharded closes the loop between the scheduler
    (host) and the data plane (SPMD workers)."""
    cfg = get_config(arch)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    res = CellResult(arch=arch, shape="chunk_serve", mesh=mesh_name, ok=False)
    n_chips = mesh_chips(mesh)
    model = build_model(cfg)
    impl = model.impl
    if not hasattr(impl, "chunked_step") or cfg.sliding_window:
        res.error = "family has no linear-cache chunked_step"
        return res
    use_fsdp = cfg.sharding == "fsdp_tp"
    rules = default_rules(mesh, fsdp=use_fsdp)
    batch_ax = rules["batch"]

    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    kv_shape = (cfg.n_layers, n_slots, max_context + 1, cfg.n_kv_heads, hd)
    cache = {
        "k": jax.ShapeDtypeStruct(kv_shape, dt),
        "v": jax.ShapeDtypeStruct(kv_shape, dt),
    }
    model_sz = mesh.shape["model"]
    if cfg.n_kv_heads % model_sz == 0:
        kv_spec = sanitize_spec(mesh, (None, batch_ax, None, "model", None), kv_shape)
    else:
        kv_spec = sanitize_spec(mesh, (None, batch_ax, None, None, None), kv_shape)
    cshard = {k: NamedSharding(mesh, kv_spec) for k in ("k", "v")}

    t0 = time.time()
    try:
        with mesh, axis_rules(mesh, rules):
            pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            pshard = shardings_for(param_specs(pshape, mesh, fsdp=use_fsdp), mesh)
            tok = jax.ShapeDtypeStruct((n_slots, chunk), jnp.int32)
            lens = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
            b_sh = NamedSharding(mesh, sanitize_spec(mesh, (batch_ax, None), tok.shape))
            l_sh = NamedSharding(mesh, sanitize_spec(mesh, (batch_ax,), lens.shape))

            def chunked_round(params, tokens, cache, lens, chunk_lens):
                return impl.chunked_step(params, tokens, cache, lens, chunk_lens)

            jitted = jax.jit(
                chunked_round,
                in_shardings=(pshard, b_sh, cshard, l_sh, l_sh),
                out_shardings=(None, cshard),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(pshape, tok, cache, lens, lens)
            res.lower_s = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            res.compile_s = time.time() - t1

            rep = analyze_hlo(compiled.as_text())
            res.hlo_flops = rep.flops * n_chips
            res.hlo_bytes = rep.hbm_bytes * n_chips
            res.coll_bytes = rep.total_collective_bytes * n_chips
            res.coll_breakdown = {k: int(v) for k, v in rep.collective_bytes.items()}
            mem = compiled.memory_analysis()
            res.peak_memory_mb = float(
                getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
            ) / 2**20
            # model flops: ~n_slots*chunk tokens of prefill-like work
            res.model_flops = 2.0 * cfg.active_param_count() * n_slots * chunk
            roofline(res, n_chips)
            res.ok = True
    except Exception as e:  # noqa: BLE001
        res.error = f"{type(e).__name__}: {e}"
        if verbose:
            import traceback
            traceback.print_exc()
    return res


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def all_cells() -> List[tuple]:
    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for s in applicable_shapes(cfg):
            cells.append((arch, s.name))
    return cells


def fmt_row(r: CellResult) -> str:
    if not r.ok:
        return f"  {r.arch:24s} {r.shape:12s} FAIL  {r.error}"
    return (
        f"  {r.arch:24s} {r.shape:12s} ok "
        f"comp={r.compute_term_s*1e3:9.2f}ms mem={r.memory_term_s*1e3:9.2f}ms "
        f"coll={r.collective_term_s*1e3:9.2f}ms dom={r.dominant:10s} "
        f"useful={r.useful_flops_ratio:6.3f} roofline={r.roofline_fraction:6.3f} "
        f"mem/dev={r.peak_memory_mb:9.1f}MB"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="run single-pod AND multi-pod")
    ap.add_argument("--chunked-serve", action="store_true",
                    help="also lower the paper's mixed chunked-prefill round")
    ap.add_argument("--json", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    meshes = []
    if args.both:
        meshes = [("single-pod 16x16", False), ("multi-pod 2x16x16", True)]
    else:
        meshes = [("multi-pod 2x16x16" if args.multi_pod else "single-pod 16x16",
                   args.multi_pod)]

    if args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.arch:
        cells = [(args.arch, s.name) for s in applicable_shapes(get_config(args.arch))]
    else:
        cells = all_cells()

    results: List[CellResult] = []
    n_fail = 0
    for mesh_label, mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        print(f"=== {mesh_label}: {mesh_chips(mesh)} chips, "
              f"axes {mesh.axis_names} {tuple(mesh.devices.shape)} ===")
        for arch, shape in cells:
            r = lower_cell(arch, shape, mesh, verbose=not args.quiet)
            results.append(r)
            print(fmt_row(r), flush=True)
            n_fail += 0 if r.ok else 1
        if args.chunked_serve:
            for arch in dict.fromkeys(a for a, _ in cells):
                r = lower_chunked_serve(arch, mesh, verbose=not args.quiet)
                if r.error == "family has no linear-cache chunked_step":
                    continue
                results.append(r)
                print(fmt_row(r), flush=True)
                n_fail += 0 if r.ok else 1

    print(f"\n{len(results) - n_fail}/{len(results)} cells OK")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([asdict(r) for r in results], f, indent=1)
        print(f"wrote {args.json}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
