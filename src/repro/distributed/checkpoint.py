"""Sharded checkpointing with async save — fault-tolerance substrate.

Layout: one ``.npz``-style directory per step; every param leaf is saved as
its own file keyed by its pytree path, with a JSON manifest recording shapes,
dtypes and the step.  Saves happen on a background thread (training never
blocks on I/O); restore re-shards to whatever mesh/sharding the restoring job
uses — the TP=16 -> TP=8 elastic-resharding path is just "restore under new
shardings" because每 leaf is stored unsharded (gathered on save).

On a real multi-host deployment the gather becomes per-host shard files
(process-local ``jax.experimental.multihost_utils``); the manifest/replay
logic is identical — the single-host path here exercises the full protocol.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import uuid
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    elif hasattr(tree, "_fields"):  # NamedTuple (optimizer state) — before tuple!
        for name in tree._fields:
            out.update(_flatten(getattr(tree, name), f"{prefix}/{name}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/[{i}]"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(template: Any, flat: Dict[str, Any], prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
            for k, v in template.items()
        }
    if isinstance(template, (list, tuple)) and not hasattr(template, "_fields"):
        vals = [
            _unflatten_into(v, flat, f"{prefix}/[{i}]")
            for i, v in enumerate(template)
        ]
        return type(template)(vals)
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, n), flat, f"{prefix}/{n}")
            for n in template._fields
        ])
    return flat[prefix]


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        if self.async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any, *, blocking: bool = False) -> str:
        """Snapshot (device->host copy happens NOW; I/O maybe async)."""
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}   # sync point
        path = os.path.join(self.directory, f"step_{step:010d}")
        if self.async_save and not blocking:
            self._q.put((step, path, host))
        else:
            self._write(step, path, host)
        return path

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, path: str, host: Dict[str, np.ndarray]):
        if os.path.exists(path):      # same step already published
            return
        tmp = f"{path}.tmp.{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for i, (k, v) in enumerate(host.items()):
            fname = f"leaf_{i:05d}.npy"
            logical = str(v.dtype)
            if logical == "bfloat16":   # numpy can't round-trip ml_dtypes
                np.save(os.path.join(tmp, fname), v.view(np.uint16))
            else:
                np.save(os.path.join(tmp, fname), v)
            manifest["leaves"][k] = {
                "file": fname, "shape": list(v.shape), "dtype": logical,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        try:
            os.rename(tmp, path)  # atomic publish
        except OSError:
            shutil.rmtree(tmp, ignore_errors=True)  # concurrent save won
        self._gc()

    def wait(self):
        """Block until queued saves land; re-raise background errors."""
        self._q.join()
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True
            )

    # -- restore ------------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into `template`'s structure; `shardings` (matching pytree)
        re-shards every leaf on load — elastic resharding (e.g. a TP=16
        checkpoint restored under a TP=8 mesh) is exactly this path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        def load_leaf(meta):
            arr = np.load(os.path.join(path, meta["file"]))
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            return arr

        flat_np = {
            k: load_leaf(meta) for k, meta in manifest["leaves"].items()
        }
        state = _unflatten_into(template, flat_np)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
                state, shardings,
                is_leaf=lambda x: not isinstance(x, (dict, list, tuple)) or hasattr(x, "shape"),
            )
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return step, state

    def close(self):
        if self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=5)
