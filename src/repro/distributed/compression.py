"""Gradient compression for the DP all-reduce: int8 stochastic quantization
with error feedback.

Used by the explicit shard_map DP path (``compressed_psum``): gradients are
quantized to int8 per-block scales, summed over the data axis, dequantized;
the quantization residual is fed back into the next step's gradient (error
feedback keeps SGD/Adam convergence — Karimireddy et al., 2019).  The GSPMD
train path instead uses bf16 accumulators (TrainConfig.accum_dtype); this
module is the explicit 4x-volume-reduction alternative for DCN-limited
multi-pod meshes where the pod-level all-reduce is the bottleneck.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # elements per quantization scale


def _pad_to(x, m: int):
    n = x.size
    pad = (-n) % m
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize_int8(x, rng) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: any shape f32/bf16 -> (int8 blocks, f32 scales). Stochastic
    rounding: unbiased quantization noise."""
    flat, n = _pad_to(x.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    y = blocks / scale
    noise = jax.random.uniform(rng, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q, scale, shape, orig_size: int):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:orig_size]
    return flat.reshape(shape)


def compressed_psum(grads: Any, axis_name: str, rng, error: Any = None):
    """Quantize -> psum(int32) -> dequantize, with error feedback.

    grads/error: pytrees; returns (mean_grads, new_error).
    Inside shard_map over `axis_name`.  Wire volume: 1 byte/elem + one f32
    scale per 256 elems (~4.02x less than f32, ~2.01x less than bf16).
    """
    n_dev = jax.lax.psum(1, axis_name)
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = (
        jax.tree.leaves(error) if error is not None
        else [jnp.zeros_like(l, dtype=jnp.float32) for l in leaves]
    )
    rngs = jax.random.split(rng, len(leaves))

    out, new_err = [], []
    for leaf, e, r in zip(leaves, err_leaves, rngs):
        target = leaf.astype(jnp.float32) + e
        q, scale = quantize_int8(target, r)
        # int8 sums can overflow int8 — widen before the collective
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_sum = jax.lax.psum(scale, axis_name)  # scales averaged implicitly below
        # each device contributed its own scale; approximate joint dequant
        # with the mean scale (exact per-device dequant would need an
        # all-gather of scales; mean-scale keeps volume minimal)
        mean_scale = s_sum / n_dev
        deq = dequantize_int8(
            (q_sum / n_dev), mean_scale, leaf.shape, leaf.size
        )
        local_deq = dequantize_int8(
            q.astype(jnp.int32), scale, leaf.shape, leaf.size
        )
        new_err.append(target - local_deq)       # residual this device failed to send
        out.append(deq.astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_err)


def compression_ratio() -> float:
    """Wire bytes per element vs f32."""
    return (1.0 + 4.0 / BLOCK) / 4.0
