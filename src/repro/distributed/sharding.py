"""Sharding rules: logical axes, parameter specs, activation constraints.

Model code annotates activations with *logical* axis names via ``constrain``.
A rules context (set by the launcher / dry-run) maps logical names to mesh
axes; without an active context ``constrain`` is the identity, so models run
unsharded on CPU tests unchanged.

Parameter sharding is name-based (``spec_for_param``): TP over the "model"
axis for head/ffn/expert dims, optional FSDP over the "data" axis for the
embed dims of big models (2D weight sharding), replication for norms/scalars.
Every candidate spec is sanitized against actual dim sizes — axes that do not
divide a dimension are dropped (e.g. granite's single KV head under TP=16).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()

AxisVal = Union[None, str, Tuple[str, ...]]


def _active() -> Optional[Tuple[Mesh, Dict[str, AxisVal]]]:
    return getattr(_ctx, "active", None)


@contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, AxisVal]):
    prev = _active()
    _ctx.active = (mesh, rules)
    try:
        yield
    finally:
        _ctx.active = prev


def _axis_size(mesh: Mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def sanitize_spec(mesh: Mesh, spec: Sequence[AxisVal], shape: Sequence[int]) -> P:
    """Drop mesh axes that don't divide the corresponding dim."""
    used = set()
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        keep = []
        size = 1
        for a in axes:
            asz = mesh.shape[a]
            if a not in used and dim % (size * asz) == 0:
                keep.append(a)
                size *= asz
        for a in keep:
            used.add(a)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def constrain(x, logical: Sequence[Optional[str]]):
    """Attach a sharding constraint by logical axis names (no-op w/o context)."""
    active = _active()
    if active is None or not hasattr(x, "shape") or x.ndim != len(logical):
        return x
    mesh, rules = active
    spec = [rules.get(name) if name else None for name in logical]
    spec_p = sanitize_spec(mesh, spec, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec_p))


# ---------------------------------------------------------------------------
# rule sets
# ---------------------------------------------------------------------------


def default_rules(mesh: Mesh, *, shard_seq: bool = False, fsdp: bool = False) -> Dict[str, AxisVal]:
    """Logical-name -> mesh-axis mapping.

    shard_seq: long-context decode — shard the KV/cache length over "data"
    (sequence parallelism for the cache; softmax reductions become small
    all-reduces under GSPMD).
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes: AxisVal = ("pod", "data") if has_pod else "data"
    rules: Dict[str, AxisVal] = {
        "batch": batch_axes,
        "seq": None,
        "kv_seq": "data" if shard_seq else None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "moe_mlp": "model",
        "experts": "model",
        "vocab": "model",
        "ssm_inner": "model",
        "fsdp": "data" if fsdp else None,
        "pod_dp": "pod" if has_pod else None,
        # layout of KV COLLECTED for the prefill cache — distinct from the
        # compute-path kv_heads so a capacity-driven cache layout (e.g.
        # hd-sharded) becomes a local slice at collection instead of
        # back-propagating into the attention loop.
        "cache_seq": None,
        "cache_heads": "model",
        "cache_hd": None,
        # residual-stream sequence dim (Megatron sequence parallelism):
        # "model" shards norms/residual adds over TP and decomposes the TP
        # all-reduces into reduce-scatter + all-gather
        "act_seq": None,
    }
    return rules


# ---------------------------------------------------------------------------
# parameter specs (name-based)
# ---------------------------------------------------------------------------

_PARAM_RULES = [
    # (name, ndim) -> logical spec (pre-sanitization)
    ("wq", ("fsdp", "model", None)),
    ("wk", ("fsdp", "model", None)),
    ("wv", ("fsdp", "model", None)),
    ("wo", ("model", None, "fsdp")),
    ("bq", ("model", None)),
    ("bk", ("model", None)),
    ("bv", ("model", None)),
    ("w_gate", None),   # resolved dynamically (dense vs moe)
    ("w_up", None),
    ("w_down", None),
    ("router", (None, None)),
    ("embed", ("model", "fsdp")),       # (V, D): vocab over model
    ("lm_head", ("fsdp", "model")),     # (D, V)
    ("in_proj", ("fsdp", "model")),     # mamba (D, 2*d_inner)
    ("conv_w", ("model", None)),        # (d_inner, width)
    ("conv_b", ("model",)),
    ("x_proj", ("model", None)),        # (d_inner, dt_rank + 2*state)
    ("dt_proj", (None, "model")),
    ("dt_bias", ("model",)),
    ("A_log", ("model", None)),
    ("D", ("model",)),
    ("out_proj", ("model", "fsdp")),    # (d_inner, D)
    # xlstm
    ("up_proj", ("fsdp", "model")),
    ("down_proj", ("model", "fsdp")),
    ("wi", ("model", None)),
    ("wf", ("model", None)),
    ("wog", ("fsdp", "model")),
    ("r_gate", ("model", None)),
]


def spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh, *, fsdp: bool) -> P:
    name = path.split("/")[-1]
    is_expert = "moe" in path and name in ("w_gate", "w_up", "w_down")
    is_ffn = (not is_expert) and name in ("w_gate", "w_up", "w_down")
    model_axis_sz = mesh.shape["model"]

    # layer-stacked params carry a leading layer dim: detect via path marker
    stacked = "layers" in path or "blocks" in path
    lead: Tuple[AxisVal, ...] = (None,) if stacked else ()

    fsdp_ax: AxisVal = "data" if fsdp else None

    if is_expert:
        # experts (E, D, F)/(E, F, D): EP over model if divisible, else TP on F
        e_dim = shape[len(lead)]
        if e_dim % model_axis_sz == 0:
            spec: Tuple[AxisVal, ...] = lead + ("model", fsdp_ax, None)
        elif name == "w_down":
            spec = lead + (None, "model", fsdp_ax)
        else:
            spec = lead + (None, fsdp_ax, "model")
        return sanitize_spec(mesh, spec, shape)
    if is_ffn:
        if name == "w_down":
            spec = lead + ("model", fsdp_ax)
        else:
            spec = lead + (fsdp_ax, "model")
        return sanitize_spec(mesh, spec, shape)

    for rule_name, logical in _PARAM_RULES:
        if name == rule_name and logical is not None:
            resolved = tuple(
                ("data" if fsdp else None) if ax == "fsdp" else ax for ax in logical
            )
            spec = lead + resolved
            return sanitize_spec(mesh, spec, shape)
    # norms, scalars, biases: replicated (stacked layer dim unsharded)
    return sanitize_spec(mesh, lead + (None,) * (len(shape) - len(lead)), shape)


def _flatten_paths(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    else:
        out[prefix] = tree
    return out


def param_specs(params_shape: Any, mesh: Mesh, *, fsdp: bool) -> Any:
    """PartitionSpec pytree matching a param pytree (of arrays or
    ShapeDtypeStructs)."""

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else str(k)) for k, v in tree.items()}
        return spec_for_param(prefix, tree.shape, mesh, fsdp=fsdp)

    return walk(params_shape)


def shardings_for(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
