"""Latency metrics: request-level and prefill-level summaries (§4.2).

L_req = finish - arrive; L_pf = prefill_done - arrive; TTFT; TPOT.
Percentile statistics are the primary summary (high-percentile latency is
more informative than the mean in interactive serving).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.request import Request, RequestState

PCTS = (50, 80, 90, 95, 99)


def percentiles(xs: Sequence[float], pcts=PCTS) -> Dict[str, float]:
    if len(xs) == 0:
        return {f"p{p}": float("nan") for p in pcts} | {"mean": float("nan")}
    arr = np.asarray(xs, np.float64)
    out = {f"p{p}": float(np.percentile(arr, p)) for p in pcts}
    out["mean"] = float(arr.mean())
    out["max"] = float(arr.max())
    return out


@dataclass
class LatencyReport:
    e2e: Dict[str, float]
    ttft: Dict[str, float]
    prefill_e2e: Dict[str, float]
    tpot: Dict[str, float]
    n_finished: int
    n_total: int
    makespan: float
    throughput_rps: float

    def row(self) -> Dict[str, float]:
        return {
            "mean_e2e": self.e2e["mean"],
            "p95_e2e": self.e2e["p95"],
            "p99_e2e": self.e2e["p99"],
            "mean_ttft": self.ttft["mean"],
            "p95_ttft": self.ttft["p95"],
            "p99_ttft": self.ttft["p99"],
            "mean_prefill": self.prefill_e2e["mean"],
            "p90_prefill": self.prefill_e2e["p90"],
            "p99_prefill": self.prefill_e2e["p99"],
            "mean_tpot": self.tpot["mean"],
            "throughput_rps": self.throughput_rps,
        }


def summarize(requests: Iterable[Request], makespan: Optional[float] = None) -> LatencyReport:
    reqs = list(requests)
    fin = [r for r in reqs if r.finish_time is not None]
    e2e = [r.e2e_latency() for r in fin]
    ttft = [r.ttft() for r in reqs if r.ttft() is not None]
    pf = [r.prefill_e2e() for r in reqs if r.prefill_e2e() is not None]
    tpot = [
        (r.finish_time - r.first_token_time) / max(r.generated - 1, 1)
        for r in fin
        if r.first_token_time is not None and r.generated > 1
    ]
    ms = makespan if makespan is not None else (
        max((r.finish_time for r in fin), default=0.0)
        - min((r.arrival_time for r in reqs), default=0.0)
    )
    return LatencyReport(
        e2e=percentiles(e2e),
        ttft=percentiles(ttft),
        prefill_e2e=percentiles(pf),
        tpot=percentiles(tpot),
        n_finished=len(fin),
        n_total=len(reqs),
        makespan=ms,
        throughput_rps=len(fin) / ms if ms > 0 else float("nan"),
    )


def cdf_points(xs: Sequence[float], n: int = 100) -> List[tuple]:
    arr = np.sort(np.asarray(xs, np.float64))
    return [(float(arr[int(q * (len(arr) - 1))]), q) for q in np.linspace(0, 1, n)]


# ---------------------------------------------------------------------------
# KV memory-subsystem metrics
# ---------------------------------------------------------------------------


@dataclass
class MemoryReport:
    """One serving run's KV lifecycle summary: prefix-cache effectiveness,
    eviction/preemption pressure, and per-tenant block occupancy."""

    cache_lookups: int
    cache_hit_blocks: int
    cache_miss_blocks: int
    cache_hit_rate: float            # block-level, over all prefix lookups
    cache_hit_tokens: int            # prefill tokens skipped via the cache
    evictions: int                   # cached blocks reclaimed for new allocs
    preemptions: int                 # requests evicted for KV pressure
    kv_deferrals: int                # chunks shrunk/deferred for lack of blocks
    used_blocks: int                 # referenced blocks at end of run
    cached_blocks: int               # refcount-0 blocks held by the cache
    free_blocks: int
    utilization: float
    blocks_by_tenant: Dict[str, int]
    # swap-out preemption traffic (0 everywhere in recompute mode)
    swap_preemptions: int = 0        # victims staged host-side, not recomputed
    swap_restores: int = 0           # staged victims swapped back in
    swapped_out_tokens: int = 0      # Σ tokens moved device -> host
    swapped_in_tokens: int = 0       # Σ tokens moved host -> device
    # tiered KV hierarchy (0 everywhere without the host tier knobs)
    prefetched_restores: int = 0     # restores run early with leftover capacity
    restore_wait_rounds: int = 0     # Σ rounds victims spent host-staged
    host_demotions: int = 0          # staged records evicted under the budget
    partial_restores: int = 0        # tail-only swap-ins (prefix recomputed)
    tail_restored_tokens: int = 0
    host_resident_bytes: int = 0     # host-tier occupancy at end of run
    host_peak_bytes: int = 0
    host_evictions: int = 0          # tier-side eviction count (all causes)

    def row(self) -> Dict[str, float]:
        return {
            "cache_hit_rate": self.cache_hit_rate,
            "cache_hit_tokens": float(self.cache_hit_tokens),
            "evictions": float(self.evictions),
            "preemptions": float(self.preemptions),
            "kv_deferrals": float(self.kv_deferrals),
            "kv_utilization": self.utilization,
            "swap_preemptions": float(self.swap_preemptions),
            "swap_restores": float(self.swap_restores),
            "prefetched_restores": float(self.prefetched_restores),
            "restore_wait_rounds": float(self.restore_wait_rounds),
            "host_demotions": float(self.host_demotions),
            "partial_restores": float(self.partial_restores),
            "host_peak_bytes": float(self.host_peak_bytes),
        }


def summarize_memory(pool, scheduler_stats=None) -> MemoryReport:
    """Build a MemoryReport from a ``KVBlockPool`` (and optionally the
    scheduler's stats, which own the preemption/deferral counters)."""
    s = pool.stats
    return MemoryReport(
        cache_lookups=s.lookups,
        cache_hit_blocks=s.hit_blocks,
        cache_miss_blocks=s.miss_blocks,
        cache_hit_rate=s.hit_rate,
        cache_hit_tokens=s.hit_tokens,
        evictions=s.evictions,
        preemptions=getattr(scheduler_stats, "preemptions", 0),
        kv_deferrals=getattr(scheduler_stats, "kv_deferrals", 0),
        used_blocks=pool.used_blocks,
        cached_blocks=pool.cached_blocks,
        free_blocks=len(pool.free_blocks),
        utilization=pool.utilization(),
        blocks_by_tenant=pool.blocks_by_tenant(),
        swap_preemptions=getattr(scheduler_stats, "swap_preemptions", 0),
        swap_restores=getattr(scheduler_stats, "swap_restores", 0),
        swapped_out_tokens=s.swapped_out_tokens,
        swapped_in_tokens=s.swapped_in_tokens,
        prefetched_restores=getattr(scheduler_stats, "prefetched_restores", 0),
        restore_wait_rounds=getattr(scheduler_stats, "restore_wait_rounds", 0),
        host_demotions=getattr(scheduler_stats, "host_demotions", 0),
        partial_restores=getattr(scheduler_stats, "partial_restores", 0),
        tail_restored_tokens=getattr(scheduler_stats, "tail_restored_tokens", 0),
        host_resident_bytes=pool.host.stats.resident_bytes,
        host_peak_bytes=pool.host.stats.peak_bytes,
        host_evictions=pool.host.stats.evictions,
    )


# ---------------------------------------------------------------------------
# multi-tenant fairness metrics
# ---------------------------------------------------------------------------


def jain_index(xs: Iterable[float]) -> float:
    """Jain's fairness index: (Σx)² / (n·Σx²).  1.0 = perfectly even,
    1/n = one party gets everything.  Empty input → NaN (undefined);
    a single party, or all-zero allocations, → 1.0 (trivially fair)."""
    arr = np.asarray(list(xs), np.float64)
    if arr.size == 0:
        return float("nan")
    ss = float((arr * arr).sum())
    if ss == 0.0:
        return 1.0
    s = float(arr.sum())
    return s * s / (arr.size * ss)


@dataclass
class FairnessReport:
    """Per-tenant latency + service summary for one serving run.

    ``service_tokens``: tokens actually delivered per tenant (prefill
    progress + generated tokens).  ``normalized_service`` divides by the
    tenant's weight — the quantity the VTC equalizes.  ``jain`` is Jain's
    index over normalized service; ``max_service_delta`` is the worst-case
    spread (max - min) of normalized service, the VTC paper's service-bound
    metric.
    """

    per_tenant: Dict[str, LatencyReport]
    service_tokens: Dict[str, float]
    normalized_service: Dict[str, float]
    jain: float
    max_service_delta: float

    def row(self) -> Dict[str, float]:
        out = {"jain": self.jain, "max_service_delta": self.max_service_delta}
        for t, rep in self.per_tenant.items():
            out[f"{t}/p99_ttft"] = rep.ttft["p99"]
            out[f"{t}/mean_e2e"] = rep.e2e["mean"]
            out[f"{t}/service_tokens"] = self.service_tokens[t]
        return out


def request_service_tokens(req: Request) -> float:
    """Tokens the engine actually delivered to one request so far.
    ``context_len`` nets out tokens a preemption folded into the prompt, so
    recompute work is never double-counted as delivered service."""
    return float(req.context_len)


def summarize_by_tenant(
    requests: Iterable[Request],
    *,
    weights: Optional[Dict[str, float]] = None,
    makespan: Optional[float] = None,
) -> FairnessReport:
    reqs = list(requests)
    by_tenant: Dict[str, List[Request]] = {}
    for r in reqs:
        by_tenant.setdefault(r.tenant, []).append(r)
    per_tenant = {
        t: summarize(rs, makespan=makespan) for t, rs in sorted(by_tenant.items())
    }
    service = {
        t: sum(request_service_tokens(r) for r in rs)
        for t, rs in sorted(by_tenant.items())
    }
    weights = weights or {}
    normalized = {t: s / float(weights.get(t, 1.0)) for t, s in service.items()}
    vals = list(normalized.values())
    delta = (max(vals) - min(vals)) if vals else float("nan")
    return FairnessReport(
        per_tenant=per_tenant,
        service_tokens=service,
        normalized_service=normalized,
        jain=jain_index(vals),
        max_service_delta=delta,
    )


# ---------------------------------------------------------------------------
# SLO attainment metrics
# ---------------------------------------------------------------------------


@dataclass
class SLOTenantReport:
    """Attainment buckets for one tenant — every terminal request lands in
    exactly ONE of {attained, violated, shed, rejected}:

      * ``attained``  — served to completion, every configured SLO met
        (vacuously attained when the tenant has no SLOs)
      * ``violated``  — served to completion past a TTFT or E2E target
      * ``shed``      — retired WITHOUT service by SLO load shedding
        (``Request.shed_reason``: "admission" or "deadline")
      * ``rejected``  — refused without service for a non-SLO reason
        (hard token-bucket quota)

    ``finished`` is attained + violated (requests that completed service);
    the bucket identity attained + violated + shed == finished + rejected +
    (shed - rejected) reduces to the partition check
    attained + violated + shed + rejected == terminal requests, which the
    property suite fuzzes.  ``attainment`` counts sheds against the tenant:
    attained / (attained + violated + shed)."""

    attained: int
    violated: int
    shed: int
    rejected: int
    finished: int
    attainment: float
    ttft_slack_s: Dict[str, float]   # percentiles of (ttft_slo - ttft), finished reqs
    e2e_slack_s: Dict[str, float]    # percentiles of (e2e_slo - e2e), finished reqs


@dataclass
class SLOReport:
    """Per-tenant SLO-attainment gauges for one serving run (the llmserve
    prometheus-exporter shape, aggregated at end of run)."""

    per_tenant: Dict[str, SLOTenantReport]
    attained: int
    violated: int
    shed: int
    rejected: int
    attainment: float

    def row(self) -> Dict[str, float]:
        out = {
            "attained": float(self.attained),
            "violated": float(self.violated),
            "shed": float(self.shed),
            "attainment": self.attainment,
        }
        for t, rep in self.per_tenant.items():
            out[f"{t}/attainment"] = rep.attainment
            out[f"{t}/violated"] = float(rep.violated)
            out[f"{t}/shed"] = float(rep.shed)
        return out


# ---------------------------------------------------------------------------
# fault-tolerance / chaos metrics
# ---------------------------------------------------------------------------


@dataclass
class RobustnessReport:
    """One serving run's fault-tolerance summary.

    ``recovered_resumable`` counts failovers that re-placed a host-staged KV
    record on a survivor (zero re-prefilled tokens — the acceptance metric
    of ``bench_failover``); ``requeued_reprefill`` counts retries that had
    to fold-and-recompute.  ``shed_replica_failure`` are terminal sheds
    after ``max_retries`` (or a fully dead fleet).  ``faults_fired`` is the
    injector's total — a chaos run that fired nothing tested nothing."""

    replicas_died: int = 0
    failovers: int = 0
    recovered_resumable: int = 0
    requeued_reprefill: int = 0
    retries: int = 0
    shed_replica_failure: int = 0
    quarantined: int = 0             # NaN/Inf-quarantined requests
    expired_handoffs: int = 0        # TTL'd out of the handoff store
    crash_unwinds: int = 0           # serve-loop crash cleanups
    colocated_fallbacks: int = 0     # degraded-pool colocation decisions
    faults_fired: int = 0
    events: List[str] = None

    def row(self) -> Dict[str, float]:
        return {
            "replicas_died": float(self.replicas_died),
            "failovers": float(self.failovers),
            "recovered_resumable": float(self.recovered_resumable),
            "requeued_reprefill": float(self.requeued_reprefill),
            "shed_replica_failure": float(self.shed_replica_failure),
            "quarantined": float(self.quarantined),
            "expired_handoffs": float(self.expired_handoffs),
            "crash_unwinds": float(self.crash_unwinds),
            "faults_fired": float(self.faults_fired),
        }


def summarize_robustness(rstats, *, injector=None, quarantined: int = 0,
                         crash_unwinds: int = 0,
                         crash_shed: int = 0) -> RobustnessReport:
    """Fold a router's ``FailoverStats`` (plus per-replica counters the
    router does not own — quarantines, crash unwinds, and local
    retry-exhaustion sheds) into a report."""
    return RobustnessReport(
        replicas_died=rstats.replicas_died,
        failovers=rstats.failovers,
        recovered_resumable=rstats.recovered_resumable,
        requeued_reprefill=rstats.requeued_reprefill,
        retries=rstats.retries,
        shed_replica_failure=rstats.shed_replica_failure + crash_shed,
        quarantined=quarantined,
        expired_handoffs=rstats.expired_handoffs,
        crash_unwinds=crash_unwinds,
        colocated_fallbacks=rstats.colocated_fallbacks,
        faults_fired=(injector.count() if injector is not None else 0),
        events=list(rstats.events),
    )


def summarize_slo(requests: Iterable[Request], registry) -> SLOReport:
    """Classify every request into the attainment buckets against its
    tenant's ``ttft_slo_s``/``e2e_slo_s``.  ``registry`` is duck-typed:
    ``.get(name) -> spec`` (a ``TenantRegistry`` works).  Requests still in
    flight (not FINISHED) are not counted in any bucket."""
    by_tenant: Dict[str, List[Request]] = {}
    for r in requests:
        by_tenant.setdefault(r.tenant, []).append(r)

    per_tenant: Dict[str, SLOTenantReport] = {}
    for t, rs in sorted(by_tenant.items()):
        spec = registry.get(t)
        ttft_slo = getattr(spec, "ttft_slo_s", None)
        e2e_slo = getattr(spec, "e2e_slo_s", None)
        attained = violated = shed = rejected = 0
        ttft_slack: List[float] = []
        e2e_slack: List[float] = []
        for r in rs:
            if r.finish_time is not None:
                viol = False
                if ttft_slo is not None and r.ttft() is not None:
                    ttft_slack.append(ttft_slo - r.ttft())
                    viol |= r.ttft() > ttft_slo
                if e2e_slo is not None and r.e2e_latency() is not None:
                    e2e_slack.append(e2e_slo - r.e2e_latency())
                    viol |= r.e2e_latency() > e2e_slo
                if viol:
                    violated += 1
                else:
                    attained += 1
            elif r.state == RequestState.FINISHED:
                # terminal without service: SLO shed or hard-quota reject
                if r.shed_reason is not None:
                    shed += 1
                else:
                    rejected += 1
        per_tenant[t] = SLOTenantReport(
            attained=attained,
            violated=violated,
            shed=shed,
            rejected=rejected,
            finished=attained + violated,
            attainment=attained / max(attained + violated + shed, 1),
            ttft_slack_s=percentiles(ttft_slack),
            e2e_slack_s=percentiles(e2e_slack),
        )

    tot_a = sum(r.attained for r in per_tenant.values())
    tot_v = sum(r.violated for r in per_tenant.values())
    tot_s = sum(r.shed for r in per_tenant.values())
    tot_r = sum(r.rejected for r in per_tenant.values())
    return SLOReport(
        per_tenant=per_tenant,
        attained=tot_a,
        violated=tot_v,
        shed=tot_s,
        rejected=tot_r,
        attainment=tot_a / max(tot_a + tot_v + tot_s, 1),
    )
