"""Latency metrics: request-level and prefill-level summaries (§4.2).

L_req = finish - arrive; L_pf = prefill_done - arrive; TTFT; TPOT.
Percentile statistics are the primary summary (high-percentile latency is
more informative than the mean in interactive serving).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.request import Request

PCTS = (50, 80, 90, 95, 99)


def percentiles(xs: Sequence[float], pcts=PCTS) -> Dict[str, float]:
    if len(xs) == 0:
        return {f"p{p}": float("nan") for p in pcts} | {"mean": float("nan")}
    arr = np.asarray(xs, np.float64)
    out = {f"p{p}": float(np.percentile(arr, p)) for p in pcts}
    out["mean"] = float(arr.mean())
    out["max"] = float(arr.max())
    return out


@dataclass
class LatencyReport:
    e2e: Dict[str, float]
    ttft: Dict[str, float]
    prefill_e2e: Dict[str, float]
    tpot: Dict[str, float]
    n_finished: int
    n_total: int
    makespan: float
    throughput_rps: float

    def row(self) -> Dict[str, float]:
        return {
            "mean_e2e": self.e2e["mean"],
            "p95_e2e": self.e2e["p95"],
            "p99_e2e": self.e2e["p99"],
            "mean_ttft": self.ttft["mean"],
            "p95_ttft": self.ttft["p95"],
            "p99_ttft": self.ttft["p99"],
            "mean_prefill": self.prefill_e2e["mean"],
            "p90_prefill": self.prefill_e2e["p90"],
            "p99_prefill": self.prefill_e2e["p99"],
            "mean_tpot": self.tpot["mean"],
            "throughput_rps": self.throughput_rps,
        }


def summarize(requests: Iterable[Request], makespan: Optional[float] = None) -> LatencyReport:
    reqs = list(requests)
    fin = [r for r in reqs if r.finish_time is not None]
    e2e = [r.e2e_latency() for r in fin]
    ttft = [r.ttft() for r in reqs if r.ttft() is not None]
    pf = [r.prefill_e2e() for r in reqs if r.prefill_e2e() is not None]
    tpot = [
        (r.finish_time - r.first_token_time) / max(r.generated - 1, 1)
        for r in fin
        if r.first_token_time is not None and r.generated > 1
    ]
    ms = makespan if makespan is not None else (
        max((r.finish_time for r in fin), default=0.0)
        - min((r.arrival_time for r in reqs), default=0.0)
    )
    return LatencyReport(
        e2e=percentiles(e2e),
        ttft=percentiles(ttft),
        prefill_e2e=percentiles(pf),
        tpot=percentiles(tpot),
        n_finished=len(fin),
        n_total=len(reqs),
        makespan=ms,
        throughput_rps=len(fin) / ms if ms > 0 else float("nan"),
    )


def cdf_points(xs: Sequence[float], n: int = 100) -> List[tuple]:
    arr = np.sort(np.asarray(xs, np.float64))
    return [(float(arr[int(q * (len(arr) - 1))]), q) for q in np.linspace(0, 1, n)]


# ---------------------------------------------------------------------------
# KV memory-subsystem metrics
# ---------------------------------------------------------------------------


@dataclass
class MemoryReport:
    """One serving run's KV lifecycle summary: prefix-cache effectiveness,
    eviction/preemption pressure, and per-tenant block occupancy."""

    cache_lookups: int
    cache_hit_blocks: int
    cache_miss_blocks: int
    cache_hit_rate: float            # block-level, over all prefix lookups
    cache_hit_tokens: int            # prefill tokens skipped via the cache
    evictions: int                   # cached blocks reclaimed for new allocs
    preemptions: int                 # requests evicted for KV pressure
    kv_deferrals: int                # chunks shrunk/deferred for lack of blocks
    used_blocks: int                 # referenced blocks at end of run
    cached_blocks: int               # refcount-0 blocks held by the cache
    free_blocks: int
    utilization: float
    blocks_by_tenant: Dict[str, int]
    # swap-out preemption traffic (0 everywhere in recompute mode)
    swap_preemptions: int = 0        # victims staged host-side, not recomputed
    swap_restores: int = 0           # staged victims swapped back in
    swapped_out_tokens: int = 0      # Σ tokens moved device -> host
    swapped_in_tokens: int = 0       # Σ tokens moved host -> device

    def row(self) -> Dict[str, float]:
        return {
            "cache_hit_rate": self.cache_hit_rate,
            "cache_hit_tokens": float(self.cache_hit_tokens),
            "evictions": float(self.evictions),
            "preemptions": float(self.preemptions),
            "kv_deferrals": float(self.kv_deferrals),
            "kv_utilization": self.utilization,
            "swap_preemptions": float(self.swap_preemptions),
            "swap_restores": float(self.swap_restores),
        }


def summarize_memory(pool, scheduler_stats=None) -> MemoryReport:
    """Build a MemoryReport from a ``KVBlockPool`` (and optionally the
    scheduler's stats, which own the preemption/deferral counters)."""
    s = pool.stats
    return MemoryReport(
        cache_lookups=s.lookups,
        cache_hit_blocks=s.hit_blocks,
        cache_miss_blocks=s.miss_blocks,
        cache_hit_rate=s.hit_rate,
        cache_hit_tokens=s.hit_tokens,
        evictions=s.evictions,
        preemptions=getattr(scheduler_stats, "preemptions", 0),
        kv_deferrals=getattr(scheduler_stats, "kv_deferrals", 0),
        used_blocks=pool.used_blocks,
        cached_blocks=pool.cached_blocks,
        free_blocks=len(pool.free_blocks),
        utilization=pool.utilization(),
        blocks_by_tenant=pool.blocks_by_tenant(),
        swap_preemptions=getattr(scheduler_stats, "swap_preemptions", 0),
        swap_restores=getattr(scheduler_stats, "swap_restores", 0),
        swapped_out_tokens=s.swapped_out_tokens,
        swapped_in_tokens=s.swapped_in_tokens,
    )


# ---------------------------------------------------------------------------
# multi-tenant fairness metrics
# ---------------------------------------------------------------------------


def jain_index(xs: Iterable[float]) -> float:
    """Jain's fairness index: (Σx)² / (n·Σx²).  1.0 = perfectly even,
    1/n = one party gets everything.  Empty input → NaN (undefined);
    a single party, or all-zero allocations, → 1.0 (trivially fair)."""
    arr = np.asarray(list(xs), np.float64)
    if arr.size == 0:
        return float("nan")
    ss = float((arr * arr).sum())
    if ss == 0.0:
        return 1.0
    s = float(arr.sum())
    return s * s / (arr.size * ss)


@dataclass
class FairnessReport:
    """Per-tenant latency + service summary for one serving run.

    ``service_tokens``: tokens actually delivered per tenant (prefill
    progress + generated tokens).  ``normalized_service`` divides by the
    tenant's weight — the quantity the VTC equalizes.  ``jain`` is Jain's
    index over normalized service; ``max_service_delta`` is the worst-case
    spread (max - min) of normalized service, the VTC paper's service-bound
    metric.
    """

    per_tenant: Dict[str, LatencyReport]
    service_tokens: Dict[str, float]
    normalized_service: Dict[str, float]
    jain: float
    max_service_delta: float

    def row(self) -> Dict[str, float]:
        out = {"jain": self.jain, "max_service_delta": self.max_service_delta}
        for t, rep in self.per_tenant.items():
            out[f"{t}/p99_ttft"] = rep.ttft["p99"]
            out[f"{t}/mean_e2e"] = rep.e2e["mean"]
            out[f"{t}/service_tokens"] = self.service_tokens[t]
        return out


def request_service_tokens(req: Request) -> float:
    """Tokens the engine actually delivered to one request so far.
    ``context_len`` nets out tokens a preemption folded into the prompt, so
    recompute work is never double-counted as delivered service."""
    return float(req.context_len)


def summarize_by_tenant(
    requests: Iterable[Request],
    *,
    weights: Optional[Dict[str, float]] = None,
    makespan: Optional[float] = None,
) -> FairnessReport:
    reqs = list(requests)
    by_tenant: Dict[str, List[Request]] = {}
    for r in reqs:
        by_tenant.setdefault(r.tenant, []).append(r)
    per_tenant = {
        t: summarize(rs, makespan=makespan) for t, rs in sorted(by_tenant.items())
    }
    service = {
        t: sum(request_service_tokens(r) for r in rs)
        for t, rs in sorted(by_tenant.items())
    }
    weights = weights or {}
    normalized = {t: s / float(weights.get(t, 1.0)) for t, s in service.items()}
    vals = list(normalized.values())
    delta = (max(vals) - min(vals)) if vals else float("nan")
    return FairnessReport(
        per_tenant=per_tenant,
        service_tokens=service,
        normalized_service=normalized,
        jain=jain_index(vals),
        max_service_delta=delta,
    )
