"""Discrete-event serving simulator.

The scheduler under test is the REAL ``repro.core`` code; only the execution
clock comes from the calibrated cost model (Vidur-style).  This reproduces
the paper's scheduling results faithfully: its own ablation (§4.3.1) shows the
Aging/LPRS/APC gains are queueing/ordering effects, with model execution time
unchanged.

Event loop per round:
  1. admit arrivals with arrival_time <= now (prefix-cache matched at submit:
     cached prompt blocks are acquired and the request's remaining prefill
     shrinks before it ever enters the queue),
  2. scheduler.schedule(now) -> batch (the scheduler books KV blocks
     chunk-granularly and preempts under pressure),
  3. advance clock by the cost model's batch latency (or to the next arrival
     when idle),
  4. scheduler.on_batch_done(batch, now) — also releases finished requests'
     KV references back to the pool/prefix cache.

``legacy_eager_kv=True`` restores the pre-refactor behavior (whole-prompt
allocation at admission, head-of-line blocking when the pool is full, decode
tokens silently unbooked under pressure) for A/B comparison in
``benchmarks/bench_prefix_cache.py``.

Also emits (features, latency) training samples for the LPRS predictor — the
paper's offline profiling pipeline (§3.2.1 step 3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel
from repro.engine.kv_cache import KVBlockPool
from repro.engine.metrics import (
    LatencyReport, MemoryReport, SLOReport, summarize, summarize_memory,
    summarize_slo,
)


@dataclass
class SimResult:
    report: LatencyReport
    requests: List[Request]
    rounds: int
    sim_time_s: float
    samples: Optional[Tuple[np.ndarray, np.ndarray]] = None  # (features, latency_ms)
    scheduler_stats: Optional[object] = None
    memory: Optional[MemoryReport] = None     # KV pool lifecycle summary
    slo: Optional[SLOReport] = None           # per-tenant attainment gauges


class ServingSimulator:
    def __init__(
        self,
        scheduler: ChunkedPrefillScheduler,
        cost_model: CostModel,
        *,
        kv_pool: Optional[KVBlockPool] = None,
        collect_samples: bool = False,
        idle_step_s: float = 0.001,
        max_rounds: int = 2_000_000,
        horizon_s: Optional[float] = None,
        legacy_eager_kv: bool = False,
        preemption_mode: str = "recompute",
    ):
        self.sched = scheduler
        self.cost = cost_model
        self.kv_pool = kv_pool
        self.collect_samples = collect_samples
        self.idle_step_s = idle_step_s
        self.max_rounds = max_rounds
        self.horizon_s = horizon_s    # stop mid-backlog at this sim time
        self.legacy_eager_kv = legacy_eager_kv
        if kv_pool is not None:
            # the scheduler owns block booking (unless running the legacy
            # eager-admission baseline, where the pool is features-only)
            scheduler.attach_kv_pool(kv_pool, booking=not legacy_eager_kv)
            if not legacy_eager_kv:
                # accounting-only swap (no engine hooks: records are ready
                # immediately); the cost model prices the transfers into the
                # round latency and decides swap-vs-recompute per victim
                scheduler.attach_swap(cost_model=cost_model,
                                      mode=preemption_mode)

    def run(self, requests: List[Request]) -> SimResult:
        pending = sorted(requests, key=lambda r: r.arrival_time)
        next_arrival = 0
        now = 0.0
        rounds = 0
        feats: List[np.ndarray] = []
        lats: List[float] = []

        def admit():
            nonlocal next_arrival
            while next_arrival < len(pending) and pending[next_arrival].arrival_time <= now:
                req = pending[next_arrival]
                if self.kv_pool is not None:
                    if self.legacy_eager_kv:
                        # legacy admission: the ENTIRE prompt must fit the
                        # pool up front or nobody behind this request enters
                        if not self.kv_pool.can_allocate(req.req_id, req.prompt_len,
                                                         tenant=req.tenant):
                            break
                        self.kv_pool.allocate(req.req_id, req.prompt_len,
                                              tenant=req.tenant)
                    else:
                        # register tenant + prompt hashes; a prefix-cache hit
                        # skips the matched prefill work at submit
                        self.kv_pool.submit_request(req)
                if not self.sched.submit(req) and self.kv_pool is not None:
                    self.kv_pool.release(req.req_id)   # admission-rejected
                next_arrival += 1

        while rounds < self.max_rounds:
            if self.horizon_s is not None and now >= self.horizon_s:
                break
            admit()
            if not self.sched.has_work():
                if next_arrival >= len(pending):
                    break
                now = max(now + self.idle_step_s, pending[next_arrival].arrival_time)
                continue

            batch = self.sched.schedule(now)
            if batch.is_empty():
                # nothing schedulable (e.g. APC blocked everything): advance a tick
                now += self.idle_step_s
                continue

            latency_ms = self.cost.batch_latency_ms(batch)
            if self.collect_samples:
                feats.append(batch.state.features())
                lats.append(latency_ms)

            now += latency_ms / 1000.0
            rounds += 1

            if self.kv_pool is not None and self.legacy_eager_kv:
                # legacy decode accounting (the bug the refactor fixes: a full
                # pool silently generates tokens with no blocks booked)
                for r in batch.decode_reqs:
                    if self.kv_pool.can_allocate(r.req_id, 1):
                        self.kv_pool.allocate(r.req_id, 1, tenant=r.tenant)

            self.sched.on_batch_done(batch, now)

            if self.kv_pool is not None and self.legacy_eager_kv:
                for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
                    if r.state == RequestState.FINISHED:
                        self.kv_pool.release(r.req_id)

        samples = (
            (np.stack(feats), np.asarray(lats)) if self.collect_samples and feats else None
        )
        return SimResult(
            report=summarize(requests, makespan=now),
            requests=requests,
            rounds=rounds,
            sim_time_s=now,
            samples=samples,
            scheduler_stats=self.sched.stats,
            memory=(
                summarize_memory(self.kv_pool, self.sched.stats)
                if self.kv_pool is not None else None
            ),
            slo=(
                summarize_slo(requests, self.sched.fairness.registry)
                if self.sched.fairness is not None else None
            ),
        )


def run_policy(
    requests: List[Request],
    scheduler_cfg: SchedulerConfig,
    *,
    cost_model: Optional[CostModel] = None,
    predictor=None,
    kv_pool: Optional[KVBlockPool] = None,
    collect_samples: bool = False,
    horizon_s: Optional[float] = None,
    legacy_eager_kv: bool = False,
    preemption_mode: str = "recompute",
) -> SimResult:
    """Convenience wrapper: fresh scheduler + simulator over a request list.

    NOTE: Request objects are stateful; pass freshly-generated requests.
    """
    sched = ChunkedPrefillScheduler(
        scheduler_cfg, predictor=predictor, kv_pool=kv_pool,
        kv_booking=not legacy_eager_kv,
    )
    sim = ServingSimulator(
        sched, cost_model or CostModel(), kv_pool=kv_pool,
        collect_samples=collect_samples, horizon_s=horizon_s,
        legacy_eager_kv=legacy_eager_kv, preemption_mode=preemption_mode,
    )
    return sim.run(requests)
