"""Workload generation: ShareGPT-like mixed prompt lengths + arrival
processes matching the paper's setups (§4.1).

The paper's 200-request ShareGPT replay has median prompt 19.0 tokens and P90
179.4 — a heavily right-skewed distribution.  ``sharegpt_like`` draws from a
log-normal fitted to those two quantiles (mu = ln 19, sigma from the P90/P50
ratio), clipped to the context limit; generation lengths are similarly skewed
and capped at 512 per the paper.

``apc_heterogeneous`` reproduces §4.1's APC ablation mix: 49:1 short
(30-50 tok) to long (200-220 tok) prompts with dynamic arrival rates.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.request import Request

# log-normal fit to the paper's ShareGPT stats: P50 = 19, P90 = 179.4
_SG_MU = math.log(19.0)
_SG_SIGMA = math.log(179.4 / 19.0) / 1.2815515655  # z_{0.9}


@dataclass
class WorkloadSpec:
    n_requests: int = 200
    inter_arrival_s: float = 0.1       # fixed interval (paper) ...
    poisson: bool = False              # ... or Poisson with the same mean rate
    max_context: int = 512
    max_new_tokens: int = 512
    seed: int = 0


def sharegpt_like(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    prompt = np.clip(
        np.round(rng.lognormal(_SG_MU, _SG_SIGMA, spec.n_requests)), 1, spec.max_context
    ).astype(int)
    # generation lengths: skewed, capped (paper: max 512)
    gen = np.clip(
        np.round(rng.lognormal(math.log(60.0), 1.0, spec.n_requests)), 1, spec.max_new_tokens
    ).astype(int)
    if spec.poisson:
        gaps = rng.exponential(spec.inter_arrival_s, spec.n_requests)
    else:
        gaps = np.full(spec.n_requests, spec.inter_arrival_s)
    arrivals = np.concatenate([[0.0], np.cumsum(gaps[:-1])])
    return [
        Request(prompt_len=int(p), max_new_tokens=int(g), arrival_time=float(a))
        for p, g, a in zip(prompt, gen, arrivals)
    ]


def apc_heterogeneous(
    n_requests: int = 1000,
    *,
    short_ratio: int = 49,
    long_ratio: int = 1,
    short_range=(30, 50),
    long_range=(200, 220),
    max_new_tokens: int = 64,
    base_interval_s: float = 0.02,
    seed: int = 0,
) -> List[Request]:
    """§4.1 APC ablation workload: 49:1 short:long, dynamic arrival rate."""
    rng = np.random.default_rng(seed)
    period = short_ratio + long_ratio
    reqs: List[Request] = []
    t = 0.0
    for i in range(n_requests):
        if i % period < short_ratio:
            p = int(rng.integers(short_range[0], short_range[1] + 1))
        else:
            p = int(rng.integers(long_range[0], long_range[1] + 1))
        g = int(rng.integers(8, max_new_tokens + 1))
        reqs.append(Request(prompt_len=p, max_new_tokens=g, arrival_time=t))
        # dynamically varying arrival rate (paper: "could change dynamically")
        burst = 0.3 if (i // 100) % 2 == 0 else 1.7
        t += float(rng.exponential(base_interval_s * burst))
    return reqs


def uniform_arrivals(
    n_requests: int,
    interval_s: float,
    *,
    prompt_sampler=None,
    max_seq_len: int = 4096,
    max_new_tokens: int = 256,
    seed: int = 0,
) -> List[Request]:
    """LPRS workloads (§4.4): 1000 requests, uniform 0.1 s / 1.0 s arrivals,
    max sequence length 4096."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        if prompt_sampler is not None:
            p = int(prompt_sampler(rng))
        else:
            p = int(
                np.clip(round(rng.lognormal(math.log(200.0), 1.1)), 8, max_seq_len - max_new_tokens)
            )
        g = int(rng.integers(16, max_new_tokens + 1))
        reqs.append(
            Request(prompt_len=p, max_new_tokens=g, arrival_time=i * interval_s)
        )
    return reqs


def shared_prefix(
    n_requests: int = 200,
    *,
    n_prefixes: int = 4,
    prefix_len: int = 128,
    suffix_range=(16, 64),
    max_new_tokens: int = 32,
    inter_arrival_s: float = 0.05,
    vocab_size: int = 32000,
    tenants: Optional[List[str]] = None,
    seed: int = 0,
) -> List[Request]:
    """Prefix-cache workload: every prompt is one of ``n_prefixes`` shared
    system prompts (``prefix_len`` tokens) followed by a unique user suffix —
    the RAG/chat-template pattern prefix caching exists for.  Requests carry
    real ``prompt_tokens`` so the block-hash prefix cache works in both the
    simulator and the engine; with caching on, every repeat of a prefix skips
    ``block_size``-aligned prefill work."""
    rng = np.random.default_rng(seed)
    prefixes = [
        rng.integers(1, vocab_size, prefix_len).tolist() for _ in range(n_prefixes)
    ]
    reqs: List[Request] = []
    for i in range(n_requests):
        prefix = prefixes[int(rng.integers(0, n_prefixes))]
        suffix_len = int(rng.integers(suffix_range[0], suffix_range[1] + 1))
        tokens = prefix + rng.integers(1, vocab_size, suffix_len).tolist()
        reqs.append(Request(
            prompt_len=len(tokens),
            max_new_tokens=int(rng.integers(max(1, max_new_tokens // 2),
                                            max_new_tokens + 1)),
            arrival_time=i * inter_arrival_s,
            prompt_tokens=tokens,
            tenant=tenants[i % len(tenants)] if tenants else "default",
        ))
    return reqs


@dataclass(frozen=True)
class TenantTraffic:
    """One tenant's arrival process for ``multi_tenant``.

    ``kind`` picks sane defaults for unset fields:
      * ``heavy``  — high rate, long prompts (the bully tenant)
      * ``light``  — low rate, short prompts (interactive clients)
      * ``bursty`` — light, but arrivals concentrate in on/off bursts
    """

    name: str
    kind: str = "light"                    # heavy | light | bursty
    rps: Optional[float] = None            # mean arrival rate (Poisson)
    prompt_mean: Optional[float] = None    # log-normal median prompt length
    prompt_sigma: float = 0.6
    max_new_tokens: int = 64
    burst_period_s: float = 5.0            # bursty only: on+off cycle length
    burst_duty: float = 0.2                # bursty only: fraction of cycle "on"

    _KIND_DEFAULTS = {
        "heavy": {"rps": 8.0, "prompt_mean": 200.0},
        "light": {"rps": 1.0, "prompt_mean": 30.0},
        "bursty": {"rps": 1.0, "prompt_mean": 30.0},
    }

    def resolved(self) -> "TenantTraffic":
        if self.kind not in self._KIND_DEFAULTS:
            raise ValueError(f"unknown tenant traffic kind {self.kind!r}")
        d = self._KIND_DEFAULTS[self.kind]
        return dataclasses.replace(
            self,
            rps=self.rps if self.rps is not None else d["rps"],
            prompt_mean=(
                self.prompt_mean if self.prompt_mean is not None else d["prompt_mean"]
            ),
        )


def default_tenant_mix(n_light: int = 4) -> List[TenantTraffic]:
    """The bench's 1-heavy/N-light mix."""
    return [TenantTraffic("heavy0", "heavy")] + [
        TenantTraffic(f"light{i}", "light") for i in range(n_light)
    ]


def multi_tenant(
    tenants: Optional[List[TenantTraffic]] = None,
    *,
    duration_s: float = 30.0,
    max_context: int = 512,
    seed: int = 0,
) -> List[Request]:
    """Merged multi-tenant arrival trace: independent Poisson (or on/off
    burst) streams per tenant, tagged with ``Request.tenant``, sorted by
    arrival time."""
    tenants = [t.resolved() for t in (tenants or default_tenant_mix())]
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for spec in tenants:
        # random phase offset per tenant so bursty tenants don't synchronize
        phase0 = float(rng.uniform(0.0, spec.burst_period_s))
        # first arrival is one inter-arrival gap in (a true Poisson process —
        # not a deterministic all-tenant collision at t=0)
        if spec.kind == "bursty":
            t = float(rng.exponential(spec.burst_duty / spec.rps))
        else:
            t = float(rng.exponential(1.0 / spec.rps))
        while t < duration_s:
            if spec.kind == "bursty":
                phase = (t + phase0) % spec.burst_period_s
                on_len = spec.burst_duty * spec.burst_period_s
                if phase >= on_len:                 # in the off window: skip ahead
                    t += spec.burst_period_s - phase
                    continue
                # compress the whole cycle's arrivals into the on window
                gap = float(rng.exponential(spec.burst_duty / spec.rps))
            else:
                gap = float(rng.exponential(1.0 / spec.rps))
            p = int(np.clip(
                round(rng.lognormal(math.log(spec.prompt_mean), spec.prompt_sigma)),
                1, max_context,
            ))
            g = int(rng.integers(max(1, spec.max_new_tokens // 4),
                                 spec.max_new_tokens + 1))
            reqs.append(Request(
                prompt_len=p, max_new_tokens=g, arrival_time=t, tenant=spec.name,
            ))
            t += gap
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs


def attach_prompt_tokens(reqs: List[Request], vocab_size: int, seed: int = 0) -> None:
    """Real-engine mode: synthesize token ids for each prompt."""
    rng = np.random.default_rng(seed)
    for r in reqs:
        r.prompt_tokens = rng.integers(1, vocab_size, r.prompt_len).tolist()
