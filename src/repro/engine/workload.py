"""Workload generation: ShareGPT-like mixed prompt lengths + arrival
processes matching the paper's setups (§4.1).

The paper's 200-request ShareGPT replay has median prompt 19.0 tokens and P90
179.4 — a heavily right-skewed distribution.  ``sharegpt_like`` draws from a
log-normal fitted to those two quantiles (mu = ln 19, sigma from the P90/P50
ratio), clipped to the context limit; generation lengths are similarly skewed
and capped at 512 per the paper.

``apc_heterogeneous`` reproduces §4.1's APC ablation mix: 49:1 short
(30-50 tok) to long (200-220 tok) prompts with dynamic arrival rates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.request import Request

# log-normal fit to the paper's ShareGPT stats: P50 = 19, P90 = 179.4
_SG_MU = math.log(19.0)
_SG_SIGMA = math.log(179.4 / 19.0) / 1.2815515655  # z_{0.9}


@dataclass
class WorkloadSpec:
    n_requests: int = 200
    inter_arrival_s: float = 0.1       # fixed interval (paper) ...
    poisson: bool = False              # ... or Poisson with the same mean rate
    max_context: int = 512
    max_new_tokens: int = 512
    seed: int = 0


def sharegpt_like(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    prompt = np.clip(
        np.round(rng.lognormal(_SG_MU, _SG_SIGMA, spec.n_requests)), 1, spec.max_context
    ).astype(int)
    # generation lengths: skewed, capped (paper: max 512)
    gen = np.clip(
        np.round(rng.lognormal(math.log(60.0), 1.0, spec.n_requests)), 1, spec.max_new_tokens
    ).astype(int)
    if spec.poisson:
        gaps = rng.exponential(spec.inter_arrival_s, spec.n_requests)
    else:
        gaps = np.full(spec.n_requests, spec.inter_arrival_s)
    arrivals = np.concatenate([[0.0], np.cumsum(gaps[:-1])])
    return [
        Request(prompt_len=int(p), max_new_tokens=int(g), arrival_time=float(a))
        for p, g, a in zip(prompt, gen, arrivals)
    ]


def apc_heterogeneous(
    n_requests: int = 1000,
    *,
    short_ratio: int = 49,
    long_ratio: int = 1,
    short_range=(30, 50),
    long_range=(200, 220),
    max_new_tokens: int = 64,
    base_interval_s: float = 0.02,
    seed: int = 0,
) -> List[Request]:
    """§4.1 APC ablation workload: 49:1 short:long, dynamic arrival rate."""
    rng = np.random.default_rng(seed)
    period = short_ratio + long_ratio
    reqs: List[Request] = []
    t = 0.0
    for i in range(n_requests):
        if i % period < short_ratio:
            p = int(rng.integers(short_range[0], short_range[1] + 1))
        else:
            p = int(rng.integers(long_range[0], long_range[1] + 1))
        g = int(rng.integers(8, max_new_tokens + 1))
        reqs.append(Request(prompt_len=p, max_new_tokens=g, arrival_time=t))
        # dynamically varying arrival rate (paper: "could change dynamically")
        burst = 0.3 if (i // 100) % 2 == 0 else 1.7
        t += float(rng.exponential(base_interval_s * burst))
    return reqs


def uniform_arrivals(
    n_requests: int,
    interval_s: float,
    *,
    prompt_sampler=None,
    max_seq_len: int = 4096,
    max_new_tokens: int = 256,
    seed: int = 0,
) -> List[Request]:
    """LPRS workloads (§4.4): 1000 requests, uniform 0.1 s / 1.0 s arrivals,
    max sequence length 4096."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        if prompt_sampler is not None:
            p = int(prompt_sampler(rng))
        else:
            p = int(
                np.clip(round(rng.lognormal(math.log(200.0), 1.1)), 8, max_seq_len - max_new_tokens)
            )
        g = int(rng.integers(16, max_new_tokens + 1))
        reqs.append(
            Request(prompt_len=p, max_new_tokens=g, arrival_time=i * interval_s)
        )
    return reqs


def attach_prompt_tokens(reqs: List[Request], vocab_size: int, seed: int = 0) -> None:
    """Real-engine mode: synthesize token ids for each prompt."""
    rng = np.random.default_rng(seed)
    for r in reqs:
        r.prompt_tokens = rng.integers(1, vocab_size, r.prompt_len).tolist()
