"""Real-execution chunked-prefill engine: the paper's serving loop running
actual JAX forward passes (tiny models on CPU; the identical program compiles
for TPU).

Continuous batching with PAGED KV storage (vLLM layout, the default):
  * ``n_slots`` fixed *batch rows*; a request binds a slot at its FIRST
    scheduled chunk (late binding — queued or admission-delayed backlog pins
    nothing) and keeps it until it finishes or is preempted.
  * K/V live in a physical page pool ``(layers, n_blocks + 1, block_size,
    kv_heads, head_dim)`` whose page ids are exactly the ``KVBlockPool``'s
    block ids, addressed through per-slot block tables.  Capacity scales with
    resident tokens, not ``n_slots x max_context``; prefix-cache hits need no
    payload copy (the matched blocks' pages are still resident); the last
    page is a write sink for padding lanes.
  * One jitted ``chunked_step_paged`` per scheduling round executes the
    ENTIRE mixed batch — decode slots advance by 1 token (via the paged
    flash-decode kernel when the round is a pure single-token bucket),
    prefill slots by their scheduled chunk (paged chunked-prefill kernel),
    idle slots by 0 — under static bucketed shapes.
  * ``EngineConfig(paged_kv=False)`` keeps the dense slot cache
    ``(layers, n_slots, max_context + 1, ...)`` for A/B: greedy-sampled
    outputs are identical between the two layouts.
  * The scheduler under test is the real ``repro.core`` code; latencies are
    wall-clock, so the LPRS predictor can be trained on real measurements.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, ScheduledBatch
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig, PAGED_RESIDENT
from repro.engine.metrics import LatencyReport, MemoryReport, summarize, summarize_memory
from repro.engine.sampler import SamplerConfig, sample_tokens
from repro.models.model import Model, build_model


@dataclass
class EngineConfig:
    n_slots: int = 16
    max_context: int = 1024
    chunk_buckets: Tuple[int, ...] = (1, 16, 32, 64, 128, 256)
    use_pallas: bool = False          # True: Pallas kernels (interpret on CPU)
    paged_kv: bool = True             # block-table pages; False = dense slots
    kv_block_size: int = 16           # page size when the engine owns its pool
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    seed: int = 0


class JAXEngine:
    """Executes ScheduledBatches with real forward passes."""

    def __init__(self, model_cfg: ModelConfig, cfg: Optional[EngineConfig] = None,
                 params=None, kv_pool: Optional[KVBlockPool] = None):
        self.cfg = cfg or EngineConfig()
        self.model_cfg = model_cfg
        self.model: Model = build_model(model_cfg)
        rng = jax.random.PRNGKey(self.cfg.seed)
        self.params = params if params is not None else self.model.init(rng)
        self._rng = jax.random.PRNGKey(self.cfg.seed + 1)

        B = self.cfg.n_slots
        self.slot_of: Dict[int, int] = {}          # req_id -> slot
        self.free_slots = list(range(B - 1, -1, -1))
        self.last_token = np.zeros((B,), np.int64)

        self.kv_pool: Optional[KVBlockPool] = kv_pool
        # the engine books blocks itself only while it owns a private pool;
        # an externally bound pool is booked by the scheduler
        self._owns_pool = False
        if self.cfg.paged_kv and self.kv_pool is None:
            bs = self.cfg.kv_block_size
            per_slot = math.ceil(self.cfg.max_context / bs) + 1
            self.kv_pool = KVBlockPool(KVPoolConfig(
                n_blocks=B * per_slot, block_size=bs,
            ))
            self._owns_pool = True
        self._build_state()

    # -- physical KV layout ----------------------------------------------------
    def _build_state(self) -> None:
        cfg, model_cfg = self.cfg, self.model_cfg
        B, S = cfg.n_slots, cfg.max_context
        hd = model_cfg.resolved_head_dim
        dt = jnp.dtype(model_cfg.param_dtype)
        impl = self.model.impl
        use_pallas = cfg.use_pallas

        if cfg.paged_kv:
            bs = self.kv_pool.cfg.block_size
            # physical pages = pool blocks + 1 trailing sink page (padding
            # lanes scatter there; block tables also pad with it)
            self._n_phys = self.kv_pool.cfg.n_blocks + 1
            self._sink = self.kv_pool.cfg.n_blocks
            self.max_pages = math.ceil(S / bs) + 1
            kv_shape = (model_cfg.n_layers, self._n_phys, bs,
                        model_cfg.n_kv_heads, hd)
            self.block_tables = np.full((B, self.max_pages), self._sink, np.int32)

            def step(params, tokens, cache, lens, chunk_lens, block_tables, rng):
                logits, cache = impl.chunked_step_paged(
                    params, tokens, cache, lens, chunk_lens, block_tables,
                    use_pallas=use_pallas,
                )
                toks = sample_tokens(logits, rng, self.cfg.sampler)
                return toks, cache
        else:
            kv_shape = (model_cfg.n_layers, B, S + 1, model_cfg.n_kv_heads, hd)
            self.block_tables = None

            def step(params, tokens, cache, lens, chunk_lens, rng):
                logits, cache = impl.chunked_step(
                    params, tokens, cache, lens, chunk_lens, use_pallas=use_pallas
                )
                toks = sample_tokens(logits, rng, self.cfg.sampler)
                return toks, cache

        self.cache = {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)}
        self.lens = jnp.zeros((B,), jnp.int32)
        self._step = jax.jit(step, donate_argnums=(2,))

    def bind_kv_pool(self, kv_pool: Optional[KVBlockPool]) -> None:
        """Adopt the serve loop's shared pool: the physical page array is
        rebuilt so page ids == the pool's block ids (the scheduler books
        blocks; the engine just follows the tables).  Must happen before any
        request is in flight."""
        if kv_pool is None or kv_pool is self.kv_pool:
            return
        assert not self.slot_of, "cannot rebind the KV pool mid-flight"
        self.kv_pool = kv_pool
        self._owns_pool = False
        if self.cfg.paged_kv:
            self._build_state()

    def warmup(self) -> None:
        """Compile every bucket shape once so profiling sees steady-state
        latencies, not jit compilation (the paper's 'cleaned' samples)."""
        B = self.cfg.n_slots
        for C in self.cfg.chunk_buckets:
            tokens = jnp.ones((B, C), jnp.int32)
            chunk_lens = jnp.zeros((B,), jnp.int32).at[0].set(1)
            self._rng, sub = jax.random.split(self._rng)
            args = (self.params, tokens, self.cache, self.lens, chunk_lens)
            if self.cfg.paged_kv:
                args += (jnp.asarray(self.block_tables),)
            toks, self.cache = self._step(*args, sub)
            jax.block_until_ready(toks)
        # reset cache/lens state touched by the dummy rounds (paged writes all
        # land in the sink page, which is never read back)
        self.lens = jnp.zeros((B,), jnp.int32)

    # -- slot management -------------------------------------------------------
    def acquire_slot(self, req: Request) -> bool:
        """Late slot binding: called by the scheduler when it first commits a
        chunk for ``req`` (NOT at admission — queued or rate-limit-delayed
        backlog pins no slot).  Returns True when the request holds a slot
        after the call.

        The prefix-cache lookup also happens HERE, not at admission: a
        parked backlog must not pin cached blocks (refcounts) or tenant
        quota it cannot use yet.  Only restorable blocks count — host-side
        payloads (dense) or still-resident pages (paged).  On a hit the
        dense layout copies the matched payloads into the fresh slot; the
        paged layout's matched pages are already resident (zero-copy)."""
        if req.req_id in self.slot_of:
            return True
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        self.slot_of[req.req_id] = slot
        self.last_token[slot] = 0
        if (self.kv_pool is not None and req.prefill_done == 0
                and not self.kv_pool.tables.get(req.req_id)):
            matched = self.kv_pool.match_prefix(req.req_id, require_payload=True)
            if matched > 0:
                req.prefill_done = matched
        self.lens = self.lens.at[slot].set(req.prefill_done)
        if self.cfg.paged_kv:
            self.block_tables[slot, :] = self._sink
        elif req.prefill_done > 0 and self.kv_pool is not None:
            self._restore_prefix_dense(req, slot)
        return True

    def release(self, req: Request) -> None:
        """Drop the request's slot (finish or preemption).  Idempotent.  With
        an engine-owned pool the request's blocks go back too."""
        slot = self.slot_of.pop(req.req_id, None)
        if slot is not None:
            self.free_slots.append(slot)
            if self.cfg.paged_kv:
                self.block_tables[slot, :] = self._sink
        if self._owns_pool:
            self.kv_pool.release(req.req_id)

    def has_capacity(self) -> bool:
        return len(self.free_slots) > 0

    # -- prefix-cache payloads -------------------------------------------------
    def _restore_prefix_dense(self, req: Request, slot: int) -> None:
        """Dense layout only: copy a prefix-cache hit's stored K/V payloads
        into the request's slot so the skipped prefill positions hold
        numerically identical state (causal attention: prefix KV depends only
        on prefix tokens).  At bind time ``prefill_done`` is exactly the
        matched token count."""
        kv_pool = self.kv_pool
        bs = kv_pool.cfg.block_size
        table = kv_pool.tables.get(req.req_id, [])
        n_matched = req.prefill_done // bs
        ks, vs = [], []
        for bid in table[:n_matched]:
            payload = kv_pool.payload(bid)
            assert payload is not None and payload is not PAGED_RESIDENT, (
                "dense engine prefix match requires host-side payloads"
            )
            ks.append(payload[0])
            vs.append(payload[1])
        if ks:
            # one functional update per cache tensor, not one per block
            self.cache["k"] = (
                self.cache["k"].at[:, slot, : n_matched * bs].set(jnp.concatenate(ks, axis=1))
            )
            self.cache["v"] = (
                self.cache["v"].at[:, slot, : n_matched * bs].set(jnp.concatenate(vs, axis=1))
            )

    def capture_sealed(self, req: Request) -> None:
        """Make newly sealed (full, content-addressed) prompt blocks
        restorable by future prefix hits.  Dense layout: park the K/V arrays
        host-side.  Paged layout: the data already lives at the block's
        physical page — a residency marker suffices, no copy."""
        kv_pool = self.kv_pool
        if kv_pool is None:
            return
        if self.cfg.paged_kv:
            for _idx, bid, _s, _e in kv_pool.take_newly_sealed(req.req_id):
                kv_pool.store_payload(bid, PAGED_RESIDENT)
            return
        slot = self.slot_of.get(req.req_id)
        if slot is None:
            return
        for _idx, bid, s, e in kv_pool.take_newly_sealed(req.req_id):
            k_blk = jnp.asarray(self.cache["k"][:, slot, s:e])
            v_blk = jnp.asarray(self.cache["v"][:, slot, s:e])
            kv_pool.store_payload(bid, (k_blk, v_blk))

    # -- one round ---------------------------------------------------------------
    def _bucket(self, c: int) -> int:
        for b in self.cfg.chunk_buckets:
            if c <= b:
                return b
        return self.cfg.chunk_buckets[-1]

    def _sync_block_tables(self, batch: ScheduledBatch) -> None:
        """Refresh each scheduled request's device block-table row from the
        pool (the scheduler — or the engine itself when it owns the pool —
        booked this round's blocks before execution)."""
        pool = self.kv_pool
        if self._owns_pool:
            for r, c in batch.prefill_chunks:
                pool.allocate(r.req_id, int(c))
            for r in batch.decode_reqs:
                pool.allocate(r.req_id, 1)
        for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
            slot = self.slot_of[r.req_id]
            table = pool.tables.get(r.req_id, [])
            assert len(table) <= self.max_pages, (
                f"req {r.req_id}: {len(table)} blocks > {self.max_pages} pages"
            )
            row = self.block_tables[slot]
            row[: len(table)] = table
            row[len(table):] = self._sink

    def execute(self, batch: ScheduledBatch) -> float:
        """Run one mixed round; returns wall latency in ms."""
        B = self.cfg.n_slots
        max_chunk = max(
            [c for _, c in batch.prefill_chunks] + [1 if batch.decode_reqs else 0]
        )
        C = self._bucket(max_chunk)
        tokens = np.zeros((B, C), np.int64)
        chunk_lens = np.zeros((B,), np.int32)

        for req in batch.decode_reqs:
            slot = self.slot_of[req.req_id]
            tokens[slot, 0] = self.last_token[slot]
            chunk_lens[slot] = 1
        for req, c in batch.prefill_chunks:
            slot = self.slot_of[req.req_id]
            chunk = req.prompt_tokens[req.prefill_done : req.prefill_done + c]
            tokens[slot, : len(chunk)] = chunk
            chunk_lens[slot] = len(chunk)

        args = (self.params, jnp.asarray(tokens), self.cache, self.lens,
                jnp.asarray(chunk_lens))
        if self.cfg.paged_kv:
            self._sync_block_tables(batch)
            args += (jnp.asarray(self.block_tables),)

        self._rng, sub = jax.random.split(self._rng)
        t0 = time.perf_counter()
        toks, self.cache = self._step(*args, sub)
        toks = np.asarray(jax.block_until_ready(toks))
        wall_ms = (time.perf_counter() - t0) * 1e3

        self.lens = self.lens + jnp.asarray(chunk_lens)
        # next_token carries the sampled id into receive_token so delivered
        # outputs — and any preemption fold — hold the REAL token values
        for req in batch.decode_reqs:
            slot = self.slot_of[req.req_id]
            self.last_token[slot] = toks[slot]
            req.next_token = int(toks[slot])
        for req, c in batch.prefill_chunks:
            slot = self.slot_of[req.req_id]
            if req.remaining_prefill - c <= 0:     # prefill completes this round
                self.last_token[slot] = toks[slot]
                req.next_token = int(toks[slot])
        return wall_ms


@dataclass
class ServeResult:
    report: LatencyReport
    requests: List[Request]
    rounds: int
    wall_s: float
    samples: Optional[Tuple[np.ndarray, np.ndarray]] = None
    outputs: Optional[Dict[int, List[int]]] = None
    memory: Optional[MemoryReport] = None     # KV pool lifecycle summary


def compress_idle_gap(pending: List[Request], next_i: int, now: float) -> None:
    """Jump the idle gap to the next arrival by shifting ALL future arrivals
    by the same constant, so inter-arrival gaps — and therefore arrival-order
    and aging behavior — are preserved mid-run."""
    offset = now - pending[next_i].arrival_time
    for j in range(next_i, len(pending)):
        pending[j].arrival_time += offset


def serve(
    requests: List[Request],
    scheduler: ChunkedPrefillScheduler,
    engine: JAXEngine,
    *,
    kv_pool: Optional[KVBlockPool] = None,
    collect_samples: bool = False,
    realtime_arrivals: bool = False,
    max_rounds: int = 200_000,
) -> ServeResult:
    """Continuous-batching serve loop over real execution.

    Admission hands requests straight to the scheduler — an engine slot is
    bound only when the scheduler first commits a chunk (late binding, via
    the slot-binder hook), so queued or admission-delayed backlog can never
    pin slots.

    realtime_arrivals=False (default) admits requests by the engine's own
    clock (wall time since start), compressing idle gaps — deterministic and
    fast for tests; True sleeps to honor arrival times.
    """
    pending = sorted(requests, key=lambda r: r.arrival_time)
    for r in pending:
        assert r.prompt_tokens is not None, "attach_prompt_tokens() first"
    next_i = 0
    t_start = time.perf_counter()
    now = 0.0
    rounds = 0
    feats, lats = [], []
    outputs: Dict[int, List[int]] = {}
    if kv_pool is not None:
        if scheduler.kv_pool is None:
            # the scheduler books blocks chunk-granularly inside schedule()
            scheduler.attach_kv_pool(kv_pool)
        engine.bind_kv_pool(kv_pool)
    # slots bind at first schedule and free at preemption, not admission
    scheduler.attach_slot_binder(engine.acquire_slot, releaser=engine.release)

    def admit(now_s: float):
        nonlocal next_i
        while next_i < len(pending) and pending[next_i].arrival_time <= now_s:
            req = pending[next_i]
            if kv_pool is not None:
                # registration only (tenant + prompt block hashes): the
                # prefix-cache MATCH waits for first slot bind, so a parked
                # backlog pins no cached blocks and no tenant quota
                kv_pool.register_request(
                    req.req_id, tenant=req.tenant,
                    prompt_tokens=req.prompt_tokens, prompt_len=req.prompt_len,
                )
            if not scheduler.submit(req):      # admission-rejected: give back
                if kv_pool is not None:
                    kv_pool.release(req.req_id)
            next_i += 1

    while rounds < max_rounds:
        now = time.perf_counter() - t_start
        admit(now)
        if not scheduler.has_work():
            if next_i >= len(pending):
                break
            if realtime_arrivals:
                time.sleep(min(0.001, pending[next_i].arrival_time - now))
            else:
                compress_idle_gap(pending, next_i, now)
            continue

        # preemption victims' slots were already freed inside schedule() (the
        # releaser hook) — a victim may even have re-bound a fresh slot and
        # been rescheduled within the same round, so do NOT release here.
        batch = scheduler.schedule(now)
        if batch.is_empty():
            time.sleep(0.0005)
            continue

        wall_ms = engine.execute(batch)
        if kv_pool is not None:
            # newly sealed (full, hashed) prompt blocks become restorable
            for r, _c in batch.prefill_chunks:
                engine.capture_sealed(r)
        if collect_samples:
            feats.append(batch.state.features())
            lats.append(wall_ms)
        rounds += 1

        now = time.perf_counter() - t_start
        scheduler.on_batch_done(batch, now)    # releases finished KV refs

        for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
            outputs.setdefault(r.req_id, [])
            if r.state == RequestState.FINISHED:
                outputs[r.req_id] = list(r.output_tokens)
                engine.release(r)

    samples = (np.stack(feats), np.asarray(lats)) if collect_samples and feats else None
    return ServeResult(
        report=summarize(requests, makespan=now),
        requests=requests,
        rounds=rounds,
        wall_s=now,
        samples=samples,
        outputs=outputs,
        memory=(
            summarize_memory(kv_pool, scheduler.stats) if kv_pool is not None else None
        ),
    )
