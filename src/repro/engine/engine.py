"""Real-execution chunked-prefill engine: the paper's serving loop running
actual JAX forward passes (tiny models on CPU; the identical program compiles
for TPU).

Continuous batching with PAGED KV storage (vLLM layout, the default):
  * ``n_slots`` fixed *batch rows*; a request binds a slot at its FIRST
    scheduled chunk (late binding — queued or admission-delayed backlog pins
    nothing) and keeps it until it finishes or is preempted.
  * K/V live in a physical page pool ``(layers, n_blocks + 1, block_size,
    kv_heads, head_dim)`` whose page ids are exactly the ``KVBlockPool``'s
    block ids, addressed through per-slot block tables.  Capacity scales with
    resident tokens, not ``n_slots x max_context``; prefix-cache hits need no
    payload copy (the matched blocks' pages are still resident); the last
    page is a write sink for padding lanes.
  * One jitted step per scheduling round executes the ENTIRE mixed batch —
    decode slots advance by 1 token, prefill slots by their scheduled chunk,
    idle slots by 0 — under static bucketed shapes.  The step FUSES the
    cache-length update and token sampling (one dispatch per round, no
    follow-up host ops) and keeps the sampled tokens in a device-resident
    ``last_token`` buffer that the NEXT round's step consumes directly, so
    decode can proceed round-to-round without the host ever observing the
    token values.
  * PIPELINED serving (``EngineConfig(pipelined=True)``, the default):
    ``serve`` overlaps round N's device execution with the host's
    scheduling/aging/VTC/KV booking for round N+1.  The host readback of
    sampled ids becomes an async copy drained one round late and is used
    only for delivered outputs, length accounting, and preemption folds —
    greedy outputs are bit-identical to the synchronous engine
    (``pipelined=False``), which is kept for A/B.
  * The scheduler under test is the real ``repro.core`` code; latencies are
    wall-clock, so the LPRS predictor can be trained on real measurements.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, ScheduledBatch
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.kv_cache import KVBlockPool, KVPoolConfig, PAGED_RESIDENT
from repro.engine.metrics import (
    LatencyReport, MemoryReport, RobustnessReport, SLOReport, summarize,
    summarize_memory, summarize_robustness, summarize_slo,
)
from repro.kernels.ops import (
    gather_swap_pages, gather_swap_pages_q8, scatter_swap_pages,
    scatter_swap_pages_q8,
)
from repro.engine.sampler import SamplerConfig, sample_tokens
from repro.models.model import Model, build_model
from repro.robustness import FailoverStats, ReplicaHealth


@dataclass
class EngineConfig:
    n_slots: int = 16
    max_context: int = 1024
    chunk_buckets: Tuple[int, ...] = (1, 16, 32, 64, 128, 256)
    use_pallas: bool = False          # True: Pallas kernels (interpret on CPU)
    paged_kv: bool = True             # block-table pages; False = dense slots
    kv_block_size: int = 16           # page size when the engine owns its pool
    pages_per_tile: int = 1           # pages DMA-gathered per paged-kernel tile
    # physical page-pool layout: "split" keeps separate K and V pools;
    # "fused" interleaves them on the head axis ([K0,V0,K1,V1,...]) so the
    # paged kernels fetch each page's K+V with ONE DMA (half the page-table
    # reads and issue count).  Paged-kv only.
    kv_layout: str = "split"
    # VMEM tile buffers per paged-kernel grid: tile t+depth-1's gather is
    # issued before tile t's wait, so DMA overlaps the MXU dot (1 = the
    # synchronous issue-then-wait path)
    buffering_depth: int = 1
    pipelined: bool = True            # overlap schedule(N+1) with execute(N)
    # preemption mode: "recompute" discards a victim's KV (re-prefill from
    # scratch, the A/B default); "swap" stages it host-side and restores it
    # on re-schedule — the scheduler picks per victim via the cost model
    preemption_mode: str = "recompute"
    # numerics quarantine: the fused step additionally emits a per-slot
    # all-finite mask over the logits (one extra readback lane, no extra
    # dispatch); the serve loop sheds requests whose sampled logits went
    # NaN/Inf (shed_reason="numerics") instead of streaming garbage ids
    nan_guard: bool = False
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    seed: int = 0


def _pow2_bucket(n: int) -> int:
    """Smallest power of two >= n: the dirty-row block-table scatter pads its
    row count to these buckets so only O(log n_slots) shapes ever compile
    (warmup pre-compiles exactly this set)."""
    k = 1
    while k < n:
        k <<= 1
    return k


@dataclass
class InflightRound:
    """One dispatched-but-undrained round: the device is executing (or has
    finished) it while the host schedules the next one.  ``toks`` is the
    device array of sampled ids; ``sampled`` names the (request, slot) pairs
    whose token this round actually produced (decodes + prefill-completing
    chunks).  ``out_index`` records, per request, which position of
    ``output_tokens`` received this round's placeholder (filled by the serve
    loop after ``on_batch_done``); ``drain`` patches the real ids there."""
    toks: jax.Array
    sampled: List[Tuple[Request, int]]
    t_dispatch: float
    out_index: Dict[int, int] = field(default_factory=dict)
    finished: List[Request] = field(default_factory=list)
    prefill_ids: set = field(default_factory=set)   # this round's prefill reqs
    # nan_guard: per-slot all-finite logits mask (async readback alongside
    # toks); drain fills nonfinite with the sampled req_ids whose logits
    # carried NaN/Inf so the serve loop can quarantine them
    finite: Optional[jax.Array] = None
    nonfinite: set = field(default_factory=set)
    # the batch this round executed — a crash unwind enumerates its members
    batch: Optional[ScheduledBatch] = None


class JAXEngine:
    """Executes ScheduledBatches with real forward passes."""

    def __init__(self, model_cfg: ModelConfig, cfg: Optional[EngineConfig] = None,
                 params=None, kv_pool: Optional[KVBlockPool] = None):
        self.cfg = cfg or EngineConfig()
        self.model_cfg = model_cfg
        self.model: Model = build_model(model_cfg)
        rng = jax.random.PRNGKey(self.cfg.seed)
        self.params = params if params is not None else self.model.init(rng)
        self._rng = jax.random.PRNGKey(self.cfg.seed + 1)

        B = self.cfg.n_slots
        self.slot_of: Dict[int, int] = {}          # req_id -> slot
        self.free_slots = list(range(B - 1, -1, -1))

        # device-idle gap before each dispatch (the host bubble the pipeline
        # is built to close); fed by execute()/dispatch()
        self.bubble_ms: List[float] = []
        self._t_ready: Optional[float] = None
        # nan_guard: req_ids whose sampled logits were non-finite in the most
        # recently drained round (sync serve loops read it after execute())
        self.last_nonfinite: set = set()
        # storage poisoned by the nan_logits chaos site.  Pages/slots released
        # by the quarantined victim go back to the free pool still holding
        # NaN, so a later request reusing them would read non-finite lanes it
        # never wrote — scrub_poisoned() zeroes them once the victim is shed.
        self._poisoned: List[tuple] = []

        # swap-out preemption: device->host gathers whose async host copy has
        # not drained yet — (req_id, staging record, per-cache-tensor
        # arrays); finalize_swaps() attaches the payload to the record
        # DIRECTLY (not through the pool), so a record the disagg router
        # prefetched into the handoff store or a destination pool still
        # finalizes — same one-round-late path as the sampled-token readback
        self._pending_swaps: List[Tuple[int, object, Tuple[jax.Array, ...]]] = []

        self.kv_pool: Optional[KVBlockPool] = kv_pool
        # warmup() flips this: binding a shape-changing pool afterwards would
        # silently invalidate every compiled shape, so bind_kv_pool refuses
        self.warmed = False
        # the engine books blocks itself only while it owns a private pool;
        # an externally bound pool is booked by the scheduler
        self._owns_pool = False
        if self.cfg.paged_kv and self.kv_pool is None:
            bs = self.cfg.kv_block_size
            per_slot = math.ceil(self.cfg.max_context / bs) + 1
            self.kv_pool = KVBlockPool(KVPoolConfig(
                n_blocks=B * per_slot, block_size=bs,
            ))
            self._owns_pool = True
        self._build_state()

    # -- physical KV layout ----------------------------------------------------
    def _build_state(self) -> None:
        cfg, model_cfg = self.cfg, self.model_cfg
        B, S = cfg.n_slots, cfg.max_context
        hd = model_cfg.resolved_head_dim
        dt = jnp.dtype(model_cfg.param_dtype)
        impl = self.model.impl
        use_pallas = cfg.use_pallas
        pages_per_tile = cfg.pages_per_tile
        assert cfg.kv_layout in ("split", "fused"), cfg.kv_layout
        assert cfg.buffering_depth >= 1, cfg.buffering_depth
        self._fused = cfg.paged_kv and cfg.kv_layout == "fused"
        assert self._fused or cfg.kv_layout == "split", (
            "kv_layout='fused' requires paged_kv=True"
        )

        def _inject_last(tokens, use_last, last_token):
            """Decode lanes consume the device-resident last sampled token
            (the host staged a 0 there — it may not know the id yet)."""
            col0 = jnp.arange(tokens.shape[1])[None, :] == 0
            return jnp.where(use_last[:, None] & col0,
                             last_token[:, None], tokens)

        def _fused_tail(logits, cache, lens, chunk_lens, last_token,
                        sample_mask, rng):
            """Sampling + length update + device token feedback, fused into
            the SAME dispatch as the forward pass (no follow-up host ops)."""
            toks = sample_tokens(logits, rng, self.cfg.sampler)
            new_last = jnp.where(sample_mask, toks, last_token)
            if cfg.nan_guard:
                finite = jnp.isfinite(logits).all(axis=-1)
                return toks, cache, lens + chunk_lens, new_last, finite
            return toks, cache, lens + chunk_lens, new_last

        if cfg.paged_kv:
            bs = self.kv_pool.cfg.block_size
            # physical pages = pool blocks + 1 trailing sink page (padding
            # lanes scatter there; block tables also pad with it)
            self._n_phys = self.kv_pool.cfg.n_blocks + 1
            self._sink = self.kv_pool.cfg.n_blocks
            self.max_pages = math.ceil(S / bs) + 1
            n_kv = model_cfg.n_kv_heads * (2 if self._fused else 1)
            kv_shape = (model_cfg.n_layers, self._n_phys, bs, n_kv, hd)
            # device-resident block tables, refreshed with DIRTY-SLOT
            # incremental updates; _bt_host mirrors exactly what the device
            # holds, _bt_len tracks per-slot entries already uploaded
            self._bt_host = np.full((B, self.max_pages), self._sink, np.int32)
            self._bt_len = np.zeros((B,), np.int32)
            self._bt_dirty: set = set()
            self.block_tables = jnp.asarray(self._bt_host)

            def step(params, tokens, cache, lens, chunk_lens, block_tables,
                     last_token, use_last, sample_mask, rng):
                tokens = _inject_last(tokens, use_last, last_token)
                logits, cache = impl.chunked_step_paged(
                    params, tokens, cache, lens, chunk_lens, block_tables,
                    use_pallas=use_pallas, pages_per_tile=pages_per_tile,
                    kv_layout=cfg.kv_layout,
                    buffering_depth=cfg.buffering_depth,
                )
                return _fused_tail(logits, cache, lens, chunk_lens,
                                   last_token, sample_mask, rng)

            donate = (2, 3, 6)     # cache, lens, last_token
        else:
            kv_shape = (model_cfg.n_layers, B, S + 1, model_cfg.n_kv_heads, hd)
            self.block_tables = None

            def step(params, tokens, cache, lens, chunk_lens,
                     last_token, use_last, sample_mask, rng):
                tokens = _inject_last(tokens, use_last, last_token)
                logits, cache = impl.chunked_step(
                    params, tokens, cache, lens, chunk_lens, use_pallas=use_pallas
                )
                return _fused_tail(logits, cache, lens, chunk_lens,
                                   last_token, sample_mask, rng)

            donate = (2, 3, 5)     # cache, lens, last_token

        if self._fused:
            self.cache = {"kv": jnp.zeros(kv_shape, dt)}
        else:
            self.cache = {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)}
        self.lens = jnp.zeros((B,), jnp.int32)
        self.last_token = jnp.zeros((B,), jnp.int32)   # device-resident
        self._step = jax.jit(step, donate_argnums=donate)

    def bind_kv_pool(self, kv_pool: Optional[KVBlockPool]) -> None:
        """Adopt the serve loop's shared pool: the physical page array is
        rebuilt so page ids == the pool's block ids (the scheduler books
        blocks; the engine just follows the tables).  Must happen before any
        request is in flight."""
        if kv_pool is None or kv_pool is self.kv_pool:
            return
        assert not self.slot_of, "cannot rebind the KV pool mid-flight"
        if self.warmed and self.cfg.paged_kv:
            # the paged rebuild resizes the physical page array (page ids ==
            # block ids), so every shape warmup compiled is stale — the run
            # would silently re-pay cold compilation inside serving rounds
            raise RuntimeError(
                "bind_kv_pool after warmup(): the paged rebuild invalidates "
                "every prewarmed shape — bind the external pool FIRST, then "
                "call warmup()"
            )
        if kv_pool.cfg.host_kv_dtype == "int8" and not self.cfg.paged_kv:
            raise RuntimeError(
                "host_kv_dtype='int8' requires paged_kv: the quantized swap "
                "kernels are page-shaped"
            )
        self.kv_pool = kv_pool
        self._owns_pool = False
        if self.cfg.paged_kv:
            self._build_state()

    def _cache_names(self) -> Tuple[str, ...]:
        """The cache dict's tensor keys, in swap payload order: the fused
        layout stores ONE head-interleaved pool, split stores two."""
        return ("kv",) if self._fused else ("k", "v")

    def warmup(self, *, include_swap: Optional[bool] = None) -> None:
        """Compile every bucket shape once so profiling sees steady-state
        latencies, not jit compilation (the paper's 'cleaned' samples).

        Every jitted shape the serving loop can hit under the CONFIGURED
        ``(kv_layout, buffering_depth, pages_per_tile)`` combination is
        covered: the step compiles per chunk bucket with those knobs baked
        in, the dirty-row block-table scatter per power-of-two row bucket,
        and — when this engine can swap (``preemption_mode="swap"``) or the
        caller says it will export/import KV (``include_swap=True``, the
        disagg handoff path, which rides the same gather/scatter kernels
        regardless of preemption mode) — the swap kernels per page-id
        bucket.

        Order matters with an EXTERNAL pool: ``bind_kv_pool`` rebuilds the
        physical page array (page ids must equal the pool's block ids),
        which changes the cache shape and invalidates everything compiled
        here — bind first, then warm up."""
        B = self.cfg.n_slots
        off = jnp.zeros((B,), jnp.bool_)
        for C in self.cfg.chunk_buckets:
            tokens = jnp.ones((B, C), jnp.int32)
            chunk_lens = jnp.zeros((B,), jnp.int32).at[0].set(1)
            self._rng, sub = jax.random.split(self._rng)
            args = (self.params, tokens, self.cache, self.lens, chunk_lens)
            if self.cfg.paged_kv:
                args += (self.block_tables,)
            args += (self.last_token, off, off)
            out = self._step(*args, sub)
            toks, self.cache, self.lens, self.last_token = out[:4]
            jax.block_until_ready(toks)
        # reset cache/lens state touched by the dummy rounds (paged writes all
        # land in the sink page, which is never read back)
        self.lens = jnp.zeros((B,), jnp.int32)
        if self.cfg.paged_kv:
            # pre-compile every dirty-row scatter bucket the runtime can hit
            # (slot 0's current mirror row rewritten in place — a data no-op)
            for k in sorted({_pow2_bucket(n) for n in range(1, B + 1)}):
                idx = np.zeros((k,), np.int32)
                self.block_tables = self.block_tables.at[jnp.asarray(idx)].set(
                    jnp.asarray(self._bt_host[idx])
                )
            jax.block_until_ready(self.block_tables)
        if include_swap is None:
            include_swap = self.cfg.preemption_mode == "swap"
        if include_swap:
            self._prewarm_swap_shapes()
        self.warmed = True

    def _prewarm_swap_shapes(self) -> None:
        """Compile the swap gather/scatter for every page-id bucket a swap
        can hit (paged) or the slot row copy (dense), so the first real
        preemption — or disagg handoff export/import — doesn't pay jit
        compilation inside a serving round."""
        names = self._cache_names()
        if self.cfg.paged_kv:
            buckets = sorted({_pow2_bucket(n)
                              for n in range(1, self.max_pages + 1)})
            q8 = self._host_quantized()
            for k in buckets:
                ids = jnp.full((k,), self._sink, jnp.int32)   # sink-only: no-op
                for nm in names:
                    if q8:
                        q, scales = gather_swap_pages_q8(
                            self.cache[nm], ids,
                            use_pallas=self.cfg.use_pallas)
                        self.cache[nm] = scatter_swap_pages_q8(
                            self.cache[nm], ids, q, scales,
                            use_pallas=self.cfg.use_pallas)
                    else:
                        staged = gather_swap_pages(
                            self.cache[nm], ids,
                            use_pallas=self.cfg.use_pallas)
                        self.cache[nm] = scatter_swap_pages(
                            self.cache[nm], ids, staged,
                            use_pallas=self.cfg.use_pallas)
            jax.block_until_ready(self.cache[names[0]])
        else:
            k_row = np.asarray(self.cache["k"][:, 0])
            self.cache["k"] = self.cache["k"].at[:, 0].set(jnp.asarray(k_row))
            jax.block_until_ready(self.cache["k"])

    # -- slot management -------------------------------------------------------
    def acquire_slot(self, req: Request) -> bool:
        """Late slot binding: called by the scheduler when it first commits a
        chunk for ``req`` (NOT at admission — queued or rate-limit-delayed
        backlog pins no slot).  Returns True when the request holds a slot
        after the call.

        The prefix-cache lookup also happens HERE, not at admission: a
        parked backlog must not pin cached blocks (refcounts) or tenant
        quota it cannot use yet.  Only restorable blocks count — host-side
        payloads (dense) or still-resident pages (paged).  On a hit the
        dense layout copies the matched payloads into the fresh slot; the
        paged layout's matched pages are already resident (zero-copy)."""
        if req.req_id in self.slot_of:
            return True
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        self.slot_of[req.req_id] = slot
        if (self.kv_pool is not None and req.prefill_done == 0
                and not self.kv_pool.tables.get(req.req_id)):
            matched = self.kv_pool.match_prefix(req.req_id, require_payload=True)
            if matched > 0:
                req.prefill_done = matched
        self.lens = self.lens.at[slot].set(req.prefill_done)
        if self.cfg.paged_kv:
            self._bt_host[slot, :] = self._sink
            self._bt_len[slot] = 0
            self._bt_dirty.add(slot)
        elif req.prefill_done > 0 and self.kv_pool is not None:
            self._restore_prefix_dense(req, slot)
        return True

    def release(self, req: Request) -> None:
        """Drop the request's slot (finish or preemption).  Idempotent.  With
        an engine-owned pool the request's blocks go back too."""
        slot = self.slot_of.pop(req.req_id, None)
        if slot is not None:
            self.free_slots.append(slot)
            if self.cfg.paged_kv:
                self._bt_host[slot, :] = self._sink
                self._bt_len[slot] = 0
                self._bt_dirty.add(slot)
        if self._owns_pool:
            self.kv_pool.release(req.req_id)

    def has_capacity(self) -> bool:
        return len(self.free_slots) > 0

    # -- swap-out preemption (device<->host KV migration) ----------------------
    def _host_quantized(self) -> bool:
        """True when staged host pages are INT8 (pool ``host_kv_dtype``)."""
        return (self.kv_pool is not None
                and self.kv_pool.cfg.host_kv_dtype == "int8")

    def _swap_page_ids(self, req_id: int) -> Tuple[np.ndarray, int]:
        """The request's physical page ids, right-padded with the sink page
        to a power-of-two bucket so the gather/scatter kernels only ever
        compile O(log max_pages) shapes.  Returns (padded ids, real count)."""
        table = self.kv_pool.tables.get(req_id, [])
        n = len(table)
        k = _pow2_bucket(max(n, 1))
        ids = np.full((k,), self._sink, np.int32)
        ids[:n] = table
        return ids, n

    def swap_out(self, req: Request) -> None:
        """Scheduler swapper hook: gather the victim's KV into a contiguous
        staging tensor (paged: one jitted page gather over its block table;
        dense: its slot rows), start the async device→host copy, move the
        pool accounting to a SWAPPING staging record, and release the slot.
        The payload becomes restorable only when ``finalize_swaps`` drains
        the copy — the same one-round-late visibility the token readback
        has, so a mid-pipeline victim is never restored (or re-bound) in the
        round that is still copying its pages out."""
        pool = self.kv_pool
        slot = self.slot_of.get(req.req_id)
        assert slot is not None, f"swap_out of unbound req {req.req_id}"
        if self.cfg.paged_kv:
            ids, _n = self._swap_page_ids(req.req_id)
            jids = jnp.asarray(ids)
            if self._host_quantized():
                # fused gather+quantize: the host copy moves int8 pages plus
                # small per-page-per-head scales — about half the bytes
                arrays = tuple(
                    gather_swap_pages_q8(self.cache[nm], jids,
                                         use_pallas=self.cfg.use_pallas)
                    for nm in self._cache_names()
                )
            else:
                arrays = tuple(
                    gather_swap_pages(self.cache[nm], jids,
                                      use_pallas=self.cfg.use_pallas)
                    for nm in self._cache_names()
                )
        else:
            # dense layout: the whole slot row (static shape — positions past
            # the stored length are never attended to after restore)
            arrays = (self.cache["k"][:, slot], self.cache["v"][:, slot])
        for a in jax.tree_util.tree_leaves(arrays):
            a.copy_to_host_async()
        # keep the RECORD, not just the id: finalize must find it wherever
        # the disagg router's prefetch may have moved it by drain time
        rec = pool.swap_out(req.req_id)        # state: SWAPPING
        self._pending_swaps.append((req.req_id, rec, arrays))
        self.release(req)

    def finalize_swaps(self) -> None:
        """Drain pending swap-out copies: block until each staged tensor is
        host-side (the copies were dispatched before the current round's
        step, so this wait is bounded) and mark the staging records
        SWAPPED_OUT.  The payload attaches to the record object itself —
        location-transparent: under handoff PREFETCH the record may already
        sit in the ``KVHandoffStore`` or a destination pool's staging store
        rather than this engine's pool.  Called from ``drain`` — swap
        traffic retires on the same one-round-late path as sampled tokens —
        and by the serve loop when no round is in flight to piggyback on."""
        if not self._pending_swaps:
            return
        for _req_id, rec, arrays in self._pending_swaps:
            KVBlockPool.finalize_record(
                rec, jax.tree_util.tree_map(np.asarray, arrays)
            )
        self._pending_swaps.clear()

    def has_pending_swaps(self) -> bool:
        return bool(self._pending_swaps)

    def swap_in(self, req: Request, payload) -> None:
        """Scheduler restorer hook, called right after ``pool.swap_in``
        rebuilt the request's table from fresh blocks: scatter the staged
        K/V into the new physical pages (paged) or the freshly bound slot's
        rows (dense) and restore the device-side length."""
        slot = self.slot_of.get(req.req_id)
        assert slot is not None, f"swap_in of unbound req {req.req_id}"
        assert payload is not None, f"swap_in of req {req.req_id} without payload"
        names = self._cache_names()
        assert len(payload) == len(names), (
            f"req {req.req_id}: payload arity {len(payload)} != cache layout "
            f"{names} — swapped under a different kv_layout?"
        )
        tokens = self.kv_pool.lens.get(req.req_id, 0)
        if self.cfg.paged_kv:
            ids, n = self._swap_page_ids(req.req_id)
            staged_pages = (payload[0][0] if isinstance(payload[0], tuple)
                            else payload[0]).shape[1]
            assert n and ids.shape[0] == staged_pages, (
                f"req {req.req_id}: restore bucket {ids.shape[0]} != staged "
                f"{staged_pages}"
            )
            jids = jnp.asarray(ids)
            for nm, a in zip(names, payload):
                self._scatter_staged(nm, jids, a)
            # table changed wholesale: force a full device row rewrite
            self._bt_host[slot, :] = self._sink
            self._bt_len[slot] = 0
            self._bt_dirty.add(slot)
        else:
            for nm, a in zip(names, payload):
                self.cache[nm] = self.cache[nm].at[:, slot].set(jnp.asarray(a))
        self.lens = self.lens.at[slot].set(tokens)

    def _scatter_staged(self, nm: str, jids, staged) -> None:
        """Scatter one cache tensor's staged pages — a ``(q, scales)`` pair
        rides the fused dequantizing scatter, a plain array the fp one."""
        if isinstance(staged, tuple):
            q, scales = staged
            self.cache[nm] = scatter_swap_pages_q8(
                self.cache[nm], jids, jnp.asarray(q), jnp.asarray(scales),
                use_pallas=self.cfg.use_pallas)
        else:
            self.cache[nm] = scatter_swap_pages(
                self.cache[nm], jids, jnp.asarray(staged),
                use_pallas=self.cfg.use_pallas)

    @staticmethod
    def slice_swap_payload(payload, tail_start_blocks: int, n_blocks: int):
        """Trim a host-staged payload to its tail pages (partial swap-in):
        keep pages ``[tail_start_blocks, n_blocks)`` of every staged array
        — page axis 1, real pages only; the pow2 padding is rebuilt for the
        tail's own scatter bucket (padded entries target the sink page, so
        their content is never read).  Returns real copies: the prefix pages'
        memory is actually released once the original payload drops."""
        k = n_blocks - tail_start_blocks
        kpad = _pow2_bucket(max(k, 1))

        def trim(a):
            a = np.asarray(a)
            out = np.zeros(a.shape[:1] + (kpad,) + a.shape[2:], a.dtype)
            out[:, :k] = a[:, tail_start_blocks:n_blocks]
            return out

        return tuple(
            tuple(trim(x) for x in a) if isinstance(a, tuple) else trim(a)
            for a in payload
        )

    def swap_in_tail(self, req: Request, payload,
                     tail_start_blocks: int) -> None:
        """Scheduler tail-restorer hook, called right after
        ``pool.swap_in_tail`` appended fresh blocks for the staged tail: the
        request re-prefilled blocks ``[0, tail_start_blocks)`` normally, so
        only the tail pages are scattered and the device length jumps to the
        record's full stored length."""
        slot = self.slot_of.get(req.req_id)
        assert slot is not None, f"swap_in_tail of unbound req {req.req_id}"
        assert payload is not None, (
            f"swap_in_tail of req {req.req_id} without payload"
        )
        assert self.cfg.paged_kv, "partial swap-in requires the paged layout"
        names = self._cache_names()
        assert len(payload) == len(names), (
            f"req {req.req_id}: payload arity {len(payload)} != cache layout "
            f"{names} — swapped under a different kv_layout?"
        )
        table = self.kv_pool.tables.get(req.req_id, [])
        tail = table[tail_start_blocks:]
        assert tail, f"req {req.req_id}: empty tail restore"
        kpad = _pow2_bucket(len(tail))
        ids = np.full((kpad,), self._sink, np.int32)
        ids[: len(tail)] = tail
        staged_pages = (payload[0][0] if isinstance(payload[0], tuple)
                        else payload[0]).shape[1]
        assert kpad == staged_pages, (
            f"req {req.req_id}: tail bucket {kpad} != staged {staged_pages}"
        )
        jids = jnp.asarray(ids)
        for nm, a in zip(names, payload):
            self._scatter_staged(nm, jids, a)
        tokens = self.kv_pool.lens.get(req.req_id, 0)
        self._bt_host[slot, :] = self._sink
        self._bt_len[slot] = 0
        self._bt_dirty.add(slot)
        self.lens = self.lens.at[slot].set(tokens)

    def poison_kv(self, req: Request) -> None:
        """Chaos hook (the ``nan_logits`` fault site): corrupt the request's
        OWN attended KV so its next forward pass yields non-finite logits,
        exercising the numerics-quarantine path end to end.  Only PRIVATE
        storage is touched — shared prefix pages (refcount > 1) are skipped,
        so co-resident requests stay bit-identical to a fault-free run."""
        slot = self.slot_of.get(req.req_id)
        if slot is None:
            return
        written = int(jax.device_get(self.lens)[slot])
        if written <= 0:
            return
        if self.cfg.paged_kv:
            table = self.kv_pool.tables.get(req.req_id, [])
            if not table:
                return
            bs = self.kv_pool.cfg.block_size
            bi = min((written - 1) // bs, len(table) - 1)
            while bi >= 0 and self.kv_pool._ref.get(table[bi], 1) > 1:
                bi -= 1
            if bi < 0:
                return           # every page is shared: nothing safe to poison
            pid = table[bi]
            for nm in self._cache_names():
                self.cache[nm] = self.cache[nm].at[:, pid].set(jnp.nan)
            self._poisoned.append(("page", pid))
        else:
            for nm in ("k", "v"):
                self.cache[nm] = (
                    self.cache[nm].at[:, slot, written - 1].set(jnp.nan)
                )
            self._poisoned.append(("dense", slot, written - 1))

    def scrub_poisoned(self) -> None:
        """Zero the storage poison_kv() corrupted.  Called once the victim is
        quarantined: its pages return to the free pool, and a NaN lane the
        next owner never overwrites must not re-trigger the guard on it."""
        for entry in self._poisoned:
            if entry[0] == "page":
                for nm in self._cache_names():
                    self.cache[nm] = self.cache[nm].at[:, entry[1]].set(0)
            else:
                for nm in ("k", "v"):
                    self.cache[nm] = (
                        self.cache[nm].at[:, entry[1], entry[2]].set(0)
                    )
        self._poisoned.clear()

    # -- prefix-cache payloads -------------------------------------------------
    def _restore_prefix_dense(self, req: Request, slot: int) -> None:
        """Dense layout only: copy a prefix-cache hit's stored K/V payloads
        into the request's slot so the skipped prefill positions hold
        numerically identical state (causal attention: prefix KV depends only
        on prefix tokens).  At bind time ``prefill_done`` is exactly the
        matched token count."""
        kv_pool = self.kv_pool
        bs = kv_pool.cfg.block_size
        table = kv_pool.tables.get(req.req_id, [])
        n_matched = req.prefill_done // bs
        ks, vs = [], []
        for bid in table[:n_matched]:
            payload = kv_pool.payload(bid)
            assert payload is not None and payload is not PAGED_RESIDENT, (
                "dense engine prefix match requires host-side payloads"
            )
            ks.append(payload[0])
            vs.append(payload[1])
        if ks:
            # one functional update per cache tensor, not one per block
            self.cache["k"] = (
                self.cache["k"].at[:, slot, : n_matched * bs].set(jnp.concatenate(ks, axis=1))
            )
            self.cache["v"] = (
                self.cache["v"].at[:, slot, : n_matched * bs].set(jnp.concatenate(vs, axis=1))
            )

    def capture_sealed(self, req: Request) -> None:
        """Make newly sealed (full, content-addressed) prompt blocks
        restorable by future prefix hits.  Dense layout: park the K/V arrays
        (slices of the round's output cache — an async device computation, no
        host sync even mid-pipeline).  Paged layout: the data already lives
        at the block's physical page — a residency marker suffices, no
        copy."""
        kv_pool = self.kv_pool
        if kv_pool is None:
            return
        if self.cfg.paged_kv:
            for _idx, bid, _s, _e in kv_pool.take_newly_sealed(req.req_id):
                kv_pool.store_payload(bid, PAGED_RESIDENT)
            return
        slot = self.slot_of.get(req.req_id)
        if slot is None:
            return
        for _idx, bid, s, e in kv_pool.take_newly_sealed(req.req_id):
            k_blk = jnp.asarray(self.cache["k"][:, slot, s:e])
            v_blk = jnp.asarray(self.cache["v"][:, slot, s:e])
            kv_pool.store_payload(bid, (k_blk, v_blk))

    # -- one round ---------------------------------------------------------------
    def _bucket(self, c: int) -> int:
        for b in self.cfg.chunk_buckets:
            if c <= b:
                return b
        return self.cfg.chunk_buckets[-1]

    def _sync_block_tables(self, batch: ScheduledBatch) -> None:
        """Refresh scheduled requests' device block-table rows from the pool
        with DIRTY-SLOT granularity: per-request tables only ever APPEND
        between binds, so each row uploads only when it changed (new page
        crossed, fresh bind, release) — one ``.at[slots].set`` over the dirty
        rows instead of re-uploading the whole (B, max_pages) table every
        round."""
        pool = self.kv_pool
        if self._owns_pool:
            for r, c in batch.prefill_chunks:
                pool.allocate(r.req_id, int(c))
            for r in batch.decode_reqs:
                pool.allocate(r.req_id, 1)
        for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
            slot = self.slot_of[r.req_id]
            table = pool.tables.get(r.req_id, [])
            n = len(table)
            assert n <= self.max_pages, (
                f"req {r.req_id}: {n} blocks > {self.max_pages} pages"
            )
            seen = int(self._bt_len[slot])
            if slot in self._bt_dirty:
                self._bt_host[slot, :n] = table
                self._bt_host[slot, n:] = self._sink
            elif n > seen:
                self._bt_host[slot, seen:n] = table[seen:]
                self._bt_dirty.add(slot)
            self._bt_len[slot] = n
        if self._bt_dirty:
            rows = sorted(self._bt_dirty)
            # pad the row count to a power-of-2 bucket (repeating one row —
            # duplicate scatter indices carry identical data) so the update
            # only ever compiles the shapes warmup pre-compiled
            k = _pow2_bucket(len(rows))
            rows = np.asarray(rows + [rows[0]] * (k - len(rows)), np.int32)
            self.block_tables = self.block_tables.at[jnp.asarray(rows)].set(
                jnp.asarray(self._bt_host[rows])
            )
            self._bt_dirty.clear()

    def _stage(self, batch: ScheduledBatch):
        """Host-side staging for one round: token ids (int32 — half the
        host->device width of the seed engine's int64 staging), per-slot
        chunk lengths, and the two masks the fused step needs: which slots
        consume the device-resident ``last_token`` (decodes) and which slots'
        sampled token is meaningful this round (decodes + chunks that finish
        their prefill)."""
        B = self.cfg.n_slots
        max_chunk = max(
            [c for _, c in batch.prefill_chunks] + [1 if batch.decode_reqs else 0]
        )
        C = self._bucket(max_chunk)
        tokens = np.zeros((B, C), np.int32)
        chunk_lens = np.zeros((B,), np.int32)
        use_last = np.zeros((B,), np.bool_)
        sample_mask = np.zeros((B,), np.bool_)
        sampled: List[Tuple[Request, int]] = []

        for req in batch.decode_reqs:
            slot = self.slot_of[req.req_id]
            chunk_lens[slot] = 1
            if req.needs_replay:
                # first decode round after a swap-in: the device-resident
                # last_token lane died with the old slot, so stage the last
                # delivered id from the host.  Safe by the drain ordering —
                # every token sampled before the swap-out drained before this
                # round stages (tokens land host-side one round late; the
                # restore itself is one more round later).
                tokens[slot, 0] = req.output_tokens[-1]
                req.needs_replay = False
            else:
                use_last[slot] = True
            sample_mask[slot] = True
            sampled.append((req, slot))
        for req, c in batch.prefill_chunks:
            slot = self.slot_of[req.req_id]
            chunk = req.prompt_tokens[req.prefill_done : req.prefill_done + c]
            tokens[slot, : len(chunk)] = chunk
            chunk_lens[slot] = len(chunk)
            if req.remaining_prefill - c <= 0:  # prefill completes this round
                sample_mask[slot] = True
                sampled.append((req, slot))
        return tokens, chunk_lens, use_last, sample_mask, sampled

    def dispatch(self, batch: ScheduledBatch) -> InflightRound:
        """Stage and launch one round WITHOUT waiting for it: the jitted step
        (forward + sampling + length update, one dispatch) runs while the
        caller goes back to scheduling.  The sampled-token readback starts as
        an async device->host copy; ``drain`` collects it one round later."""
        tokens, chunk_lens, use_last, sample_mask, sampled = self._stage(batch)
        args = (self.params, jnp.asarray(tokens), self.cache, self.lens,
                jnp.asarray(chunk_lens))
        if self.cfg.paged_kv:
            self._sync_block_tables(batch)
            args += (self.block_tables,)
        args += (self.last_token, jnp.asarray(use_last), jnp.asarray(sample_mask))
        self._rng, sub = jax.random.split(self._rng)
        t_dispatch = time.perf_counter()
        if self._t_ready is not None:
            self.bubble_ms.append((t_dispatch - self._t_ready) * 1e3)
        out = self._step(*args, sub)
        toks, self.cache, self.lens, self.last_token = out[:4]
        finite = out[4] if len(out) > 4 else None
        toks.copy_to_host_async()
        if finite is not None:
            finite.copy_to_host_async()
        return InflightRound(toks=toks, sampled=sampled, t_dispatch=t_dispatch,
                             finite=finite)

    def drain(self, inflight: InflightRound) -> float:
        """Block until the round's sampled ids are host-side, then patch the
        REAL token values into the requests' bookkeeping (placeholders were
        recorded by ``on_batch_done`` while the round executed): delivered
        outputs, ``next_token``, and — via ``patch_token`` — any copy a
        preemption already folded into a recompute prompt.  Returns
        dispatch->drain wall ms (device time plus whatever host work it
        overlapped)."""
        toks = np.asarray(inflight.toks)
        self._t_ready = time.perf_counter()
        wall_ms = (self._t_ready - inflight.t_dispatch) * 1e3
        if inflight.finite is not None:
            fin = np.asarray(inflight.finite)
            inflight.nonfinite = {
                req.req_id for req, slot in inflight.sampled if not fin[slot]
            }
        # sync-mode mirror (execute() discards the InflightRound): the serve
        # loop reads the quarantine set of the round it just executed here
        self.last_nonfinite = inflight.nonfinite
        # swap-out staging retires on the same one-round-late path: gathers
        # dispatched before this round's step are host-side by now (or the
        # asarray below bounds the wait)
        self.finalize_swaps()
        for req, slot in inflight.sampled:
            tok = int(toks[slot])
            req.next_token = tok
            idx = inflight.out_index.get(req.req_id)
            if idx is not None:
                req.patch_token(idx, tok)
        return wall_ms

    def execute(self, batch: ScheduledBatch) -> float:
        """Synchronous round (``pipelined=False`` A/B path): dispatch and
        drain back-to-back, so token ids are delivered before the caller's
        ``on_batch_done`` (with an empty ``out_index`` the drain's patching
        is a no-op and only ``next_token`` delivery remains); returns wall
        latency in ms."""
        return self.drain(self.dispatch(batch))


@dataclass
class ServeResult:
    report: LatencyReport
    requests: List[Request]
    rounds: int
    wall_s: float
    samples: Optional[Tuple[np.ndarray, np.ndarray]] = None
    outputs: Optional[Dict[int, List[int]]] = None
    memory: Optional[MemoryReport] = None     # KV pool lifecycle summary
    host_bubble_ms: Optional[List[float]] = None   # device-idle gap per round
    slo: Optional[SLOReport] = None           # per-tenant attainment gauges
    robustness: Optional["RobustnessReport"] = None  # chaos/fault summary


def compress_idle_gap(pending: List[Request], next_i: int, now: float) -> None:
    """Jump the idle gap to the next arrival by shifting ALL future arrivals
    by the same constant, so inter-arrival gaps — and therefore arrival-order
    and aging behavior — are preserved mid-run."""
    offset = now - pending[next_i].arrival_time
    for j in range(next_i, len(pending)):
        pending[j].arrival_time += offset


class ReplicaServer:
    """One replica's continuous-batching state machine: the body of
    ``serve()`` factored into admit/step/drain pieces so a multi-replica
    driver (``repro.disagg.DisaggregatedRouter``) can interleave several
    engines — each with its own scheduler and pool — inside one host loop,
    while single-replica ``serve()`` stays a thin wrapper.

    ``step(now)`` runs at most one scheduling round and reports what
    happened:
      * ``"round"``     — a batch was dispatched (pipelined) or executed
      * ``"drained"``   — progress was made by draining the in-flight round
      * ``"finalized"`` — pending swap-out copies were landed (no round ran)
      * ``"starved"``   — runnable work exists but nothing could be placed
      * ``"idle"``      — no queued or in-flight work at all

    Value-dependent stop tokens (``Request.stop_token``) are honored here,
    not in ``receive_token``: a pipelined engine learns token VALUES one
    round late, so the stop is applied at drain time — by which point the
    request may already be booked into the next, not-yet-dispatched round
    (unwound via ``scheduler.on_stop``, which also refunds the
    over-scheduled round's KV booking), preempted, or mid-handoff.  Greedy
    outputs stay bit-identical to the synchronous engine, which observes the
    same stop in the same round's ``on_batch_done``.
    """

    def __init__(
        self,
        scheduler: ChunkedPrefillScheduler,
        engine: JAXEngine,
        *,
        kv_pool: Optional[KVBlockPool] = None,
        collect_samples: bool = False,
        on_prefill_complete=None,
        on_stopped=None,
        name: str = "replica",
    ):
        self.sched = scheduler
        self.engine = engine
        self.kv_pool = kv_pool
        self.collect_samples = collect_samples
        # multi-replica hook: called once per request in the round its
        # prefill completed (state DECODING, first token bookkept) — the
        # disaggregated router decides there whether to export the KV
        self.on_prefill_complete = on_prefill_complete
        # multi-replica hook: called after a value-dependent stop is applied
        # (scheduler.on_stop already ran) — the router chases a prefetched
        # handoff record to whatever pool it moved on to and unwinds it there
        self.on_stopped = on_stopped
        self.name = name
        self.pipelined = engine.cfg.pipelined
        self.inflight: Optional[InflightRound] = None
        self.rounds = 0
        self.outputs: Dict[int, List[int]] = {}
        # fault tolerance (repro.robustness): an attached injector fires
        # seeded chaos sites inside step(); fault_tolerant converts any
        # exception out of a round into a crash unwind + "error" status
        # instead of tearing down the serve loop
        self.injector = None
        self.fault_tolerant = False
        self.last_error: Optional[BaseException] = None
        self.crash_unwinds = 0
        self.crash_requeued = 0
        # local retry bound: a request requeued by _crash_cleanup more than
        # max_crash_retries times sheds terminally instead of cycling — on a
        # single replica there is no fleet to fail over to, and a repeating
        # crash site must not livelock the serve loop (None = unbounded)
        self.max_crash_retries: Optional[int] = None
        self._crash_retries: Dict[int, int] = {}
        self.crash_shed: List[Request] = []
        self.quarantined: List[Request] = []
        # torn-round bookkeeping for _crash_cleanup: the round being drained
        # (popped off self.inflight but not yet patched/delivered) and the
        # batch scheduled-but-not-yet-retired by on_batch_done
        self._draining: Optional[InflightRound] = None
        self._pending_batch: Optional[ScheduledBatch] = None
        self.feats: List[np.ndarray] = []
        self.lats: List[float] = []
        self.t_start = time.perf_counter()

        if kv_pool is not None:
            if scheduler.kv_pool is None:
                # the scheduler books blocks chunk-granularly inside schedule()
                scheduler.attach_kv_pool(kv_pool)
            engine.bind_kv_pool(kv_pool)
        # slots bind at first schedule and free at preemption, not admission
        scheduler.attach_slot_binder(engine.acquire_slot, releaser=engine.release)
        if scheduler.kv_pool is not None and scheduler.kv_booking:
            # preemption mode comes from the ENGINE config (it owns the
            # physical swap path); the deterministic cost model prices swap
            # bytes vs recompute FLOPs per victim
            scheduler.attach_swap(
                engine.swap_out, engine.swap_in,
                cost_model=CostModel(CostModelConfig(noise_std=0.0)),
                mode=engine.cfg.preemption_mode,
                restorer_tail=engine.swap_in_tail,
                payload_slicer=engine.slice_swap_payload,
            )
        # bubble accounting is per-serve: drop any history (and the
        # ready-stamp of a previous serve, which would read as one giant
        # inter-serve bubble)
        engine.bubble_ms = []
        engine._t_ready = None

    # -- clock ----------------------------------------------------------------
    def start(self, t_start: float) -> None:
        """Anchor this replica's clock (a multi-replica driver shares one)."""
        self.t_start = t_start

    def _now(self) -> float:
        return time.perf_counter() - self.t_start

    # -- intake ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Admit one request: pool registration (tenant + prompt hashes
        only — the prefix-cache MATCH waits for first slot bind, so a parked
        backlog pins no cached blocks and no tenant quota) plus scheduler
        submission."""
        if self.kv_pool is not None:
            self.kv_pool.register_request(
                req.req_id, tenant=req.tenant,
                prompt_tokens=req.prompt_tokens, prompt_len=req.prompt_len,
            )
        if not self.sched.submit(req):         # admission-rejected: give back
            if self.kv_pool is not None:
                self.kv_pool.release(req.req_id)

    def adopt_handoff(self, req: Request, rec, reg) -> None:
        """Decode-pool side of a cross-replica handoff: land the exported
        staging record in this replica's pool and enqueue the request.  The
        ordinary swap-restore path inside ``schedule()`` then binds a slot,
        re-charges the tenant's quota, scatters the payload, and resumes the
        request decode-only (``needs_replay`` stages its last delivered
        token) — no prefill chunk is ever scheduled for it here."""
        self.kv_pool.import_swap(req.req_id, rec, reg)
        self.sched.submit_handoff(req)

    # -- introspection ---------------------------------------------------------
    def has_work(self) -> bool:
        return self.sched.has_work()

    def has_inflight(self) -> bool:
        return self.inflight is not None

    def busy(self) -> bool:
        return (self.sched.has_work() or self.inflight is not None
                or self.engine.has_pending_swaps())

    def outstanding_work(self) -> int:
        """Tokens of runnable work currently on this replica (prefill left +
        decode left over queued/decoding requests) — the router's load key."""
        total = 0
        for r in self.sched.queue.requests():
            total += r.remaining_prefill + (r.max_new_tokens - r.generated)
        for r in self.sched._decoding.values():
            total += r.remaining_prefill + (r.max_new_tokens - r.generated)
        return total

    def tenant_outstanding(self, tenant: str) -> int:
        total = 0
        for r in list(self.sched.queue.requests()) + list(
                self.sched._decoding.values()):
            if r.tenant == tenant:
                total += r.remaining_prefill + (r.max_new_tokens - r.generated)
        return total

    # -- one scheduling round --------------------------------------------------
    def step(self, now: float) -> str:
        """Run one round, optionally under the fault boundary: chaos sites
        fire here and — when ``fault_tolerant`` — any exception out of the
        round (injected or real) is converted into a crash unwind plus an
        ``"error"`` status the health machinery consumes, instead of tearing
        down the whole serve loop."""
        if self.injector is None and not self.fault_tolerant:
            return self._step_impl(now)
        try:
            inj = self.injector
            if inj is not None:
                spec = inj.fire("slow_round_ms", replica=self.name)
                if spec is not None:
                    time.sleep(max(spec.value, 0.0) / 1e3)
                inj.maybe_raise("replica_step_crash", replica=self.name)
            return self._step_impl(now)
        except Exception as e:  # noqa: BLE001 — the replica fault boundary
            if not self.fault_tolerant:
                raise
            self.last_error = e
            self._crash_cleanup()
            return "error"

    def _step_impl(self, now: float) -> str:
        sched, engine = self.sched, self.engine
        drained_eagerly = False
        if self.inflight is not None and self.inflight.toks.is_ready():
            # device already finished: drain before (not after) the next
            # schedule — tokens/timestamps stamp at true readiness and the
            # bubble metric doesn't hide idle time behind the overlap
            self._drain_inflight()
            drained_eagerly = True
        if not sched.has_work():
            if self.inflight is not None:
                self._drain_inflight()
                return "drained"
            if engine.has_pending_swaps():
                # an exported (handoff) request's gather can be the only
                # pending work on this replica — land it so the router can
                # move the staged record on
                engine.finalize_swaps()
                return "finalized"
            # an eager drain above counts as progress — it may have just
            # finalized an exported gather the router is waiting on, so
            # "idle" (a quiesce signal) would be premature this step
            return "drained" if drained_eagerly else "idle"

        # preemption victims' slots were already freed inside schedule() (the
        # releaser hook) — a victim may even have re-bound a fresh slot and
        # been rescheduled within the same round, so do NOT release here.
        # In pipelined mode this schedule overlaps the in-flight round.
        batch = sched.schedule(now)
        if batch.is_empty():
            if self.inflight is not None:
                self._drain_inflight()
                return "drained"
            if engine.has_pending_swaps():
                # nothing in flight to piggyback the staging drain on (e.g.
                # every runnable request is a SWAPPING victim): finalize now
                # so the next schedule() round can restore them
                engine.finalize_swaps()
                return "finalized"
            return "drained" if drained_eagerly else "starved"

        # the batch is booked and counted but not yet retired: a crash
        # anywhere before on_batch_done must strip it back out of the stats
        self._pending_batch = batch
        if self.injector is not None:
            for r in batch.decode_reqs:
                if self.injector.fire("nan_logits", replica=self.name,
                                      req_id=r.req_id) is not None:
                    engine.poison_kv(r)

        if self.pipelined:
            if self.inflight is not None:
                # round N-1's ids land BEFORE round N+1 stages anything that
                # could embed them (a preemption fold re-prefills delivered
                # tokens) — this is the pipeline's one-round visibility lag.
                # The just-scheduled batch rides along so a late stop can be
                # unwound from it before it dispatches.
                self._drain_inflight(pending_batch=batch)
            self.inflight = engine.dispatch(batch)
            self.inflight.batch = batch
            wall_ms = None
        else:
            wall_ms = engine.execute(batch)
        if self.kv_pool is not None:
            # newly sealed (full, hashed) prompt blocks become restorable
            for r, _c in batch.prefill_chunks:
                engine.capture_sealed(r)
        if self.collect_samples:
            self.feats.append(batch.state.features())
            if wall_ms is not None:
                self.lats.append(wall_ms)
        self.rounds += 1

        now2 = self._now()
        sched.on_batch_done(batch, now2)       # releases finished KV refs
        self._pending_batch = None             # retired: charged and counted

        # sync-mode numerics quarantine: execute() drained inside the round,
        # so the finite mask is already host-visible.  Roll back the poisoned
        # token (its charge refunds), shed terminally, deliver the clean
        # prefix.  Pipelined mode does the same one round late, at drain.
        if not self.pipelined and engine.last_nonfinite:
            prefill_ids = {q.req_id for q, _ in batch.prefill_chunks}
            for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
                if r.req_id not in engine.last_nonfinite:
                    continue
                if r.rollback_undrained(1):
                    sched.refund_rolled_back(
                        r, first_token=r.req_id in prefill_ids)
                sched.shed_request(r, reason="numerics")
                self.outputs[r.req_id] = list(r.output_tokens)
                self.quarantined.append(r)
                if self.on_stopped is not None:
                    self.on_stopped(self, r)
            engine.scrub_poisoned()

        if self.pipelined:
            # the placeholder each sampled request just received sits at the
            # tail of its output_tokens; drain() patches the real id there
            for req, _slot in self.inflight.sampled:
                self.inflight.out_index[req.req_id] = len(req.output_tokens) - 1
            # sampled ∩ prefill = chunks that completed their prefill this
            # round: their prefill_end_time re-stamps at drain
            self.inflight.prefill_ids = {r.req_id for r, _ in batch.prefill_chunks}

        for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
            self.outputs.setdefault(r.req_id, [])
            if r.state == RequestState.FINISHED:
                if self.pipelined:
                    self.inflight.finished.append(r)
                else:
                    self.outputs[r.req_id] = list(r.output_tokens)
                engine.release(r)

        if not self.pipelined:
            # synchronous engine: token values are already real (execute()
            # drains internally), so stops and per-token timestamps apply in
            # the same round
            for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
                if r.req_id in engine.last_nonfinite:
                    continue       # quarantined above: its token rolled back
                if r.remaining_prefill == 0 and r.output_tokens:
                    r.token_times.append(now2)
                if (r.stop_token is not None
                        and r.state == RequestState.DECODING
                        and r.output_tokens
                        and r.output_tokens[-1] == r.stop_token):
                    r.finish_stopped(now2)
                    self.outputs[r.req_id] = list(r.output_tokens)
                    sched.on_stop(r)
                    if self.on_stopped is not None:
                        self.on_stopped(self, r)

        if self.on_prefill_complete is not None:
            for r, _c in batch.prefill_chunks:
                if r.state == RequestState.DECODING and r.remaining_prefill == 0:
                    self.on_prefill_complete(self, r)
        return "round"

    # -- drain -----------------------------------------------------------------
    def _drain_inflight(self, pending_batch: Optional[ScheduledBatch] = None) -> None:
        inflight, self.inflight = self.inflight, None
        # visible to _crash_cleanup until this round is fully delivered: a
        # crash inside drain/stop processing must unwind it, not strand it
        self._draining = inflight
        wall_ms = self.engine.drain(inflight)
        if self.collect_samples:
            self.lats.append(wall_ms)
        # timestamps recorded against the placeholder `now` are re-stamped to
        # the moment the ids actually became host-visible — the earliest a
        # client could receive them — so pipelined LatencyReports are not
        # systematically understated vs the synchronous engine's
        now_v = self._now()
        # numerics quarantine FIRST: a request whose sampled logits were
        # non-finite must not stamp, deliver, or stop on the garbage id.  The
        # poisoned placeholder rolls back (charge refunded), the request
        # sheds terminally, and its clean delivered prefix is the output.
        for req, _slot in inflight.sampled:
            if req.req_id not in inflight.nonfinite:
                continue
            if req in inflight.finished:
                inflight.finished.remove(req)
            if req.rollback_undrained(1):
                self.sched.refund_rolled_back(
                    req, first_token=req.req_id in inflight.prefill_ids)
            self.sched.shed_request(
                req, reason="numerics", batch=pending_batch)
            self.outputs[req.req_id] = list(req.output_tokens)
            self.quarantined.append(req)
            if self.on_stopped is not None:
                self.on_stopped(self, req)
        if inflight.nonfinite:
            self.engine.scrub_poisoned()
        for req, _slot in inflight.sampled:
            if req.req_id in inflight.nonfinite:
                continue
            if inflight.out_index.get(req.req_id) == 0:
                req.first_token_time = now_v
            if req.req_id in inflight.prefill_ids:
                req.prefill_end_time = now_v
            req.token_times.append(now_v)
        for r in inflight.finished:
            r.finish_time = now_v
            # patched ids are final only now — deliver them
            self.outputs[r.req_id] = list(r.output_tokens)
        # value-dependent stops, one round late: only now are the sampled ids
        # real.  A stopping request may meanwhile have been booked into the
        # next round (pending_batch — scheduled but not yet dispatched),
        # preempted to the queue, swap-staged, or exported for a handoff;
        # on_stop unwinds each of those (the over-scheduled round's KV
        # booking is refunded with the release).
        for req, _slot in inflight.sampled:
            if req.stop_token is None or req.state == RequestState.FINISHED:
                continue
            idx = inflight.out_index.get(req.req_id)
            if idx is None or req.output_tokens[idx] != req.stop_token:
                continue
            req.finish_stopped(now_v)
            self.outputs[req.req_id] = list(req.output_tokens)
            self.sched.on_stop(req, pending_batch)
            if self.on_stopped is not None:
                self.on_stopped(self, req)
        self._draining = None

    # -- crash unwind ----------------------------------------------------------
    def _crash_cleanup(self) -> None:
        """A step crashed somewhere between scheduling and delivery: unwind
        the torn round(s) so this replica (or, after failover, its
        survivors) can carry on without leaking slots, KV blocks, or phantom
        VTC charges.

        Up to three torn artifacts can exist:
          * ``_draining``      — a round popped by ``_drain_inflight`` that
                                 crashed before its tokens were delivered,
          * ``self.inflight``  — a round dispatched but never drained,
          * ``_pending_batch`` — a batch scheduled (KV booked, stats counted)
                                 whose ``on_batch_done`` never ran.

        Undrained placeholder tokens roll back and their charge refunds (the
        values never became host-visible; greedy recompute regenerates them
        bit-identically).  Every involved live request is then evicted from
        the scheduler, folded via ``preempt()`` (at-most-once delivery), and
        re-queued locally.  Already-delivered requests are left alone."""
        torn: List[InflightRound] = []
        if self._draining is not None:
            torn.append(self._draining)
            self._draining = None
        if self.inflight is not None:
            torn.append(self.inflight)
            self.inflight = None
        pending = self._pending_batch
        self._pending_batch = None

        victims: Dict[int, Request] = {}
        for infl in torn:
            for req, _slot in infl.sampled:
                victims[req.req_id] = req
            for req in infl.finished:
                victims[req.req_id] = req
            if infl.batch is not None:
                for req in infl.batch.decode_reqs:
                    victims[req.req_id] = req
                for req, _c in infl.batch.prefill_chunks:
                    victims[req.req_id] = req
        if pending is not None:
            for req in pending.decode_reqs:
                victims[req.req_id] = req
            for req, _c in pending.prefill_chunks:
                victims[req.req_id] = req

        for infl in torn:
            for req, _slot in infl.sampled:
                if infl.out_index.get(req.req_id) is None:
                    continue   # crash hit before the placeholder bookkeeping
                if (req.state == RequestState.FINISHED
                        and self.outputs.get(req.req_id)):
                    continue   # fully delivered before the crash: irrevocable
                if req.rollback_undrained(1):
                    self.sched.refund_rolled_back(
                        req, first_token=req.req_id in infl.prefill_ids)

        for req in victims.values():
            if req.state == RequestState.FINISHED:
                continue       # delivered, stopped, or shed before the crash
            if (self.kv_pool is not None
                    and req.req_id not in self.kv_pool._reg
                    and self.kv_pool.swap_state(req.req_id) is None
                    and not self.kv_pool.tables.get(req.req_id)):
                # no longer owned here: the round that tore also completed
                # this request's prefill and the router exported its handoff
                # (export_swap popped the registration) before the crash.
                # Its placeholder rolled back above; the handoff pipeline (or
                # the router's failover retraction, if this replica is dying)
                # owns its fate now.
                continue
            k = self._crash_retries.get(req.req_id, 0) + 1
            self._crash_retries[req.req_id] = k
            if (self.max_crash_retries is not None
                    and k > self.max_crash_retries):
                self.sched.shed_request(
                    req, reason="replica_failure", batch=pending)
                self.outputs[req.req_id] = list(req.output_tokens)
                self.crash_shed.append(req)
                continue
            self.sched.evict_request(req, pending)
            req.preempt()
            if self.kv_pool is not None:
                self.kv_pool.register_request(
                    req.req_id, tenant=req.tenant,
                    prompt_tokens=req.prompt_tokens,
                    prompt_len=req.prompt_len,
                )
            self.sched.requeue_failed(req)
            self.crash_requeued += 1
        self.crash_unwinds += 1

    def finish(self) -> None:
        """End-of-serve cleanup: drain the last round and land any pending
        swap copies (no staging entry is left mid-flight at exit)."""
        if self.inflight is not None:
            self._drain_inflight()
        self.engine.finalize_swaps()


def serve(
    requests: List[Request],
    scheduler: ChunkedPrefillScheduler,
    engine: JAXEngine,
    *,
    kv_pool: Optional[KVBlockPool] = None,
    collect_samples: bool = False,
    realtime_arrivals: bool = False,
    max_rounds: int = 200_000,
    robustness=None,
) -> ServeResult:
    """Continuous-batching serve loop over real execution.

    Admission hands requests straight to the scheduler — an engine slot is
    bound only when the scheduler first commits a chunk (late binding, via
    the slot-binder hook), so queued or admission-delayed backlog can never
    pin slots.

    With ``EngineConfig(pipelined=True)`` (default) the loop runs as a
    two-stage pipeline: while the device executes round N, the host runs
    admission + ``schedule()`` (aging, VTC, KV booking, preemption) for
    round N+1 and drains round N's sampled ids as an async copy — round N's
    token VALUES become host-visible one round late, which is fine because
    round bookkeeping (chunk deliveries, length-capped termination) is
    value-independent and the values themselves are only needed for
    delivered outputs, stop-token termination, and preemption folds, all
    patched/applied at drain time before anything is staged from them.
    ``collect_samples`` latencies in pipelined mode are dispatch->drain
    walls (device time plus overlapped host work).

    The loop body lives in ``ReplicaServer`` (one replica's admit/step/drain
    state machine); this wrapper owns only arrival admission and idle-gap
    handling.  realtime_arrivals=False (default) admits requests by the
    engine's own clock (wall time since start), compressing idle gaps —
    deterministic and fast for tests; True sleeps to honor arrival times.
    """
    pending = sorted(requests, key=lambda r: r.arrival_time)
    for r in pending:
        assert r.prompt_tokens is not None, "attach_prompt_tokens() first"
    server = ReplicaServer(
        scheduler, engine, kv_pool=kv_pool, collect_samples=collect_samples,
    )
    if robustness is not None:
        # colocated fault tolerance: crash unwinds + NaN quarantine survive
        # in-place (there is no second replica to fail over to — replica
        # death/failover lives in the disaggregated router)
        server.fault_tolerant = True
        server.injector = robustness.make_injector()
        server.max_crash_retries = robustness.max_retries
    # the same health machine the fleet router runs, over the lone replica:
    # a persistent fault (a repeat-crash site, a wedged device) must not
    # spin the serve loop forever — once DEAD, remaining work sheds
    # terminally (exactly-once termination with no fleet to fail over to)
    health = (ReplicaHealth(robustness.health, "replica0")
              if robustness is not None else None)
    next_i = 0
    t_start = time.perf_counter()
    server.start(t_start)
    now = 0.0

    while server.rounds < max_rounds:
        now = time.perf_counter() - t_start
        while next_i < len(pending) and pending[next_i].arrival_time <= now:
            server.submit(pending[next_i])
            next_i += 1
        status = server.step(now)
        if health is not None:
            health.observe(status, busy=server.busy(),
                           error=server.last_error
                           if status == "error" else None)
            if health.is_dead:
                break
        if status == "idle":
            if next_i >= len(pending):
                break
            if realtime_arrivals:
                time.sleep(min(0.001, pending[next_i].arrival_time - now))
            else:
                compress_idle_gap(pending, next_i, now)
        elif status == "starved":
            time.sleep(0.0005)

    if health is not None and health.is_dead:
        # the lone replica died: every request not already terminal sheds.
        # Submitted requests unwind their bookings through the scheduler;
        # unarrived backlog never registered anything and just marks shed.
        for i, r in enumerate(pending):
            if r.state == RequestState.FINISHED:
                continue
            if i < next_i:
                scheduler.shed_request(r, reason="replica_failure")
            else:
                r.shed_reason = "replica_failure"
                r.state = RequestState.FINISHED
            server.outputs[r.req_id] = list(r.output_tokens)
            server.crash_shed.append(r)

    server.finish()
    now = time.perf_counter() - t_start

    samples = (
        (np.stack(server.feats), np.asarray(server.lats))
        if collect_samples and server.feats else None
    )
    return ServeResult(
        report=summarize(requests, makespan=now),
        requests=requests,
        rounds=server.rounds,
        wall_s=now,
        samples=samples,
        outputs=server.outputs,
        memory=(
            summarize_memory(kv_pool, scheduler.stats) if kv_pool is not None else None
        ),
        host_bubble_ms=list(engine.bubble_ms),
        slo=(
            summarize_slo(requests, scheduler.fairness.registry)
            if scheduler.fairness is not None else None
        ),
        robustness=(
            summarize_robustness(
                FailoverStats(), injector=server.injector,
                quarantined=len(server.quarantined),
                crash_unwinds=server.crash_unwinds,
                crash_shed=len(server.crash_shed),
            )
            if robustness is not None else None
        ),
    )
