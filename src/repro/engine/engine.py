"""Real-execution chunked-prefill engine: the paper's serving loop running
actual JAX forward passes (tiny models on CPU; the identical program compiles
for TPU).

Slot-based continuous batching (vLLM/Sarathi style):
  * ``n_slots`` fixed sequence slots; requests map to slots on admission.
  * One jitted ``chunked_step`` per scheduling round executes the ENTIRE
    mixed batch — decode slots advance by 1 token, prefill slots by their
    scheduled chunk, idle slots by 0 — under static bucketed shapes
    (chunk dim padded to a power-of-two bucket) to bound recompilation.
  * The scheduler under test is the real ``repro.core`` code; latencies are
    wall-clock, so the LPRS predictor can be trained on real measurements
    (the paper's offline profiling pipeline, with CPU standing in for the
    accelerator).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, ScheduledBatch
from repro.engine.kv_cache import KVBlockPool, pool_for_model
from repro.engine.metrics import LatencyReport, MemoryReport, summarize, summarize_memory
from repro.engine.sampler import SamplerConfig, sample_tokens
from repro.models.model import Model, build_model


@dataclass
class EngineConfig:
    n_slots: int = 16
    max_context: int = 1024
    chunk_buckets: Tuple[int, ...] = (1, 16, 32, 64, 128, 256)
    use_pallas: bool = False          # True: Pallas kernels (interpret on CPU)
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    seed: int = 0


class JAXEngine:
    """Executes ScheduledBatches with real forward passes."""

    def __init__(self, model_cfg: ModelConfig, cfg: Optional[EngineConfig] = None,
                 params=None):
        self.cfg = cfg or EngineConfig()
        self.model_cfg = model_cfg
        self.model: Model = build_model(model_cfg)
        rng = jax.random.PRNGKey(self.cfg.seed)
        self.params = params if params is not None else self.model.init(rng)
        self._rng = jax.random.PRNGKey(self.cfg.seed + 1)

        B, S = self.cfg.n_slots, self.cfg.max_context
        hd = model_cfg.resolved_head_dim
        kv_shape = (model_cfg.n_layers, B, S + 1, model_cfg.n_kv_heads, hd)
        dt = jnp.dtype(model_cfg.param_dtype)
        self.cache = {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)}
        self.lens = jnp.zeros((B,), jnp.int32)

        self.slot_of: Dict[int, int] = {}          # req_id -> slot
        self.free_slots = list(range(B - 1, -1, -1))
        self.last_token = np.zeros((B,), np.int64)

        impl = self.model.impl
        use_pallas = self.cfg.use_pallas

        def step(params, tokens, cache, lens, chunk_lens, rng):
            logits, cache = impl.chunked_step(
                params, tokens, cache, lens, chunk_lens, use_pallas=use_pallas
            )
            toks = sample_tokens(logits, rng, self.cfg.sampler)
            return toks, cache

        self._step = jax.jit(step, donate_argnums=(2,),
                             static_argnames=())

    def warmup(self) -> None:
        """Compile every bucket shape once so profiling sees steady-state
        latencies, not jit compilation (the paper's 'cleaned' samples)."""
        B = self.cfg.n_slots
        for C in self.cfg.chunk_buckets:
            tokens = jnp.ones((B, C), jnp.int32)
            chunk_lens = jnp.zeros((B,), jnp.int32).at[0].set(1)
            self._rng, sub = jax.random.split(self._rng)
            toks, self.cache = self._step(
                self.params, tokens, self.cache, self.lens, chunk_lens, sub
            )
            jax.block_until_ready(toks)
        # reset cache/lens state touched by the dummy rounds
        self.lens = jnp.zeros((B,), jnp.int32)

    # -- slot management -------------------------------------------------------
    def admit(self, req: Request) -> bool:
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        self.slot_of[req.req_id] = slot
        self.lens = self.lens.at[slot].set(0)
        return True

    def release(self, req: Request) -> None:
        slot = self.slot_of.pop(req.req_id, None)
        if slot is not None:
            self.free_slots.append(slot)

    def reset_slot(self, req: Request) -> None:
        """KV-preempted request: its blocks were freed, so the slot's cache
        contents are dead — recompute restarts the prefill at position 0."""
        slot = self.slot_of.get(req.req_id)
        if slot is not None:
            self.lens = self.lens.at[slot].set(0)

    def has_capacity(self) -> bool:
        return len(self.free_slots) > 0

    # -- prefix-cache payloads -------------------------------------------------
    def restore_prefix(self, req: Request, kv_pool: KVBlockPool) -> None:
        """Write a prefix-cache hit's stored K/V payloads into the request's
        slot so the skipped prefill positions hold numerically identical
        state (causal attention: prefix KV depends only on prefix tokens)."""
        slot = self.slot_of[req.req_id]
        bs = kv_pool.cfg.block_size
        table = kv_pool.tables.get(req.req_id, [])
        n_matched = kv_pool.lens.get(req.req_id, 0) // bs
        ks, vs = [], []
        for bid in table[:n_matched]:
            payload = kv_pool.payload(bid)
            assert payload is not None, "engine prefix match requires payloads"
            ks.append(payload[0])
            vs.append(payload[1])
        if ks:
            # one functional update per cache tensor, not one per block
            self.cache["k"] = (
                self.cache["k"].at[:, slot, : n_matched * bs].set(jnp.concatenate(ks, axis=1))
            )
            self.cache["v"] = (
                self.cache["v"].at[:, slot, : n_matched * bs].set(jnp.concatenate(vs, axis=1))
            )
        self.lens = self.lens.at[slot].set(n_matched * bs)

    def capture_sealed(self, req: Request, kv_pool: KVBlockPool) -> None:
        """Park newly sealed (full, content-addressed) prompt blocks' K/V
        host-side so future prefix hits can restore them."""
        slot = self.slot_of.get(req.req_id)
        if slot is None:
            return
        for _idx, bid, s, e in kv_pool.take_newly_sealed(req.req_id):
            k_blk = jnp.asarray(self.cache["k"][:, slot, s:e])
            v_blk = jnp.asarray(self.cache["v"][:, slot, s:e])
            kv_pool.store_payload(bid, (k_blk, v_blk))

    # -- one round ---------------------------------------------------------------
    def _bucket(self, c: int) -> int:
        for b in self.cfg.chunk_buckets:
            if c <= b:
                return b
        return self.cfg.chunk_buckets[-1]

    def execute(self, batch: ScheduledBatch) -> float:
        """Run one mixed round; returns wall latency in ms."""
        B = self.cfg.n_slots
        max_chunk = max(
            [c for _, c in batch.prefill_chunks] + [1 if batch.decode_reqs else 0]
        )
        C = self._bucket(max_chunk)
        tokens = np.zeros((B, C), np.int64)
        chunk_lens = np.zeros((B,), np.int32)

        for req in batch.decode_reqs:
            slot = self.slot_of[req.req_id]
            tokens[slot, 0] = self.last_token[slot]
            chunk_lens[slot] = 1
        for req, c in batch.prefill_chunks:
            slot = self.slot_of[req.req_id]
            chunk = req.prompt_tokens[req.prefill_done : req.prefill_done + c]
            tokens[slot, : len(chunk)] = chunk
            chunk_lens[slot] = len(chunk)

        self._rng, sub = jax.random.split(self._rng)
        t0 = time.perf_counter()
        toks, self.cache = self._step(
            self.params, jnp.asarray(tokens), self.cache, self.lens,
            jnp.asarray(chunk_lens), sub,
        )
        toks = np.asarray(jax.block_until_ready(toks))
        wall_ms = (time.perf_counter() - t0) * 1e3

        self.lens = self.lens + jnp.asarray(chunk_lens)
        for req in batch.decode_reqs:
            slot = self.slot_of[req.req_id]
            self.last_token[slot] = toks[slot]
        for req, c in batch.prefill_chunks:
            slot = self.slot_of[req.req_id]
            if req.remaining_prefill - c <= 0:     # prefill completes this round
                self.last_token[slot] = toks[slot]
        return wall_ms


@dataclass
class ServeResult:
    report: LatencyReport
    requests: List[Request]
    rounds: int
    wall_s: float
    samples: Optional[Tuple[np.ndarray, np.ndarray]] = None
    outputs: Optional[Dict[int, List[int]]] = None
    memory: Optional[MemoryReport] = None     # KV pool lifecycle summary


def compress_idle_gap(pending: List[Request], next_i: int, now: float) -> None:
    """Jump the idle gap to the next arrival by shifting ALL future arrivals
    by the same constant, so inter-arrival gaps — and therefore arrival-order
    and aging behavior — are preserved mid-run."""
    offset = now - pending[next_i].arrival_time
    for j in range(next_i, len(pending)):
        pending[j].arrival_time += offset


def serve(
    requests: List[Request],
    scheduler: ChunkedPrefillScheduler,
    engine: JAXEngine,
    *,
    kv_pool: Optional[KVBlockPool] = None,
    collect_samples: bool = False,
    realtime_arrivals: bool = False,
    max_rounds: int = 200_000,
) -> ServeResult:
    """Continuous-batching serve loop over real execution.

    realtime_arrivals=False (default) admits requests by the engine's own
    clock (wall time since start), compressing idle gaps — deterministic and
    fast for tests; True sleeps to honor arrival times.
    """
    pending = sorted(requests, key=lambda r: r.arrival_time)
    for r in pending:
        assert r.prompt_tokens is not None, "attach_prompt_tokens() first"
    next_i = 0
    t_start = time.perf_counter()
    now = 0.0
    rounds = 0
    feats, lats = [], []
    outputs: Dict[int, List[int]] = {}
    if kv_pool is not None and scheduler.kv_pool is None:
        # the scheduler books blocks chunk-granularly inside schedule()
        scheduler.attach_kv_pool(kv_pool)

    def admit(now_s: float):
        nonlocal next_i
        while next_i < len(pending) and pending[next_i].arrival_time <= now_s:
            req = pending[next_i]
            if not engine.has_capacity():
                break
            matched = 0
            if kv_pool is not None:
                # prefix-cache match: only blocks with stored payloads count —
                # the engine must restore real K/V for every skipped position
                matched = kv_pool.submit_request(req, require_payload=True)
            engine.admit(req)
            if matched > 0:
                engine.restore_prefix(req, kv_pool)
            if not scheduler.submit(req):      # admission-rejected: give back
                engine.release(req)
                if kv_pool is not None:
                    kv_pool.release(req.req_id)
            next_i += 1

    while rounds < max_rounds:
        now = time.perf_counter() - t_start
        admit(now)
        if not scheduler.has_work():
            if next_i >= len(pending):
                break
            if realtime_arrivals:
                time.sleep(min(0.001, pending[next_i].arrival_time - now))
            else:
                compress_idle_gap(pending, next_i, now)
            continue

        batch = scheduler.schedule(now)
        for r in batch.preempted:
            engine.reset_slot(r)               # blocks freed: slot KV is dead
        if batch.is_empty():
            time.sleep(0.0005)
            continue

        wall_ms = engine.execute(batch)
        if kv_pool is not None:
            # park newly sealed (full, hashed) prompt blocks' K/V host-side
            for r, _c in batch.prefill_chunks:
                engine.capture_sealed(r, kv_pool)
        if collect_samples:
            feats.append(batch.state.features())
            lats.append(wall_ms)
        rounds += 1

        now = time.perf_counter() - t_start
        scheduler.on_batch_done(batch, now)    # releases finished KV refs

        for r in batch.decode_reqs + [q for q, _ in batch.prefill_chunks]:
            outputs.setdefault(r.req_id, [])
            if r.state == RequestState.FINISHED:
                outputs[r.req_id] = list(r.output_tokens)
                engine.release(r)

    samples = (np.stack(feats), np.asarray(lats)) if collect_samples and feats else None
    return ServeResult(
        report=summarize(requests, makespan=now),
        requests=requests,
        rounds=rounds,
        wall_s=now,
        samples=samples,
        outputs=outputs,
        memory=(
            summarize_memory(kv_pool, scheduler.stats) if kv_pool is not None else None
        ),
    )
