"""Multi-replica serving router: fault tolerance, straggler mitigation,
elastic scaling — the cluster-level control plane above per-replica
chunked-prefill engines.

Design (1000+ node posture, validated here over simulated replicas):
  * Each replica = one serving engine (a pod slice running the jitted step
    under its own mesh) with its own scheduler (the paper's centralized
    engine-side scheduling, §4.3.3, replicated per pod).
  * The router keeps a REQUEST JOURNAL: every request's arrival time and
    payload.  On replica failure, in-flight requests are replayed to healthy
    replicas with their ORIGINAL arrival times — Aging priorities are a pure
    function of (arrival, remaining work), so the fairness state reconstructs
    exactly (no distributed priority queues to keep consistent).
  * Heartbeats mark replicas dead after ``heartbeat_timeout``; stragglers
    (heartbeat ok, throughput below ``straggler_factor`` x fleet median) are
    drained and their queued work re-dispatched.
  * Elastic scaling: add_replica()/remove_replica() at any time; the router
    rebalances by least-outstanding-work-first dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.engine.costmodel import CostModel, CostModelConfig
from repro.engine.metrics import FairnessReport, summarize, summarize_by_tenant
from repro.tenancy import make_shared_vtc


@dataclass
class ReplicaState:
    rid: int
    scheduler: ChunkedPrefillScheduler
    sim: "ReplicaClock"
    alive: bool = True
    draining: bool = False
    added_at: float = 0.0
    last_heartbeat: float = 0.0
    rounds_done: int = 0
    tokens_done: int = 0
    assigned: Dict[int, Request] = field(default_factory=dict)  # req_id -> req


class ReplicaClock:
    """Discrete-event execution of one replica (same cost model as the
    simulator), advanced by the router's global clock."""

    def __init__(self, scheduler: ChunkedPrefillScheduler, cost: CostModel,
                 speed: float = 1.0):
        self.sched = scheduler
        self.cost = cost
        self.speed = speed            # <1 = straggler
        self.busy_until = 0.0

    def step(self, now: float) -> Optional[float]:
        """If idle and work exists, run one round; returns round latency s."""
        if now < self.busy_until or not self.sched.has_work():
            return None
        batch = self.sched.schedule(now)
        if batch.is_empty():
            return None
        dt = self.cost.batch_latency_ms(batch) / 1000.0 / self.speed
        self.busy_until = now + dt
        self.sched.on_batch_done(batch, now + dt)
        return dt


@dataclass
class RouterConfig:
    heartbeat_timeout: float = 1.0
    heartbeat_interval: float = 0.1
    straggler_factor: float = 0.35     # < 35% of median throughput => drain
    straggler_window: float = 3.0      # seconds of history for throughput
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    cost: CostModelConfig = field(default_factory=CostModelConfig)
    # one VirtualTokenCounter for the whole fleet: every replica's fair queue
    # sees each tenant's AGGREGATE service, so a tenant cannot launder load by
    # fanning requests across replicas.  Off => per-replica counters (the
    # pre-disaggregation behavior: each replica only sees its local slice).
    shared_vtc: bool = True
    # bound on replays per request across replica failures; past it the
    # request sheds terminally (shed_reason="replica_failure") instead of
    # ping-ponging forever between dying replicas.  None = unbounded (the
    # pre-fault-tolerance behavior).
    max_retries: Optional[int] = None


class Router:
    def __init__(self, cfg: RouterConfig, n_replicas: int = 2):
        self.cfg = cfg
        self.replicas: Dict[int, ReplicaState] = {}
        self._next_rid = 0
        self.journal: Dict[int, Request] = {}        # req_id -> original request
        self.completed: Dict[int, Request] = {}
        self.clock = 0.0
        self.events: List[str] = []
        self._replays: Dict[int, int] = {}           # req_id -> replay count
        self.shed_failed: List[Request] = []         # terminal replica_failure sheds
        self._shared_vtc = (
            make_shared_vtc(cfg.scheduler.fairness)
            if cfg.shared_vtc and cfg.scheduler.fairness is not None
            else None
        )
        for _ in range(n_replicas):
            self.add_replica()

    # -- elasticity ---------------------------------------------------------
    def add_replica(self, speed: float = 1.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        sched = ChunkedPrefillScheduler(
            self.cfg.scheduler, shared_vtc=self._shared_vtc
        )
        sim = ReplicaClock(sched, CostModel(self.cfg.cost), speed=speed)
        self.replicas[rid] = ReplicaState(
            rid=rid, scheduler=sched, sim=sim, last_heartbeat=self.clock,
            added_at=self.clock,
        )
        self.events.append(f"t={self.clock:.3f} add replica {rid} (speed {speed})")
        return rid

    def remove_replica(self, rid: int) -> None:
        """Graceful removal: drain then re-dispatch unfinished work."""
        st = self.replicas.get(rid)
        if st is None:
            return
        st.draining = True
        self.events.append(f"t={self.clock:.3f} drain replica {rid}")
        self._redistribute(st, reason="drain")
        st.alive = False

    def kill_replica(self, rid: int) -> None:
        """Hard failure: heartbeats stop; requests recovered by replay."""
        st = self.replicas[rid]
        st.alive = False
        self.events.append(f"t={self.clock:.3f} replica {rid} DIED")

    # -- dispatch -------------------------------------------------------------
    def _outstanding_work(self, st: ReplicaState) -> int:
        return sum(
            r.remaining_prefill + (r.max_new_tokens - r.generated)
            for r in st.assigned.values()
            if r.state != RequestState.FINISHED
        )

    def _tenant_outstanding(self, st: ReplicaState, tenant: str) -> int:
        return sum(
            r.remaining_prefill + (r.max_new_tokens - r.generated)
            for r in st.assigned.values()
            if r.tenant == tenant and r.state != RequestState.FINISHED
        )

    def _healthy(self) -> List[ReplicaState]:
        return [s for s in self.replicas.values() if s.alive and not s.draining]

    def submit(self, req: Request) -> None:
        self.journal[req.req_id] = req
        self._dispatch(req)

    def _dispatch(self, req: Request) -> None:
        healthy = self._healthy()
        if not healthy:
            raise RuntimeError("no healthy replicas")
        if self.cfg.scheduler.fairness is not None:
            # tenant-aware: spread each tenant's work across replicas first
            # (so one tenant's burst can't capture a whole replica), then
            # least-loaded overall.  Replays keep the original tenant tag, so
            # per-replica VTC accounting reconstructs after failover.
            target = min(
                healthy,
                key=lambda s: (
                    self._tenant_outstanding(s, req.tenant),
                    self._outstanding_work(s),
                    s.rid,
                ),
            )
        else:
            target = min(healthy, key=self._outstanding_work)
        if target.scheduler.submit(req):
            target.assigned[req.req_id] = req

    def _redistribute(self, st: ReplicaState, reason: str) -> None:
        """Replay a replica's unfinished requests elsewhere.

        Replayed requests keep their ORIGINAL arrival time; prefill progress
        on the dead replica is lost (its KV cache is gone), so remaining
        work resets to the full prompt — exactly the recovery semantics of a
        stateless-scheduler engine.  Aging re-derives priority from
        (arrival, remaining), so long-waiting requests keep their seniority.
        """
        replay = [r for r in st.assigned.values() if r.state != RequestState.FINISHED]
        st.assigned.clear()
        for r in replay:
            k = self._replays.get(r.req_id, 0) + 1
            self._replays[r.req_id] = k
            if self.cfg.max_retries is not None and k > self.cfg.max_retries:
                # retries exhausted: terminal shed, never silently lost — the
                # journal entry ends FINISHED so the run can still quiesce
                r.shed_reason = "replica_failure"
                r.state = RequestState.FINISHED
                self.journal[r.req_id] = r
                self.shed_failed.append(r)
                self.events.append(
                    f"t={self.clock:.3f} req {r.req_id} shed after {k - 1} replays"
                )
                continue
            fresh = Request(
                prompt_len=r.prompt_len,
                max_new_tokens=r.max_new_tokens,
                arrival_time=r.arrival_time,           # seniority preserved
                req_id=r.req_id,
                tenant=r.tenant,
                prompt_tokens=r.prompt_tokens,
            )
            self.journal[fresh.req_id] = fresh
            self._dispatch(fresh)
        if replay:
            self.events.append(
                f"t={self.clock:.3f} replayed {len(replay)} requests from "
                f"replica {st.rid} ({reason})"
            )

    # -- fairness aggregation ---------------------------------------------------
    def tenant_service(self) -> Dict[str, float]:
        """Actual tokens executed per tenant, summed across ALL replicas ever
        (dead ones included: their executed tokens were real service, even if
        the prefill progress itself was lost and replayed elsewhere).

        With a shared VTC every replica charges one counter, so it is read
        ONCE — summing each replica's view of it would multiply the total by
        the replica count."""
        if self._shared_vtc is not None:
            return {
                t: float(self._shared_vtc.actual_tokens(t))
                for t in self._shared_vtc.tenants()
            }
        out: Dict[str, float] = {}
        for st in self.replicas.values():
            fairness = st.scheduler.fairness
            if fairness is None:
                continue
            for t, tokens in fairness.service_by_tenant().items():
                out[t] = out.get(t, 0.0) + tokens
        return out

    def fairness_report(self) -> FairnessReport:
        """Per-tenant latency/service summary over the request journal."""
        weights = None
        fairness_cfg = self.cfg.scheduler.fairness
        if fairness_cfg is not None:
            weights = {t.name: t.weight for t in fairness_cfg.tenants}
        return summarize_by_tenant(
            self.journal.values(), weights=weights, makespan=self.clock
        )

    # -- health -----------------------------------------------------------------
    def _check_health(self) -> None:
        for st in list(self.replicas.values()):
            if not st.alive:
                if st.assigned:
                    self._redistribute(st, reason="failure")
                continue
            st.last_heartbeat = self.clock
        # straggler detection on throughput (tokens/s over the window)
        healthy = [
            s for s in self._healthy()
            if self.clock - s.added_at > self.cfg.straggler_window
        ]
        if len(healthy) >= 2:
            def rate_of(s):
                return s.tokens_done / max(self.clock - s.added_at, 1e-6)
            rates = sorted(rate_of(s) for s in healthy)
            median = rates[len(rates) // 2]
            for st in healthy:
                rate = rate_of(st)
                if (
                    median > 0
                    and rate < self.cfg.straggler_factor * median
                    and not st.draining
                ):
                    self.events.append(
                        f"t={self.clock:.3f} replica {st.rid} STRAGGLER "
                        f"({rate:.0f} vs median {median:.0f} tok/s) -> drain"
                    )
                    self.remove_replica(st.rid)

    # -- run ------------------------------------------------------------------
    def run(self, requests: List[Request], *, until: Optional[float] = None,
            fault_at: Optional[Dict[float, Callable]] = None,
            tick: float = 0.001, max_ticks: int = 10_000_000):
        """Event loop: admit arrivals, advance replicas, health checks.

        fault_at: {time_s: callback(router)} fault/scale injections.
        """
        pending = sorted(requests, key=lambda r: r.arrival_time)
        for r in pending:
            self.journal[r.req_id] = r
        next_i = 0
        faults = sorted((fault_at or {}).items())
        fault_i = 0
        last_health = 0.0
        ticks = 0

        def all_done():
            return next_i >= len(pending) and all(
                r.state == RequestState.FINISHED for r in self.journal.values()
            )

        while ticks < max_ticks:
            ticks += 1
            # inject faults
            while fault_i < len(faults) and faults[fault_i][0] <= self.clock:
                faults[fault_i][1](self)
                fault_i += 1
            # admissions
            while next_i < len(pending) and pending[next_i].arrival_time <= self.clock:
                self._dispatch(pending[next_i])
                next_i += 1
            # health
            if self.clock - last_health >= self.cfg.heartbeat_interval:
                self._check_health()
                last_health = self.clock
            # advance replicas
            progressed = False
            for st in self._healthy():
                dt = st.sim.step(self.clock)
                if dt is not None:
                    st.rounds_done += 1
                    progressed = True
                st.tokens_done = (
                    st.scheduler.stats.scheduled_prefill_tokens
                    + st.scheduler.stats.scheduled_decode_tokens
                )
            if until is not None and self.clock >= until:
                break
            if all_done() and fault_i >= len(faults):
                break
            self.clock += tick if not progressed else tick

        finished = [r for r in self.journal.values()]
        return summarize(finished, makespan=self.clock)
