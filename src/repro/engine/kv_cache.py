"""Paged KV-cache block pool (vLLM-style block accounting).

The pool manages fixed-size token blocks per request; on TPU the backing
store is a preallocated HBM tensor, here the accounting layer is shared by
the simulator (features + admission control) and the CPU engine (which backs
requests with per-request arrays but books blocks through the same pool, so
LPRS sees identical memory features in both modes).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class KVPoolConfig:
    n_blocks: int = 4096
    block_size: int = 16              # tokens per block
    bytes_per_token: int = 0          # 2 * L * H_kv * hd * dtype_bytes
    hbm_capacity_mb: float = 16 * 1024.0
    param_mb: float = 0.0


class KVBlockPool:
    def __init__(self, cfg: KVPoolConfig):
        self.cfg = cfg
        self.free_blocks: List[int] = list(range(cfg.n_blocks - 1, -1, -1))
        self.tables: Dict[int, List[int]] = {}     # req_id -> block ids
        self.lens: Dict[int, int] = {}             # req_id -> tokens stored

    # -- alloc/free -----------------------------------------------------------
    def blocks_needed(self, req_id: int, new_tokens: int) -> int:
        cur = self.lens.get(req_id, 0)
        have = len(self.tables.get(req_id, []))
        need = math.ceil((cur + new_tokens) / self.cfg.block_size)
        return max(0, need - have)

    def can_allocate(self, req_id: int, new_tokens: int) -> bool:
        return self.blocks_needed(req_id, new_tokens) <= len(self.free_blocks)

    def allocate(self, req_id: int, new_tokens: int) -> List[int]:
        need = self.blocks_needed(req_id, new_tokens)
        if need > len(self.free_blocks):
            raise MemoryError(
                f"KV pool exhausted: need {need} blocks, have {len(self.free_blocks)}"
            )
        got = [self.free_blocks.pop() for _ in range(need)]
        self.tables.setdefault(req_id, []).extend(got)
        self.lens[req_id] = self.lens.get(req_id, 0) + new_tokens
        return got

    def release(self, req_id: int) -> None:
        blocks = self.tables.pop(req_id, [])
        self.free_blocks.extend(blocks)
        self.lens.pop(req_id, None)

    # -- accounting (LPRS features) --------------------------------------------
    @property
    def used_blocks(self) -> int:
        return self.cfg.n_blocks - len(self.free_blocks)

    @property
    def used_mb(self) -> float:
        return self.used_blocks * self.cfg.block_size * self.cfg.bytes_per_token / 2**20

    @property
    def free_mb(self) -> float:
        return len(self.free_blocks) * self.cfg.block_size * self.cfg.bytes_per_token / 2**20

    @property
    def allocated_mb(self) -> float:
        return self.cfg.param_mb + self.used_mb

    @property
    def reserved_mb(self) -> float:
        return self.cfg.hbm_capacity_mb

    def utilization(self) -> float:
        return self.used_blocks / max(self.cfg.n_blocks, 1)


def pool_for_model(cfg_model, *, n_blocks: int = 8192, block_size: int = 16,
                   hbm_mb: float = 16 * 1024.0) -> KVBlockPool:
    """Size bytes_per_token from a ModelConfig (attention layers only)."""
    hd = cfg_model.resolved_head_dim
    if cfg_model.attn_every:
        n_attn = sum(1 for l in range(cfg_model.n_layers) if l % cfg_model.attn_every == 0)
    elif cfg_model.family == "ssm":
        n_attn = 0
    else:
        n_attn = cfg_model.n_layers
    bpt = 2 * n_attn * cfg_model.n_kv_heads * hd * 2  # k+v, bf16
    param_mb = cfg_model.param_count() * 2 / 2**20
    return KVBlockPool(
        KVPoolConfig(
            n_blocks=n_blocks,
            block_size=block_size,
            bytes_per_token=max(bpt, 2),
            hbm_capacity_mb=hbm_mb,
            param_mb=param_mb,
        )
    )
