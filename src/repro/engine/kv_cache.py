"""Paged KV-cache memory subsystem (vLLM-style block lifecycle).

The pool manages fixed-size token blocks per request; on TPU the backing
store is a preallocated HBM tensor, here the accounting layer is shared by
the simulator (features + admission control) and the CPU engine (which backs
requests with per-request arrays but books blocks through the same pool, so
LPRS sees identical memory features in both modes).

Beyond flat accounting, the pool implements the full KV lifecycle:

* **Refcounted blocks** — a physical block may back several requests at once
  (prefix sharing); it returns to circulation only when the last reference
  drops.
* **Hash-based prefix cache** — full *prompt* blocks are content-addressed by
  a chained hash ``h_i = H(h_{i-1}, tokens_i)`` (so a block's identity pins
  the entire prefix before it, not just its own tokens).  When the last
  reference to a hashed block drops the block is parked in an LRU of
  *evictable* cached blocks instead of the free list: a later request whose
  prompt shares the block-aligned prefix re-acquires it with
  ``match_prefix`` and skips the corresponding prefill compute.
* **Per-tenant quotas** — each tenant may be capped to a block budget;
  allocation and prefix acquisition charge the requesting tenant, release
  refunds it.  A shared physical block is charged to every request holding a
  reference (conservative logical accounting: quotas bound what a tenant can
  *pin*, not a fair-division of physical residency).
* **Payload store** — the real engine parks the actual K/V arrays of sealed
  blocks host-side so a prefix hit restores numerically identical KV state
  into a fresh slot (causal attention: prefix KV depends only on the prefix).
* **Swap staging store** — a preemption victim's KV can be *swapped out* to a
  host-side staging entry instead of discarded: ``swap_out`` moves the
  request's whole table into a ``_SwapRecord`` (device blocks freed, tenant
  quota refunded), ``swap_in`` later rebuilds the table from fresh blocks
  (quota re-charged) and hands the staged payload back for the device
  restore.  The record carries the block lifecycle state: ``SWAPPING`` while
  the device→host gather is still in flight (the scheduler must not restore
  — or even re-bind — the victim yet), ``SWAPPED_OUT`` once the payload is
  host-resident.  Blocks referenced by live tables are implicitly
  ``RESIDENT``.
* **Managed host tier** — the staging store is a real second cache level,
  not an unbounded spill area: a ``HostTier`` byte budget
  (``host_max_bytes``) is charged at swap-out and released at
  restore/drop/export.  When a reservation does not fit, the pool evicts
  its oldest staged records (stage-time LRU) — the evicted victim is
  *demoted to recompute*: the scheduler notices the record vanished and
  folds the request via ``Request.preempt()``, so nothing ever leaks, it
  just re-prefills.  Opt-in ``host_kv_dtype="int8"`` stores host pages
  quantized (per-page-per-head absmax scales, fused into the swap
  kernels), roughly halving the bytes a staged token charges.  Records can
  also be *shrunk to their decode-hot tail* (``shrink_swap_to_tail``) so a
  fragmented pool restores the last ``k`` blocks decode-resumable and only
  re-prefills the evicted prefix (``swap_in_tail``).  One ``HostTier`` may
  be shared by several pools and the cross-replica handoff store, closing
  one byte ledger over the whole host footprint.

Invariant (``check_invariants``):  ``free + evictable + referenced ==
n_blocks``; refcounts are never negative; every table entry references a
live block; tenant charges sum to the table sizes; every live request's
tokens are tracked by exactly one of {block table, swap staging entry},
never both; swapped tokens pin no device blocks and no tenant quota.
"""
from __future__ import annotations

import enum
import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class KVQuotaExceeded(MemoryError):
    """Allocation refused because the tenant's KV block quota is exhausted
    (the pool itself may still have free blocks)."""


# Payload sentinel for the paged engine: the sealed block's K/V still live in
# the engine's physical page at this block id, so a prefix hit needs no
# host-side copy — the marker only proves residency to ``require_payload``
# matches.  (A block id is recycled only after eviction removes its hash, so
# a matchable block's page content is always intact.)
PAGED_RESIDENT = "paged-resident"


class BlockState(enum.Enum):
    """Lifecycle of a request's KV data relative to device memory.  Blocks in
    a live table are RESIDENT; a swap record is SWAPPING while the
    device→host gather is in flight and SWAPPED_OUT once the payload is
    host-side (only then may the request be restored)."""

    RESIDENT = "resident"
    SWAPPING = "swapping"
    SWAPPED_OUT = "swapped_out"


@dataclass
class _SwapRecord:
    """One swapped-out request's host-side staging entry: its logical KV
    length, how many device blocks a restore must re-allocate, and the
    gathered K/V payload (``None`` for accounting-only users like the
    simulator, or while the async gather has not drained yet)."""

    tokens: int                       # stored KV length at swap-out
    n_blocks: int                     # device blocks the restore re-allocates
    tenant: str = "default"
    state: BlockState = BlockState.SWAPPING
    payload: object = None            # engine K/V arrays once the copy drains
    # cross-replica handoff: an imported record's restored prompt blocks are
    # content-addressed on THIS pool at swap-in, so later placement probes
    # (``probe_prefix``) see the prefix as resident here
    seal_on_restore: bool = False
    # host-tier accounting: bytes this record charges against the HostTier
    # budget (0 for accounting-only pools with bytes_per_token == 0)
    nbytes: int = 0
    # payload stored as INT8 pages + per-page-per-head scales (host_kv_dtype)
    quantized: bool = False
    # partial swap-in: > 0 marks a record shrunk to its decode-hot tail —
    # only blocks [tail_start_blocks, n_blocks) remain staged; the prefix
    # must be re-prefilled before ``swap_in_tail`` appends the tail
    tail_start_blocks: int = 0


@dataclass
class HostTierStats:
    """Byte ledger of the host staging tier.  Closes every step:
    ``put_bytes - freed_bytes == resident_bytes`` and, with a budget set,
    ``resident_bytes <= max_bytes`` always."""

    put_bytes: int = 0                # Σ bytes ever charged
    freed_bytes: int = 0              # Σ bytes ever released
    resident_bytes: int = 0           # currently charged
    peak_bytes: int = 0               # high-water mark of resident_bytes
    evictions: int = 0                # staged records evicted, all causes
    swap_evictions: int = 0           # ... evicted to fit a new swap-out
    handoff_evictions: int = 0        # ... evicted to fit a handoff import


class HostTier:
    """Byte-budgeted host staging tier shared by swap records and (optionally)
    the cross-replica handoff store.  The tier itself only keeps the ledger;
    *what* to evict is the owning pool's call (stage-time LRU over its own
    records) — reservations must therefore be gated by the caller
    (``host_can_stage``) so ``charge`` never has to fail halfway through a
    swap-out."""

    def __init__(self, max_bytes: Optional[int] = None):
        assert max_bytes is None or max_bytes >= 0
        self.max_bytes = max_bytes
        self.stats = HostTierStats()

    def can_fit(self, nbytes: int) -> bool:
        return (self.max_bytes is None
                or self.stats.resident_bytes + nbytes <= self.max_bytes)

    def charge(self, nbytes: int) -> None:
        assert nbytes >= 0
        assert self.can_fit(nbytes), (
            f"host tier over budget: {self.stats.resident_bytes} + {nbytes} "
            f"> {self.max_bytes} (caller must gate on host_can_stage)"
        )
        st = self.stats
        st.put_bytes += nbytes
        st.resident_bytes += nbytes
        st.peak_bytes = max(st.peak_bytes, st.resident_bytes)

    def release(self, nbytes: int) -> None:
        st = self.stats
        assert 0 <= nbytes <= st.resident_bytes, (
            f"host tier ledger underflow: release {nbytes} of "
            f"{st.resident_bytes} resident"
        )
        st.freed_bytes += nbytes
        st.resident_bytes -= nbytes

    def note_eviction(self, cause: str) -> None:
        self.stats.evictions += 1
        field_name = f"{cause}_evictions"
        setattr(self.stats, field_name,
                getattr(self.stats, field_name) + 1)

    def check_invariants(self) -> None:
        st = self.stats
        assert st.resident_bytes >= 0, "negative host-tier residency"
        assert st.put_bytes - st.freed_bytes == st.resident_bytes, (
            f"host tier ledger drift: put {st.put_bytes} - freed "
            f"{st.freed_bytes} != resident {st.resident_bytes}"
        )
        if self.max_bytes is not None:
            assert st.resident_bytes <= self.max_bytes, (
                f"host tier over budget: {st.resident_bytes} > {self.max_bytes}"
            )


@dataclass
class KVPoolConfig:
    n_blocks: int = 4096
    block_size: int = 16              # tokens per block
    bytes_per_token: int = 0          # 2 * L * H_kv * hd * dtype_bytes
    hbm_capacity_mb: float = 16 * 1024.0
    param_mb: float = 0.0
    enable_prefix_cache: bool = False
    # bounds on the *evictable* prefix-cache LRU (refcount-0 cached blocks):
    # None = unbounded (cache grows until demand reclaims it)
    cache_max_blocks: Optional[int] = None   # capacity cap on parked blocks
    cache_ttl_s: Optional[float] = None      # evict blocks idle longer than this
    # host staging tier: byte budget over staged swap records (None =
    # unbounded, the pre-tier behavior); reservations past the budget evict
    # the oldest staged records, demoting their victims to recompute
    host_max_bytes: Optional[int] = None
    # "auto" stages pages in the device dtype; "int8" quantizes host pages
    # (per-page-per-head absmax scales) — a staged token charges roughly
    # half the bytes against host_max_bytes
    host_kv_dtype: str = "auto"


@dataclass
class KVPoolStats:
    lookups: int = 0                  # match_prefix calls
    hit_blocks: int = 0               # cached blocks re-acquired
    miss_blocks: int = 0              # full prompt blocks that missed
    hit_tokens: int = 0               # prefill tokens skipped via the cache
    evictions: int = 0                # cached blocks evicted, all causes
    demand_evictions: int = 0         # ... reclaimed for new allocations
    capacity_evictions: int = 0       # ... trimmed by cache_max_blocks
    ttl_evictions: int = 0            # ... expired by cache_ttl_s
    sealed_blocks: int = 0            # blocks that became cache-addressable
    swap_outs: int = 0                # requests swapped out to host staging
    swap_ins: int = 0                 # requests restored from host staging
    swapped_out_tokens: int = 0       # Σ tokens moved device -> host
    swapped_in_tokens: int = 0        # Σ tokens moved host -> device
    handoff_exports: int = 0          # staged records exported to another pool
    handoff_imports: int = 0          # staged records imported from another pool
    partial_swap_ins: int = 0         # tail-only restores (partial swap-in)
    tail_tokens_restored: int = 0     # Σ tokens restored via swap_in_tail

    @property
    def hit_rate(self) -> float:
        """Block-level cache hit rate over all prefix lookups."""
        total = self.hit_blocks + self.miss_blocks
        return self.hit_blocks / total if total else 0.0


@dataclass
class _Registration:
    """Submit-time metadata the prefix cache needs for one request."""

    tenant: str = "default"
    prompt_len: int = 0
    block_hashes: List[int] = field(default_factory=list)  # full prompt blocks
    sealed: int = 0                   # prompt blocks already content-addressed
    newly_sealed: List[Tuple[int, int, int, int]] = field(default_factory=list)
    # (block_index, block_id, start_token, end_token) since last take_newly_sealed


class KVBlockPool:
    def __init__(self, cfg: KVPoolConfig):
        self.cfg = cfg
        self.free_blocks: List[int] = list(range(cfg.n_blocks - 1, -1, -1))
        self.tables: Dict[int, List[int]] = {}     # req_id -> block ids
        self.lens: Dict[int, int] = {}             # req_id -> tokens stored
        # block metadata (only for non-free blocks)
        self._ref: Dict[int, int] = {}             # block_id -> refcount
        self._hash_of: Dict[int, int] = {}         # block_id -> content hash
        self._payload: Dict[int, object] = {}      # block_id -> engine KV arrays
        # prefix cache: content hash -> block id; LRU over refcount-0 members
        self._cache_index: Dict[int, int] = {}
        self._evictable: "OrderedDict[int, int]" = OrderedDict()  # block_id -> hash
        self._parked_at: Dict[int, float] = {}     # block_id -> park clock (TTL)
        self._now = 0.0                            # advanced by the scheduler
        # host-side swap staging: req_id -> _SwapRecord (disjoint from tables).
        # Insertion order == stage-time order: the dict doubles as the host
        # tier's eviction LRU (oldest staged record evicts first).
        self._swap: Dict[int, _SwapRecord] = {}
        # host tier: private by default; attach_host_tier shares one budget
        # across several pools and the handoff store
        self.host = HostTier(cfg.host_max_bytes)
        self._host_charged = 0        # bytes THIS pool holds in the tier
        # per-request registration + per-tenant accounting
        self._reg: Dict[int, _Registration] = {}
        self._tenant_used: Dict[str, int] = {}     # tenant -> charged blocks
        self._tenant_quota: Dict[str, int] = {}    # tenant -> max blocks (absent = inf)
        self.stats = KVPoolStats()

    # -- registration / prefix cache ------------------------------------------
    @staticmethod
    def _chain_hashes(tokens, block_size: int) -> List[int]:
        """Chained content hashes over the full (block-aligned) prompt blocks."""
        hashes: List[int] = []
        prev = 0
        for i in range(len(tokens) // block_size):
            prev = hash((prev, tuple(tokens[i * block_size : (i + 1) * block_size])))
            hashes.append(prev)
        return hashes

    def register_request(
        self,
        req_id: int,
        *,
        tenant: str = "default",
        prompt_tokens=None,
        prompt_len: int = 0,
    ) -> None:
        """Record submit-time metadata (tenant for quota charging; prompt
        block hashes for the prefix cache).  Idempotent per request."""
        reg = self._reg.get(req_id)
        if reg is None:
            reg = _Registration(tenant=tenant, prompt_len=prompt_len)
            self._reg[req_id] = reg
        reg.tenant = tenant
        if prompt_len:
            reg.prompt_len = prompt_len
        if self.cfg.enable_prefix_cache and prompt_tokens is not None:
            reg.prompt_len = reg.prompt_len or len(prompt_tokens)
            reg.block_hashes = self._chain_hashes(prompt_tokens, self.cfg.block_size)

    def tenant_of(self, req_id: int) -> str:
        reg = self._reg.get(req_id)
        return reg.tenant if reg is not None else "default"

    def match_prefix(self, req_id: int, *, require_payload: bool = False) -> int:
        """Acquire the longest cached chain of the request's prompt blocks.

        Matched blocks are refcounted into the request's table and the
        request's stored length jumps past them — the caller then skips the
        corresponding prefill compute.  Always leaves at least one token of
        prompt uncached (the final-token logits must be computed to start
        decoding).  Returns the number of prompt tokens covered.
        """
        reg = self._reg.get(req_id)
        if reg is None or not reg.block_hashes or self.tables.get(req_id):
            return 0
        self.stats.lookups += 1
        bs = self.cfg.block_size
        matched: List[int] = []
        for h in reg.block_hashes:
            bid = self._cache_index.get(h)
            if bid is None or (require_payload and bid not in self._payload):
                break
            matched.append(bid)
        # never cover the whole prompt: the last token's logits start decode
        while matched and len(matched) * bs >= reg.prompt_len:
            matched.pop()
        # quota: matched blocks pin memory for this tenant too
        quota = self._tenant_quota.get(reg.tenant)
        if quota is not None:
            headroom = max(0, quota - self._tenant_used.get(reg.tenant, 0))
            matched = matched[:headroom]
        self.stats.hit_blocks += len(matched)
        self.stats.miss_blocks += len(reg.block_hashes) - len(matched)
        if not matched:
            return 0
        for bid in matched:
            self._ref[bid] = self._ref.get(bid, 0) + 1
            self._evictable.pop(bid, None)      # referenced again: not evictable
            self._parked_at.pop(bid, None)
        self.tables[req_id] = list(matched)
        self.lens[req_id] = len(matched) * bs
        reg.sealed = len(matched)               # shared blocks are already sealed
        self._tenant_used[reg.tenant] = (
            self._tenant_used.get(reg.tenant, 0) + len(matched)
        )
        self.stats.hit_tokens += len(matched) * bs
        return len(matched) * bs

    def submit_request(self, req, *, require_payload: bool = False) -> int:
        """Admission hook: register + prefix-match one ``Request``; on a hit
        the request's ``prefill_done`` jumps past the cached tokens so the
        scheduler only sees the residual prefill work."""
        self.register_request(
            req.req_id,
            tenant=req.tenant,
            prompt_tokens=req.prompt_tokens,
            prompt_len=req.prompt_len,
        )
        matched = self.match_prefix(req.req_id, require_payload=require_payload)
        if matched > 0:
            req.prefill_done = matched
        return matched

    # -- quotas ---------------------------------------------------------------
    def set_tenant_quota(self, tenant: str, max_blocks: Optional[int]) -> None:
        if max_blocks is None:
            self._tenant_quota.pop(tenant, None)
        else:
            self._tenant_quota[tenant] = int(max_blocks)

    def tenant_quota(self, tenant: str) -> Optional[int]:
        return self._tenant_quota.get(tenant)

    def tenant_used_blocks(self, tenant: str) -> int:
        return self._tenant_used.get(tenant, 0)

    def blocks_by_tenant(self) -> Dict[str, int]:
        return {t: n for t, n in self._tenant_used.items() if n > 0}

    def quota_headroom_blocks(self, tenant: str) -> float:
        quota = self._tenant_quota.get(tenant)
        if quota is None:
            return math.inf
        return max(0, quota - self._tenant_used.get(tenant, 0))

    # -- alloc/free -----------------------------------------------------------
    def blocks_needed(self, req_id: int, new_tokens: int) -> int:
        cur = self.lens.get(req_id, 0)
        have = len(self.tables.get(req_id, []))
        need = math.ceil((cur + new_tokens) / self.cfg.block_size)
        return max(0, need - have)

    def allocatable_blocks(self) -> int:
        """Free blocks plus cached blocks nobody references (reclaimable)."""
        return len(self.free_blocks) + len(self._evictable)

    def can_allocate(self, req_id: int, new_tokens: int,
                     tenant: Optional[str] = None) -> bool:
        need = self.blocks_needed(req_id, new_tokens)
        if need > self.allocatable_blocks():
            return False
        return need <= self.quota_headroom_blocks(tenant or self.tenant_of(req_id))

    def quota_blocked(self, req_id: int, new_tokens: int,
                      tenant: Optional[str] = None) -> bool:
        """True when the tenant quota (not pool space) is the binding limit."""
        need = self.blocks_needed(req_id, new_tokens)
        return need > self.quota_headroom_blocks(tenant or self.tenant_of(req_id))

    def max_new_tokens(self, req_id: int, tenant: Optional[str] = None) -> int:
        """How many new tokens this request could allocate right now, given
        pool space, reclaimable cache, and its tenant's quota headroom."""
        bs = self.cfg.block_size
        cur = self.lens.get(req_id, 0)
        have = len(self.tables.get(req_id, []))
        slack = have * bs - cur
        headroom = min(
            self.allocatable_blocks(),
            self.quota_headroom_blocks(tenant or self.tenant_of(req_id)),
        )
        return int(slack + headroom * bs)

    def _evict_one(self, reason: str = "demand") -> None:
        bid, h = self._evictable.popitem(last=False)    # LRU
        self._parked_at.pop(bid, None)
        self._cache_index.pop(h, None)
        self._hash_of.pop(bid, None)
        self._payload.pop(bid, None)
        self._ref.pop(bid, None)
        self.free_blocks.append(bid)
        self.stats.evictions += 1
        setattr(self.stats, f"{reason}_evictions",
                getattr(self.stats, f"{reason}_evictions") + 1)

    # -- cache bounds (TTL / capacity) ----------------------------------------
    def advance_clock(self, now: float) -> None:
        """Move the pool's clock forward (the scheduler calls this every
        round) and expire cached blocks older than ``cache_ttl_s``.  The LRU
        order equals park-time order, so expiry walks the front only."""
        if now > self._now:
            self._now = now
        ttl = self.cfg.cache_ttl_s
        if ttl is None:
            return
        while self._evictable:
            oldest = next(iter(self._evictable))
            if self._now - self._parked_at.get(oldest, self._now) <= ttl:
                break
            self._evict_one(reason="ttl")

    def _enforce_cache_capacity(self) -> None:
        cap = self.cfg.cache_max_blocks
        if cap is None:
            return
        while len(self._evictable) > cap:
            self._evict_one(reason="capacity")

    def _pop_block(self) -> int:
        if not self.free_blocks:
            self._evict_one()
        return self.free_blocks.pop()

    def allocate(self, req_id: int, new_tokens: int,
                 tenant: Optional[str] = None) -> List[int]:
        t = tenant if tenant is not None else self.tenant_of(req_id)
        if req_id not in self._reg:
            self._reg[req_id] = _Registration(tenant=t)
        need = self.blocks_needed(req_id, new_tokens)
        if need > self.allocatable_blocks():
            raise MemoryError(
                f"KV pool exhausted: need {need} blocks, have "
                f"{self.allocatable_blocks()} (free {len(self.free_blocks)} "
                f"+ evictable {len(self._evictable)})"
            )
        if need > self.quota_headroom_blocks(t):
            raise KVQuotaExceeded(
                f"tenant {t!r} KV quota exhausted: need {need} blocks, quota "
                f"{self._tenant_quota.get(t)}, used {self._tenant_used.get(t, 0)}"
            )
        got = [self._pop_block() for _ in range(need)]
        for bid in got:
            self._ref[bid] = 1
        self.tables.setdefault(req_id, []).extend(got)
        self.lens[req_id] = self.lens.get(req_id, 0) + new_tokens
        if need:
            self._tenant_used[t] = self._tenant_used.get(t, 0) + need
        self._seal(req_id)
        return got

    def _seal(self, req_id: int) -> None:
        """Content-address prompt blocks that just became full, making them
        matchable by future requests (while still referenced)."""
        if not self.cfg.enable_prefix_cache:
            return
        reg = self._reg.get(req_id)
        if reg is None or not reg.block_hashes:
            return
        bs = self.cfg.block_size
        table = self.tables.get(req_id, [])
        filled = self.lens.get(req_id, 0)
        n_sealable = min(len(reg.block_hashes), filled // bs, len(table))
        for i in range(reg.sealed, n_sealable):
            bid, h = table[i], reg.block_hashes[i]
            if h in self._cache_index:
                # identical content already addressable (shared or duplicate):
                # leave the index pointing at the first copy
                reg.sealed = i + 1
                continue
            self._cache_index[h] = bid
            self._hash_of[bid] = h
            reg.sealed = i + 1
            reg.newly_sealed.append((i, bid, i * bs, (i + 1) * bs))
            self.stats.sealed_blocks += 1

    def take_newly_sealed(self, req_id: int) -> List[Tuple[int, int, int, int]]:
        """Drain (block_index, block_id, start_tok, end_tok) records for
        blocks sealed since the last call — the engine captures their KV
        payloads from its slot cache."""
        reg = self._reg.get(req_id)
        if reg is None or not reg.newly_sealed:
            return []
        out, reg.newly_sealed = reg.newly_sealed, []
        return out

    # -- payloads (real-engine KV reuse) ---------------------------------------
    def store_payload(self, block_id: int, payload: object) -> None:
        if block_id in self._hash_of:      # only cache-addressable blocks
            self._payload[block_id] = payload

    def payload(self, block_id: int) -> Optional[object]:
        return self._payload.get(block_id)

    def release(self, req_id: int, *, keep_registration: bool = False) -> None:
        """Drop all of a request's references.  Idempotent.  Cached (hashed)
        blocks whose refcount reaches zero are parked in the eviction LRU;
        unhashed blocks return to the free list.  ``keep_registration=True``
        (preemption) retains tenant + prompt hashes for the recompute pass."""
        blocks = self.tables.pop(req_id, [])
        self.lens.pop(req_id, None)
        reg = self._reg.get(req_id)
        if blocks and reg is not None:
            used = self._tenant_used.get(reg.tenant, 0) - len(blocks)
            if used > 0:
                self._tenant_used[reg.tenant] = used
            else:
                self._tenant_used.pop(reg.tenant, None)
        for bid in blocks:
            ref = self._ref.get(bid, 0) - 1
            assert ref >= 0, f"double-free of block {bid}"
            if ref > 0:
                self._ref[bid] = ref
                continue
            h = self._hash_of.get(bid)
            if h is not None and self.cfg.enable_prefix_cache:
                self._ref[bid] = 0
                self._evictable[bid] = h       # most-recently used end
                self._evictable.move_to_end(bid)
                self._parked_at[bid] = self._now
                self._enforce_cache_capacity()
            else:
                self._ref.pop(bid, None)
                self._hash_of.pop(bid, None)
                self._payload.pop(bid, None)
                self.free_blocks.append(bid)
        if reg is not None:
            if keep_registration:
                reg.sealed = 0
                reg.newly_sealed = []
            else:
                self._reg.pop(req_id, None)

    # -- host staging tier ------------------------------------------------------
    def attach_host_tier(self, tier: HostTier) -> None:
        """Share one ``HostTier`` budget with other pools / the handoff
        store.  Must happen before anything is staged here (the private
        tier's charges cannot be migrated)."""
        assert self._host_charged == 0 and not self._swap, (
            "attach_host_tier after records were staged"
        )
        self.host = tier

    def host_bytes_for(self, tokens: int) -> int:
        """Bytes a staged record of this many tokens charges the host tier.
        INT8 staging halves the payload (the per-page scales are small
        against the page itself and are folded into the estimate)."""
        nb = tokens * self.cfg.bytes_per_token
        if self.cfg.host_kv_dtype == "int8":
            nb //= 2
        return nb

    def host_can_stage(self, tokens: int) -> bool:
        """True when a swap-out of this many tokens can be staged after
        evicting every one of THIS pool's own records if need be.  Bytes
        charged by co-tenants of a shared tier (other pools, the handoff
        store) are not evictable from here."""
        if self.host.max_bytes is None:
            return True
        nbytes = self.host_bytes_for(tokens)
        pinned = self.host.stats.resident_bytes - self._host_charged
        return nbytes <= self.host.max_bytes - pinned

    def _host_evict_oldest(self, cause: str) -> int:
        """Evict the oldest staged record (stage-time LRU) to make host
        room.  The evicted request is DEMOTED: its KV is gone from both
        tiers, so the scheduler folds it via ``Request.preempt()`` when it
        notices the record vanished — a recompute, never a leak.  Returns
        the demoted req_id."""
        assert self._swap, "host eviction from an empty staging store"
        req_id = next(iter(self._swap))
        rec = self._swap.pop(req_id)
        self.host.release(rec.nbytes)
        self._host_charged -= rec.nbytes
        self.host.note_eviction(cause)
        return req_id

    def _host_reserve(self, nbytes: int, *, cause: str = "swap") -> None:
        while not self.host.can_fit(nbytes) and self._swap:
            self._host_evict_oldest(cause)
        self.host.charge(nbytes)      # asserts fit: callers gate on
        self._host_charged += nbytes  # host_can_stage first

    def _host_release(self, rec: _SwapRecord) -> None:
        self.host.release(rec.nbytes)
        self._host_charged -= rec.nbytes

    # -- swap-out preemption (host staging) ------------------------------------
    def swap_out(self, req_id: int, *, ready: bool = False) -> _SwapRecord:
        """Move a request's KV accounting from its block table to a host-side
        staging record: device blocks are released (shared/hashed blocks
        follow the normal refcount/park path — the staged payload covers the
        FULL stored length, so a restore never depends on the cache), tenant
        quota is refunded, and the request becomes decode-resumable instead
        of prefill-restart.

        The record starts in ``SWAPPING`` (the engine's async device→host
        gather is in flight; ``finish_swap_out`` flips it) unless
        ``ready=True`` (accounting-only callers — the simulator — have no
        real copy to wait for).  ``reg.sealed`` is kept: the prompt is
        unchanged, so already-indexed prefix blocks stay valid; only
        ``newly_sealed`` capture records are dropped (their blocks are no
        longer engine-readable)."""
        table = self.tables.get(req_id)
        assert table, f"swap_out of req {req_id} with no blocks"
        assert req_id not in self._swap, f"req {req_id} already swapped"
        tokens = self.lens.get(req_id, 0)
        nbytes = self.host_bytes_for(tokens)
        # reserve host bytes FIRST (may demote older staged victims); the
        # new record is not in _swap yet, so it can never evict itself
        self._host_reserve(nbytes, cause="swap")
        rec = _SwapRecord(
            tokens=tokens,
            n_blocks=len(table),
            tenant=self.tenant_of(req_id),
            state=BlockState.SWAPPED_OUT if ready else BlockState.SWAPPING,
            nbytes=nbytes,
            quantized=self.cfg.host_kv_dtype == "int8",
        )
        reg = self._reg.get(req_id)
        sealed = reg.sealed if reg is not None else 0
        self.release(req_id, keep_registration=True)
        if reg is not None:
            reg.sealed = sealed          # prompt unchanged: hashes still valid
        self._swap[req_id] = rec
        self.stats.swap_outs += 1
        self.stats.swapped_out_tokens += tokens
        return rec

    def finish_swap_out(self, req_id: int, payload: object = None) -> None:
        """The async gather drained: attach the host payload and mark the
        record restorable (``SWAPPED_OUT``)."""
        rec = self._swap.get(req_id)
        assert rec is not None, f"finish_swap_out of unswapped req {req_id}"
        self.finalize_record(rec, payload)

    @staticmethod
    def finalize_record(rec: _SwapRecord, payload: object = None) -> None:
        """Finalize a staging record DIRECTLY, wherever it currently lives.
        Under handoff PREFETCH a SWAPPING record may already have been
        exported into the ``KVHandoffStore`` — or imported by a destination
        pool — before its gather drains; the source engine holds the record
        object and finalizes it here, and the destination's ``swap_ready``
        gate turns true the moment the payload is host-side."""
        if payload is not None:
            rec.payload = payload
        rec.state = BlockState.SWAPPED_OUT

    def swap_state(self, req_id: int) -> Optional[BlockState]:
        """``None`` when the request is not swapped (its blocks, if any, are
        RESIDENT); otherwise the staging record's lifecycle state."""
        rec = self._swap.get(req_id)
        return rec.state if rec is not None else None

    def swap_ready(self, req_id: int) -> bool:
        rec = self._swap.get(req_id)
        return rec is not None and rec.state == BlockState.SWAPPED_OUT

    def swap_tokens(self, req_id: int) -> int:
        rec = self._swap.get(req_id)
        return rec.tokens if rec is not None else 0

    def swapped_requests(self) -> List[int]:
        return list(self._swap)

    def can_swap_in(self, req_id: int, tenant: Optional[str] = None) -> bool:
        """True when the staged payload is host-resident AND the pool + the
        tenant's quota can back the restore right now."""
        rec = self._swap.get(req_id)
        if rec is None or rec.state != BlockState.SWAPPED_OUT:
            return False
        need = rec.n_blocks - rec.tail_start_blocks
        if need > self.allocatable_blocks():
            return False
        return need <= self.quota_headroom_blocks(
            tenant or self.tenant_of(req_id)
        )

    def swap_in(self, req_id: int,
                tenant: Optional[str] = None) -> Tuple[List[int], object]:
        """Restore a swapped-out request: allocate fresh device blocks
        (re-charging the tenant's quota), rebuild its table/length, drop the
        staging record, and return ``(new_block_ids, payload)`` so the engine
        can scatter the staged K/V into the new pages.  Restored blocks are
        private (refcount 1, not re-sealed): already-indexed prefix blocks
        keep pointing at their original — possibly still cached — copies."""
        rec = self._swap.get(req_id)
        assert rec is not None, f"swap_in of unswapped req {req_id}"
        assert rec.state == BlockState.SWAPPED_OUT, (
            f"req {req_id} swap still in flight ({rec.state})"
        )
        assert rec.tail_start_blocks == 0, (
            f"req {req_id} shrunk to tail: restore via swap_in_tail"
        )
        t = tenant if tenant is not None else rec.tenant
        if rec.n_blocks > self.allocatable_blocks():
            raise MemoryError(
                f"KV pool exhausted on swap-in: need {rec.n_blocks} blocks, "
                f"have {self.allocatable_blocks()}"
            )
        if rec.n_blocks > self.quota_headroom_blocks(t):
            raise KVQuotaExceeded(
                f"tenant {t!r} KV quota exhausted on swap-in: need "
                f"{rec.n_blocks} blocks, quota {self._tenant_quota.get(t)}, "
                f"used {self._tenant_used.get(t, 0)}"
            )
        got = [self._pop_block() for _ in range(rec.n_blocks)]
        for bid in got:
            self._ref[bid] = 1
        assert not self.tables.get(req_id), "swap_in over a live table"
        self.tables[req_id] = list(got)
        self.lens[req_id] = rec.tokens
        self._tenant_used[t] = self._tenant_used.get(t, 0) + rec.n_blocks
        self._swap.pop(req_id)
        self._host_release(rec)
        self.stats.swap_ins += 1
        self.stats.swapped_in_tokens += rec.tokens
        if rec.seal_on_restore:
            # imported handoff: content-address the restored prompt blocks so
            # this pool's prefix index reflects what is now resident here —
            # placement locality probes rely on it.  (No payload marker is
            # stored: engine-side prefix matches require one, so a restore
            # can never silently alias an imported block.)
            self._seal(req_id)
        return got, rec.payload

    def drop_swap(self, req_id: int) -> None:
        """Discard a staging record without restoring (finished/cancelled
        victim, or a caller falling back to recompute).  Idempotent."""
        rec = self._swap.pop(req_id, None)
        if rec is not None:
            self._host_release(rec)

    # -- partial swap-in (decode-hot tail) -------------------------------------
    def swap_tail_start(self, req_id: int) -> int:
        """0 for a whole-record stage; otherwise the block index the staged
        payload starts at (the prefix before it must be re-prefilled)."""
        rec = self._swap.get(req_id)
        return rec.tail_start_blocks if rec is not None else 0

    def shrink_swap_to_tail(self, req_id: int, tail_start_blocks: int,
                            payload_slicer=None) -> None:
        """Shrink a staged record to its decode-hot tail: blocks
        ``[tail_start_blocks, n_blocks)`` stay staged, the prefix bytes are
        released from the host tier, and the owning request — which the
        caller has folded via ``Request.preempt()`` — re-prefills the
        prefix chunk-by-chunk before ``swap_in_tail`` appends the tail.
        ``payload_slicer(payload, tail_start_blocks, n_blocks)`` trims the
        engine arrays (accounting-only users pass None)."""
        rec = self._swap.get(req_id)
        assert rec is not None, f"shrink of unswapped req {req_id}"
        assert rec.state == BlockState.SWAPPED_OUT, (
            f"req {req_id} shrink while swap in flight ({rec.state})"
        )
        assert rec.tail_start_blocks == 0, f"req {req_id} already shrunk"
        assert 0 < tail_start_blocks < rec.n_blocks, (
            f"tail split {tail_start_blocks} outside (0, {rec.n_blocks})"
        )
        freed = min(
            rec.nbytes,
            self.host_bytes_for(tail_start_blocks * self.cfg.block_size),
        )
        self.host.release(freed)
        self._host_charged -= freed
        rec.nbytes -= freed
        rec.tail_start_blocks = tail_start_blocks
        if payload_slicer is not None and rec.payload is not None:
            rec.payload = payload_slicer(
                rec.payload, tail_start_blocks, rec.n_blocks)

    def swap_in_tail(self, req_id: int,
                     tenant: Optional[str] = None) -> Tuple[List[int], object]:
        """Complete a partial restore: the request has re-prefilled exactly
        the evicted prefix (``tail_start_blocks`` full blocks), so append
        fresh device blocks for the staged tail and hand back the trimmed
        payload for the engine scatter.  The request's stored length jumps
        to the record's full length — positions align because the prefix
        re-prefill was clipped to the block-exact split point."""
        rec = self._swap.get(req_id)
        assert rec is not None, f"swap_in_tail of unswapped req {req_id}"
        assert rec.state == BlockState.SWAPPED_OUT, (
            f"req {req_id} swap still in flight ({rec.state})"
        )
        d = rec.tail_start_blocks
        assert d > 0, f"req {req_id} not shrunk: restore via swap_in"
        bs = self.cfg.block_size
        table = self.tables.get(req_id, [])
        assert len(table) == d and self.lens.get(req_id, 0) == d * bs, (
            f"req {req_id} tail restore off the split: holds {len(table)} "
            f"blocks / {self.lens.get(req_id, 0)} tokens, split at {d} blocks"
        )
        need = rec.n_blocks - d
        t = tenant if tenant is not None else rec.tenant
        if need > self.allocatable_blocks():
            raise MemoryError(
                f"KV pool exhausted on tail swap-in: need {need} blocks, "
                f"have {self.allocatable_blocks()}"
            )
        if need > self.quota_headroom_blocks(t):
            raise KVQuotaExceeded(
                f"tenant {t!r} KV quota exhausted on tail swap-in: need "
                f"{need} blocks, quota {self._tenant_quota.get(t)}, "
                f"used {self._tenant_used.get(t, 0)}"
            )
        got = [self._pop_block() for _ in range(need)]
        for bid in got:
            self._ref[bid] = 1
        self.tables[req_id].extend(got)
        self.lens[req_id] = rec.tokens
        self._tenant_used[t] = self._tenant_used.get(t, 0) + need
        self._swap.pop(req_id)
        self._host_release(rec)
        tail_tokens = rec.tokens - d * bs
        self.stats.swap_ins += 1
        self.stats.partial_swap_ins += 1
        self.stats.swapped_in_tokens += tail_tokens
        self.stats.tail_tokens_restored += tail_tokens
        return got, rec.payload

    # -- cross-replica KV handoff (disaggregated prefill/decode pools) ---------
    def export_swap(self, req_id: int, *, allow_inflight: bool = False
                    ) -> Tuple[_SwapRecord, "_Registration"]:
        """Detach a host-staged record from this pool for another pool to
        ``import_swap``: the disaggregated handoff path.  By default the
        record must be SWAPPED_OUT (payload host-resident); the PREFETCH
        path passes ``allow_inflight=True`` to move a still-SWAPPING record
        early — the source engine holds the record object and attaches the
        payload via ``finalize_record`` when the gather drains, and the
        destination's restore stays gated on ``swap_ready``.  Either way the
        request's registration leaves with the record, so this pool retains
        no trace of the request."""
        rec = self._swap.get(req_id)
        assert rec is not None, f"export_swap of unswapped req {req_id}"
        assert allow_inflight or rec.state == BlockState.SWAPPED_OUT, (
            f"req {req_id} export while swap in flight ({rec.state})"
        )
        assert not self.tables.get(req_id), (
            f"req {req_id} exported while holding a live table"
        )
        del self._swap[req_id]           # validate first: a rejected export
        self._host_release(rec)          # the handoff store re-charges the
        reg = self._reg.pop(req_id, None)       # (shared) tier on put; a
        self.stats.handoff_exports += 1         # rejected export leaves the
        return rec, reg                         # pool intact

    def import_swap(self, req_id: int, rec: _SwapRecord,
                    reg: Optional["_Registration"] = None) -> None:
        """Adopt a record exported from another pool's ``export_swap``: it
        lands in this pool's staging store exactly as a local swap-out would
        have, so the ordinary ``swap_in``/restore path resumes the request
        decode-only — zero re-prefilled tokens.  A PREFETCHED record may
        still be SWAPPING (source gather in flight): it is adoptable because
        every restore path gates on ``swap_ready``, which turns true only
        when the source engine finalizes the record.  The source
        registration (tenant + prompt block hashes) carries over so quota
        charging and prefix sealing work on this side of the link."""
        assert req_id not in self._swap, f"req {req_id} already staged here"
        assert not self.tables.get(req_id), (
            f"req {req_id} imported over a live table"
        )
        if reg is not None:
            fresh = _Registration(
                tenant=reg.tenant, prompt_len=reg.prompt_len,
                block_hashes=list(reg.block_hashes),
            )
            self._reg[req_id] = fresh
        # the adopted record charges THIS pool's host tier (with a shared
        # tier the store's take() released the same bytes, so it fits by
        # construction; a private tier may demote older local records)
        self._host_reserve(rec.nbytes, cause="handoff")
        rec.seal_on_restore = self.cfg.enable_prefix_cache
        self._swap[req_id] = rec
        self.stats.handoff_imports += 1

    def probe_prefix(self, prompt_tokens) -> int:
        """Non-acquiring placement probe: how many leading prompt tokens are
        content-addressed on THIS pool right now (cached or still referenced).
        Unlike ``match_prefix`` nothing is refcounted, charged, or moved in
        the LRU — routers call this per candidate replica to score KV
        locality before deciding where a request's decode should land."""
        if not self.cfg.enable_prefix_cache or prompt_tokens is None:
            return 0
        matched = 0
        for h in self._chain_hashes(prompt_tokens, self.cfg.block_size):
            if h not in self._cache_index:
                break
            matched += 1
        return matched * self.cfg.block_size

    def resident_tokens(self, req_id: int) -> int:
        """Tokens of this request's context that are already materialized on
        (or one restore round away from) this pool: blocks it holds, a
        host-staged swap record, or — for a cold request — its longest
        indexed prompt prefix.  The scheduler's cache-aware aging credit
        scores queue candidates with this."""
        held = self.lens.get(req_id, 0)
        rec = self._swap.get(req_id)
        if rec is not None:
            # quantized-resident counts in full: an int8 page restores a
            # usable token exactly like an fp one, so the cache-aware aging
            # credit (and SLO victim ranking through it) prices both tiers
            # the same.  A tail-shrunk record contributes its staged tail on
            # top of the re-prefilled prefix the request already holds.
            if rec.tail_start_blocks > 0:
                return held + rec.tokens - rec.tail_start_blocks * self.cfg.block_size
            return rec.tokens
        if held:
            return held
        reg = self._reg.get(req_id)
        if reg is None or not reg.block_hashes:
            return 0
        matched = 0
        for h in reg.block_hashes:
            if h not in self._cache_index:
                break
            matched += 1
        return matched * self.cfg.block_size

    # -- accounting (LPRS features) --------------------------------------------
    @property
    def used_blocks(self) -> int:
        """Blocks pinned by live references (evictable cache not counted:
        it is reclaimable on demand, like the free list)."""
        return self.cfg.n_blocks - len(self.free_blocks) - len(self._evictable)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained only by the prefix cache."""
        return len(self._evictable)

    @property
    def swapped_out_blocks(self) -> int:
        """Device blocks the currently-swapped requests will re-allocate on
        restore (their data is host-side; no device blocks are pinned now).
        Tail-shrunk records only re-allocate their staged tail."""
        return sum(rec.n_blocks - rec.tail_start_blocks
                   for rec in self._swap.values())

    @property
    def used_mb(self) -> float:
        return self.used_blocks * self.cfg.block_size * self.cfg.bytes_per_token / 2**20

    @property
    def free_mb(self) -> float:
        return self.allocatable_blocks() * self.cfg.block_size * self.cfg.bytes_per_token / 2**20

    @property
    def allocated_mb(self) -> float:
        return self.cfg.param_mb + self.used_mb

    @property
    def reserved_mb(self) -> float:
        return self.cfg.hbm_capacity_mb

    def utilization(self) -> float:
        return self.used_blocks / max(self.cfg.n_blocks, 1)

    # -- invariants (property tests) -------------------------------------------
    def check_invariants(self) -> None:
        referenced = {bid for t in self.tables.values() for bid in t}
        assert referenced.isdisjoint(self.free_blocks), "table entry on free list"
        assert referenced.isdisjoint(self._evictable), "table entry marked evictable"
        n_accounted = len(self.free_blocks) + len(self._evictable) + len(referenced)
        assert n_accounted == self.cfg.n_blocks, (
            f"block conservation violated: free {len(self.free_blocks)} + "
            f"evictable {len(self._evictable)} + referenced {len(referenced)} "
            f"!= {self.cfg.n_blocks}"
        )
        for bid, ref in self._ref.items():
            assert ref >= 0, f"negative refcount on block {bid}"
        for bid in referenced:
            holders = sum(1 for t in self.tables.values() if bid in t)
            assert self._ref.get(bid, 0) == holders, (
                f"block {bid}: refcount {self._ref.get(bid, 0)} != holders {holders}"
            )
        # block-table invariants (the paged engine addresses physical pages
        # straight through these tables):
        bs = self.cfg.block_size
        for req_id, table in self.tables.items():
            # every live token maps into exactly one physical block slot
            assert self.lens.get(req_id, 0) <= len(table) * bs, (
                f"req {req_id}: {self.lens.get(req_id, 0)} tokens live in "
                f"{len(table)} blocks of {bs}"
            )
            # a table never references the same physical block twice
            assert len(set(table)) == len(table), (
                f"req {req_id}: duplicate physical block in table {table}"
            )
        for bid in referenced:
            # a physical block appears in multiple live tables only while
            # sealed (content-addressed prefix sharing); private blocks are
            # exclusively owned
            if self._ref.get(bid, 0) > 1:
                assert bid in self._hash_of, (
                    f"block {bid} shared by {self._ref[bid]} tables but not sealed"
                )
        # swap-staging invariants: a request's tokens live in exactly one of
        # {block table, staging entry} — never both (a tail-shrunk record
        # splits block-exactly: the table holds the re-prefilled prefix, the
        # record the staged tail, disjoint by position); a staged entry
        # always carries real tokens and a positive restore size
        for req_id, rec in self._swap.items():
            if rec.tail_start_blocks > 0:
                assert 0 < rec.tail_start_blocks < rec.n_blocks, (
                    f"req {req_id} tail split {rec.tail_start_blocks} outside "
                    f"(0, {rec.n_blocks})"
                )
                assert len(self.tables.get(req_id, ())) <= rec.tail_start_blocks, (
                    f"req {req_id} re-prefilled past the tail split: "
                    f"{len(self.tables.get(req_id, ()))} blocks held, "
                    f"split at {rec.tail_start_blocks}"
                )
                assert self.lens.get(req_id, 0) <= rec.tail_start_blocks * bs, (
                    f"req {req_id} prefix length {self.lens.get(req_id, 0)} "
                    f"past the tail split token {rec.tail_start_blocks * bs}"
                )
            else:
                assert not self.tables.get(req_id), (
                    f"req {req_id} swapped AND holding a live table"
                )
                assert req_id not in self.lens, (
                    f"req {req_id} swapped AND holding a device length"
                )
            assert rec.tokens > 0 and rec.n_blocks > 0, (
                f"req {req_id} empty swap record {rec}"
            )
            assert rec.tokens <= rec.n_blocks * bs, (
                f"req {req_id} swap record overfull: {rec.tokens} tokens in "
                f"{rec.n_blocks} blocks of {bs}"
            )
            assert rec.nbytes >= 0, f"req {req_id} negative staged bytes"
        # host-tier ledger: this pool's records account exactly for its
        # charges; the tier's own ledger closes (and respects the budget)
        assert self._host_charged == sum(
            rec.nbytes for rec in self._swap.values()
        ), (
            f"host charge drift: pool holds {self._host_charged} bytes, "
            f"records sum to {sum(r.nbytes for r in self._swap.values())}"
        )
        self.host.check_invariants()
        assert self._host_charged <= self.host.stats.resident_bytes, (
            "pool charged more than the tier holds"
        )
        # cache-bound invariants: parked set == evictable set; capacity holds
        assert set(self._parked_at) == set(self._evictable), "stamp/LRU drift"
        if self.cfg.cache_max_blocks is not None:
            assert len(self._evictable) <= self.cfg.cache_max_blocks
        by_tenant: Dict[str, int] = {}
        for req_id, table in self.tables.items():
            t = self.tenant_of(req_id)
            by_tenant[t] = by_tenant.get(t, 0) + len(table)
        for t, n in by_tenant.items():
            assert self._tenant_used.get(t, 0) == n, (
                f"tenant {t!r} charge {self._tenant_used.get(t, 0)} != held {n}"
            )


def pool_for_model(cfg_model, *, n_blocks: int = 8192, block_size: int = 16,
                   hbm_mb: float = 16 * 1024.0,
                   enable_prefix_cache: bool = False,
                   cache_max_blocks: Optional[int] = None,
                   cache_ttl_s: Optional[float] = None,
                   host_max_bytes: Optional[int] = None,
                   host_kv_dtype: str = "auto") -> KVBlockPool:
    """Size bytes_per_token from a ModelConfig (attention layers only)."""
    hd = cfg_model.resolved_head_dim
    if cfg_model.attn_every:
        n_attn = sum(1 for l in range(cfg_model.n_layers) if l % cfg_model.attn_every == 0)
    elif cfg_model.family == "ssm":
        n_attn = 0
    else:
        n_attn = cfg_model.n_layers
    bpt = 2 * n_attn * cfg_model.n_kv_heads * hd * 2  # k+v, bf16
    param_mb = cfg_model.param_count() * 2 / 2**20
    return KVBlockPool(
        KVPoolConfig(
            n_blocks=n_blocks,
            block_size=block_size,
            bytes_per_token=max(bpt, 2),
            hbm_capacity_mb=hbm_mb,
            param_mb=param_mb,
            enable_prefix_cache=enable_prefix_cache,
            cache_max_blocks=cache_max_blocks,
            cache_ttl_s=cache_ttl_s,
            host_max_bytes=host_max_bytes,
            host_kv_dtype=host_kv_dtype,
        )
    )
