"""Execution-time cost model for the discrete-event simulator.

Mirrors Vidur's [17] approach: per-round latency is a structured function of
batch composition, fitted/parameterized per (model, hardware).  The simulator
uses it as ground truth (with multiplicative noise); the LPRS predictor is
trained on (features, latency) samples it generates — exactly the paper's
offline profiling pipeline with the physical GPU swapped for a calibrated
model.

The functional form captures the paper's observations:
  t = c0                              fixed launch/sync overhead
    + c_prefill * prefill_tokens      compute-bound prefill
    + c_attn * sum_i chunk_i*ctx_i    prefill attention vs existing context
    + c_decode * decode_tokens        memory-bound decode (weight streaming)
    + c_ctx * sum_decode_context      KV streaming
    + c_seq * n_seqs                  per-sequence bookkeeping
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.scheduler import ScheduledBatch


@dataclass(frozen=True)
class CostModelConfig:
    c0_ms: float = 2.0
    c_prefill_ms: float = 0.045       # per prefill token
    c_attn_ms: float = 4e-6           # per (chunk token x context token)
    c_decode_ms: float = 0.10         # per decode token
    c_ctx_ms: float = 3.5e-5          # per decode context token
    c_seq_ms: float = 0.08            # per batched sequence
    # prefill/decode interference: mixed rounds pay a superlinear penalty of
    # (prefill tokens x total decode context) — compute-phase prefill evicts
    # the decode working set (Sarathi §2's observation; why identical token
    # budgets cost different wall time, the premise of LPRS §3.2)
    c_mix_ms: float = 2e-7            # per (prefill token x decode ctx token)
    # swap-out preemption: device<->host KV migration over the host link
    # (~20 GB/s effective PCIe4 => ~0.05 ms/MB) plus a fixed per-transfer
    # launch cost.  Used both to price swap rounds in the simulator and to
    # choose swap-vs-recompute per victim (bytes moved vs FLOPs recomputed).
    c_swap_ms_per_mb: float = 0.05
    c_swap_fixed_ms: float = 0.2
    noise_std: float = 0.02           # multiplicative log-normal noise
    seed: int = 0

    @staticmethod
    def for_model(name: str = "qwen3-8b") -> "CostModelConfig":
        """Rough per-model scalings (relative compute cost)."""
        scale = {
            "qwen3-8b": 1.0,
            "llama3.2-1b": 0.18,
            "qwen1.5-0.5b": 0.10,
            "mixtral-8x7b": 1.6,
        }.get(name, 1.0)
        base = CostModelConfig()
        return CostModelConfig(
            c0_ms=base.c0_ms,
            c_prefill_ms=base.c_prefill_ms * scale,
            c_attn_ms=base.c_attn_ms * scale,
            c_decode_ms=base.c_decode_ms * scale,
            c_ctx_ms=base.c_ctx_ms * scale,
            c_seq_ms=base.c_seq_ms,
        )


class CostModel:
    def __init__(self, cfg: Optional[CostModelConfig] = None):
        self.cfg = cfg or CostModelConfig()
        self._rng = np.random.default_rng(self.cfg.seed)

    # -- preemption-mode decision (swap bytes vs recompute FLOPs) -------------
    def swap_cost_ms(self, n_tokens: int, bytes_per_token: int) -> float:
        """One full swap cycle for ``n_tokens`` of KV: device→host at
        eviction plus host→device at restore (2x the bytes), each paying the
        fixed transfer-launch cost."""
        mb = n_tokens * max(bytes_per_token, 0) / 2**20
        return 2 * (self.cfg.c_swap_fixed_ms + self.cfg.c_swap_ms_per_mb * mb)

    def recompute_cost_ms(self, n_tokens: int) -> float:
        """Re-prefilling ``n_tokens`` of context from scratch: linear prefill
        compute plus the quadratic causal-attention term (each token attends
        to the context before it — n²/2 chunk×context products)."""
        c = self.cfg
        return (
            c.c_prefill_ms * n_tokens
            + c.c_attn_ms * n_tokens * n_tokens / 2.0
        )

    def batch_latency_ms(self, batch: ScheduledBatch, *, noisy: bool = True) -> float:
        c = self.cfg
        prefill_tokens = batch.prefill_tokens
        attn_work = sum(
            chunk * max(req.prefill_done, 1) for req, chunk in batch.prefill_chunks
        )
        decode_tokens = batch.decode_tokens
        sum_ctx = sum(r.context_len for r in batch.decode_reqs)
        t = (
            c.c0_ms
            + c.c_prefill_ms * prefill_tokens
            + c.c_attn_ms * attn_work
            + c.c_decode_ms * decode_tokens
            + c.c_ctx_ms * sum_ctx
            + c.c_seq_ms * batch.n_seqs
            + c.c_mix_ms * prefill_tokens * sum_ctx
        )
        # swap traffic this round (simulator: synchronous transfer; the real
        # engine overlaps it on the async drain path, so this is conservative)
        swap_mb = batch.swap_out_mb + batch.swap_in_mb
        if swap_mb > 0:
            n_xfers = len(batch.swapped_out) + len(batch.restored)
            t += c.c_swap_fixed_ms * n_xfers + c.c_swap_ms_per_mb * swap_mb
        if noisy and c.noise_std > 0:
            t *= float(self._rng.lognormal(0.0, c.noise_std))
        return t
