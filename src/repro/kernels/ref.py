"""Pure-jnp oracles for every Pallas kernel (exact f32 math, no tiling).

These are the correctness references for tests/test_kernels.py shape/dtype
sweeps and the CPU execution path of the engine (``use_pallas=False``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def chunked_prefill_attention_ref(
    q,            # (B, Sq, Hq, hd)   the prefill chunk's queries
    k_cache,      # (B, Skv, Hkv, hd) prefix KV incl. the chunk's own K
    v_cache,      # (B, Skv, Hkv, hd)
    kv_lens,      # (B,) valid KV length (prefix + chunk)
    q_offset,     # (B,) absolute position of q[:, 0] (= prefix length)
):
    """Chunk of queries attends to (prefix ‖ itself) with a causal offset.

    Query i (absolute pos q_offset + i) sees key j iff j <= q_offset + i and
    j < kv_lens.  All math in f32.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(hd))
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    qf = qf.reshape(B, Sq, Hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    q_pos = q_offset[:, None] + jnp.arange(Sq)[None, :]          # (B, Sq)
    k_pos = jnp.arange(Skv)[None, :]                             # (1, Skv)
    mask = (k_pos[:, None, :] <= q_pos[:, :, None]) & (
        k_pos[:, None, :] < kv_lens[:, None, None]
    )                                                            # (B, Sq, Skv)
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def decode_attention_ref(
    q,            # (B, Hq, hd)   one query token per sequence
    k_cache,      # (B, S, Hkv, hd)
    v_cache,      # (B, S, Hkv, hd)
    kv_lens,      # (B,) valid lengths
):
    """Single-token flash-decode oracle: full softmax over the valid cache."""
    B, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(hd))
    qf = qf.reshape(B, Hkv, g, hd)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    mask = jnp.arange(S)[None, :] < kv_lens[:, None]             # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


def gather_pages(pages, block_tables):
    """Materialize per-sequence dense K/V from a physical page pool.

    pages: (n_pages, page_size, H, hd); block_tables: (B, max_pages) int32
    -> (B, max_pages * page_size, H, hd).  Padding table entries may point at
    any valid page: positions past ``kv_lens`` are masked by the caller.
    """
    B, P = block_tables.shape
    g = pages[block_tables]                      # (B, P, ps, H, hd)
    return g.reshape(B, P * pages.shape[1], *pages.shape[2:])


def paged_prefill_attention_ref(
    q,              # (B, Sq, Hq, hd)
    k_pages,        # (n_pages, page_size, Hkv, hd)
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32
    kv_lens,        # (B,) valid KV length (prefix + chunk)
    q_offset,       # (B,) absolute position of q[:, 0]
):
    """Paged chunked-prefill oracle: gather the block table into a dense
    cache, then the exact dense computation (page indirection must be pure
    data movement — the math is identical)."""
    return chunked_prefill_attention_ref(
        q, gather_pages(k_pages, block_tables), gather_pages(v_pages, block_tables),
        kv_lens, q_offset,
    )


def paged_decode_attention_ref(
    q,              # (B, Hq, hd)
    k_pages,        # (n_pages, page_size, Hkv, hd)
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32
    kv_lens,        # (B,)
):
    """Paged flash-decode oracle via dense gather."""
    return decode_attention_ref(
        q, gather_pages(k_pages, block_tables), gather_pages(v_pages, block_tables),
        kv_lens,
    )


def split_fused_pages(kv_pages):
    """Un-interleave a fused head-interleaved pool: head axis
    ``[K0,V0,K1,V1,...]`` -> split ``(k_pages, v_pages)`` views.

    kv_pages: (n_pages, page_size, 2*Hkv, hd) -> two
    (n_pages, page_size, Hkv, hd) tensors.  The fused layout must be pure
    data movement, so every fused oracle is the split oracle over these
    strided views.
    """
    return kv_pages[:, :, 0::2], kv_pages[:, :, 1::2]


def fuse_pages(k_pages, v_pages):
    """Inverse of ``split_fused_pages``: interleave split K/V pools onto the
    head axis (``(n_pages, ps, Hkv, hd)`` x2 -> ``(n_pages, ps, 2*Hkv, hd)``)."""
    n_pages, ps, Hkv, hd = k_pages.shape
    return jnp.stack([k_pages, v_pages], axis=3).reshape(n_pages, ps, 2 * Hkv, hd)


def paged_prefill_attention_fused_ref(q, kv_pages, block_tables, kv_lens,
                                      q_offset):
    """Fused-layout paged chunked-prefill oracle (un-interleave + split oracle)."""
    k_pages, v_pages = split_fused_pages(kv_pages)
    return paged_prefill_attention_ref(
        q, k_pages, v_pages, block_tables, kv_lens, q_offset
    )


def paged_decode_attention_fused_ref(q, kv_pages, block_tables, kv_lens):
    """Fused-layout paged flash-decode oracle."""
    k_pages, v_pages = split_fused_pages(kv_pages)
    return paged_decode_attention_ref(q, k_pages, v_pages, block_tables, kv_lens)


def quantize_pages(pages):
    """INT8-quantize staged KV pages with per-page-per-head absmax scales.

    ``pages``: ``(L, n_pages, page_size, H, hd)`` (H is ``Hkv`` for the
    split layout, ``2*Hkv`` for the fused head-interleaved one — per-head
    scales keep K and V independently scaled either way).  Returns
    ``(q, scales)`` with ``q`` int8 of the same shape and ``scales`` f32
    ``(L, n_pages, 1, H, 1)`` sized so ``q * scales`` broadcasts back.

    The scale is ``absmax / 127`` per (layer, page, head): symmetric, no
    zero point — KV activations are roughly zero-centered, and symmetry
    keeps the dequant a single multiply in the scatter kernel.  An all-zero
    page quantizes to zeros with scale 0 (guarded against 0/0).
    """
    x = pages.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(2, 4), keepdims=True)
    scales = amax / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_pages(q, scales, dtype):
    """Inverse of ``quantize_pages``: ``q * scales`` cast to the pool dtype."""
    return (q.astype(jnp.float32) * scales).astype(dtype)


def fused_swiglu_ref(x, w_gate, w_up, w_down):
    """x: (M, D); w_gate/w_up: (D, F); w_down: (F, D) -> (M, D), f32 math."""
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ w_gate.astype(jnp.float32)) * (
        xf @ w_up.astype(jnp.float32)
    )
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)
