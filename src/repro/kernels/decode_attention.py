"""Pallas TPU kernel: flash-decode attention (single-token GQA decode).

decode_32k / long_500k shapes are HBM-bound KV streaming: one new query
token per sequence attends to a long cache.  The kernel streams K/V blocks
once and keeps the online-softmax state (m, l, acc) in f32 VMEM scratch.

TPU adaptation:
  * all `group` query heads of one KV head are processed together as the
    (group, hd) left operand — an MXU-friendly tall-skinny matmul against
    each (blk_k, hd) KV tile (the GPU analogue uses warp-level broadcast;
    on TPU the group dimension rides the sublane axis).
  * kv_lens via scalar prefetch: tiles past the valid length are skipped
    entirely, so decoding a 1k-token sequence in a 32k cache touches only
    1k tokens of HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK_K = 256

NEG_INF = -1e30


def _decode_kernel(
    kv_len_ref,     # (B,) scalar prefetch
    q_ref,          # (group, hd)
    k_ref,          # (blk_k, hd)
    v_ref,          # (blk_k, hd)
    o_ref,          # (group, hd)
    m_ref,          # (group,) f32
    l_ref,          # (group,) f32
    acc_ref,        # (group, hd) f32
    *,
    block_k: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    kv_i = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kv_len_ref[b]
    k_pos = kv_i * block_k + jax.lax.iota(jnp.int32, block_k)

    @pl.when(k_pos[0] < kv_len)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * sm_scale        # (g, hd)
        k = k_ref[...].astype(jnp.float32)                   # (blk_k, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # (g, blk_k)
        mask = k_pos[None, :] < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q,            # (B, Hq, hd) one token per sequence
    k_cache,      # (B, S, Hkv, hd)
    v_cache,      # (B, S, Hkv, hd)
    kv_lens,      # (B,) int32
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    B, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv

    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)

    grid = (B, Hkv, S // block_k)

    kernel = functools.partial(
        _decode_kernel, block_k=block_k, sm_scale=1.0 / math.sqrt(hd)
    )

    q_g = q.reshape(B, Hkv, group, hd)
    k_t = k_cache.transpose(0, 2, 1, 3)    # (B, Hkv, S, hd)
    v_t = v_cache.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, group, hd), lambda b, h, ki, *_: (b, h, 0, 0)
                ),
                pl.BlockSpec(
                    (None, None, block_k, hd), lambda b, h, ki, *_: (b, h, ki, 0)
                ),
                pl.BlockSpec(
                    (None, None, block_k, hd), lambda b, h, ki, *_: (b, h, ki, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (None, None, group, hd), lambda b, h, ki, *_: (b, h, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        interpret=interpret,
    )(kv_lens.astype(jnp.int32), q_g, k_t, v_t)

    return out.reshape(B, Hq, hd)
