"""Pallas TPU kernels: KV page swap gather/scatter (KV migration).

Two subsystems move KV pages between device HBM and a host-side staging
buffer through these kernels: swap-out preemption (stage a victim's pages
instead of discarding them for recompute) and disaggregated prefill/decode
serving (export a finished prefill's KV from a prefill-pool replica, through
the host handoff store, into a decode-pool replica — gather on the source
device, scatter on the destination).  Either way the device half of the move
is pure data movement over the paged layout:

* ``swap_gather_pages`` — collect a victim's scattered physical pages into
  ONE contiguous staging tensor ``(L, n_pages, page_size, Hkv, hd)``; the
  engine starts ``copy_to_host_async`` on the result, so the host transfer
  is a single dense DMA rather than ``n_pages`` strided ones.
* ``swap_scatter_pages`` — the inverse: write a restored staging tensor into
  freshly allocated physical pages (aliased in place: the page pool is
  donated, no second copy of HBM is materialized).

Both ride the same scalar-prefetched page-id indirection as the paged
attention kernels: ids land in SMEM before the body runs, each grid step
moves one ``(page_size, Hkv, hd)`` page with one async local copy.  Page-id
lists are padded to power-of-two buckets by the caller (gather pads with the
sink page — garbage rows are sliced off host-side; scatter pads with the
sink page — duplicate writes land in the never-read sink).

The pure-jnp oracles (``use_pallas=False``) are the A/B reference: fancy
indexing for the gather, ``.at[].set`` for the scatter.  On CPU the kernels
run in interpret mode (correctness, not speed); on TPU the same calls
compile to Mosaic.

The INT8 host-tier variants (``*_q8``) fuse the quantization into the same
data movement: the gather DMAs each page into VMEM scratch, computes a
per-(layer, page, head) absmax scale on the fly, and writes an int8 page
plus its scales; the scatter dequantizes in VMEM before the async copy into
the (donated) physical pool.  The host round-trip then moves ~half the
bytes, and the device pool never sees a quantized value.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import dequantize_pages, quantize_pages


def _gather_kernel(ids_ref, pages_ref, out_ref, sem):
    l = pl.program_id(0)
    i = pl.program_id(1)
    pid = ids_ref[i]
    cp = pltpu.make_async_copy(pages_ref.at[l, pid], out_ref.at[0, 0], sem)
    cp.start()
    cp.wait()


def _scatter_kernel(ids_ref, staged_ref, pages_in_ref, pages_out_ref, sem):
    del pages_in_ref                   # aliased with pages_out_ref (in-place)
    l = pl.program_id(0)
    i = pl.program_id(1)
    pid = ids_ref[i]
    cp = pltpu.make_async_copy(staged_ref.at[0, 0], pages_out_ref.at[l, pid], sem)
    cp.start()
    cp.wait()


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def swap_gather_pages(pages, ids, *, use_pallas: bool = False,
                      interpret: bool = True):
    """Gather ``pages[:, ids]`` into a contiguous staging tensor.

    ``pages``: ``(L, n_phys, page_size, Hkv, hd)`` physical pool;
    ``ids``: ``(n,)`` int32 page ids (padded entries point at the sink page —
    the caller slices the staging tensor down to the real page count after
    the host copy drains).  Returns ``(L, n, page_size, Hkv, hd)``.
    """
    if not use_pallas:
        return pages[:, ids]
    L = pages.shape[0]
    n = ids.shape[0]
    blk = (1, 1) + pages.shape[2:]
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L, n),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec(blk, lambda l, i, ids: (l, i, 0, 0, 0)),
            scratch_shapes=[pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct((L, n) + pages.shape[2:], pages.dtype),
        interpret=interpret,
    )(ids, pages)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"),
                   donate_argnums=(0,))
def swap_scatter_pages(pages, ids, staged, *, use_pallas: bool = False,
                       interpret: bool = True):
    """Scatter a staging tensor back into physical pages:
    ``pages[:, ids] = staged``, in place (``pages`` is donated/aliased).

    Padded id entries must point at the sink page — duplicate scatter writes
    then land only in the never-read sink row.
    """
    if not use_pallas:
        return pages.at[:, ids].set(staged.astype(pages.dtype))
    L = pages.shape[0]
    n = ids.shape[0]
    blk = (1, 1) + pages.shape[2:]
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L, n),
            in_specs=[
                pl.BlockSpec(blk, lambda l, i, ids: (l, i, 0, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct(pages.shape, pages.dtype),
        # alias indices count the scalar-prefetch operand: 0=ids, 1=staged,
        # 2=pages -> output 0
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids, staged.astype(pages.dtype), pages)


def _gather_q8_kernel(ids_ref, pages_ref, q_ref, scale_ref, scratch, sem):
    l = pl.program_id(0)
    i = pl.program_id(1)
    pid = ids_ref[i]
    cp = pltpu.make_async_copy(pages_ref.at[l, pid], scratch, sem)
    cp.start()
    cp.wait()
    x = scratch[...].astype(jnp.float32)          # (page_size, H, hd)
    amax = jnp.max(jnp.abs(x), axis=(0, 2), keepdims=True)   # (1, H, 1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q_ref[0, 0] = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    scale_ref[0, 0] = scale


def _scatter_q8_kernel(ids_ref, q_ref, scale_ref, pages_in_ref,
                       pages_out_ref, scratch, sem):
    del pages_in_ref                   # aliased with pages_out_ref (in-place)
    l = pl.program_id(0)
    i = pl.program_id(1)
    pid = ids_ref[i]
    x = q_ref[0, 0].astype(jnp.float32) * scale_ref[0, 0]
    scratch[...] = x.astype(scratch.dtype)
    cp = pltpu.make_async_copy(scratch, pages_out_ref.at[l, pid], sem)
    cp.start()
    cp.wait()


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def swap_gather_pages_q8(pages, ids, *, use_pallas: bool = False,
                         interpret: bool = True):
    """Gather ``pages[:, ids]`` and quantize to INT8 in one pass.

    Same indirection and padding contract as ``swap_gather_pages``; returns
    ``(q, scales)``: int8 ``(L, n, page_size, H, hd)`` staging pages plus
    f32 per-(layer, page, head) absmax scales ``(L, n, 1, H, 1)``.  The
    quantization happens in VMEM right after each page's DMA lands, so the
    host copy moves int8 pages, never the full-width staging tensor.
    """
    if not use_pallas:
        return quantize_pages(pages[:, ids])
    L = pages.shape[0]
    n = ids.shape[0]
    ps, H, hd = pages.shape[2:]
    qblk = (1, 1, ps, H, hd)
    sblk = (1, 1, 1, H, 1)
    return pl.pallas_call(
        _gather_q8_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L, n),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=[
                pl.BlockSpec(qblk, lambda l, i, ids: (l, i, 0, 0, 0)),
                pl.BlockSpec(sblk, lambda l, i, ids: (l, i, 0, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((ps, H, hd), pages.dtype),
                pltpu.SemaphoreType.DMA,
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((L, n, ps, H, hd), jnp.int8),
            jax.ShapeDtypeStruct((L, n, 1, H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(ids, pages)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"),
                   donate_argnums=(0,))
def swap_scatter_pages_q8(pages, ids, q_staged, scales, *,
                          use_pallas: bool = False, interpret: bool = True):
    """Dequantize INT8 staging pages and scatter them into physical pages:
    ``pages[:, ids] = q_staged * scales``, in place (``pages`` donated).

    Inverse of ``swap_gather_pages_q8`` — the dequant multiply runs in VMEM
    on each page before its async copy, so the device pool only ever holds
    full-width values.  Padding contract as ``swap_scatter_pages``.
    """
    if not use_pallas:
        return pages.at[:, ids].set(
            dequantize_pages(q_staged, scales, pages.dtype))
    L = pages.shape[0]
    n = ids.shape[0]
    ps, H, hd = pages.shape[2:]
    qblk = (1, 1, ps, H, hd)
    sblk = (1, 1, 1, H, 1)
    return pl.pallas_call(
        _scatter_q8_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L, n),
            in_specs=[
                pl.BlockSpec(qblk, lambda l, i, ids: (l, i, 0, 0, 0)),
                pl.BlockSpec(sblk, lambda l, i, ids: (l, i, 0, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
            scratch_shapes=[
                pltpu.VMEM((ps, H, hd), pages.dtype),
                pltpu.SemaphoreType.DMA,
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(pages.shape, pages.dtype),
        # alias indices count the scalar-prefetch operand: 0=ids, 1=q,
        # 2=scales, 3=pages -> output 0
        input_output_aliases={3: 0},
        interpret=interpret,
    )(ids, q_staged, scales, pages)
