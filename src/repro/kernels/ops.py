"""Jit'd public wrappers for the Pallas kernels.

``use_pallas`` selects kernel vs pure-jnp oracle; on CPU the kernels run in
interpret mode (Python-executed kernel bodies — correctness, not speed); on
TPU the same calls compile to Mosaic.  The engine flips this with one flag.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.chunked_prefill_attention import chunked_prefill_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fused_swiglu import fused_swiglu
from repro.kernels.paged_decode_attention import (
    paged_decode_attention,
    paged_decode_attention_fused,
)
from repro.kernels.paged_prefill_attention import (
    paged_prefill_attention,
    paged_prefill_attention_fused,
)
from repro.kernels.swap import (
    swap_gather_pages, swap_gather_pages_q8, swap_scatter_pages,
    swap_scatter_pages_q8,
)

_ON_TPU = None


def on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return _ON_TPU


def prefill_chunk_attention(q, k_cache, v_cache, kv_lens, q_offset, *,
                            use_pallas: bool = True, block_q: int = 128,
                            block_k: int = 128):
    """(B, Sq, Hq, hd) chunk vs (B, Skv, Hkv, hd) cache with causal offset."""
    if not use_pallas:
        return ref.chunked_prefill_attention_ref(q, k_cache, v_cache, kv_lens, q_offset)
    return chunked_prefill_attention(
        q, k_cache, v_cache, kv_lens, q_offset,
        block_q=block_q, block_k=block_k, interpret=not on_tpu(),
    )


def flash_decode_attention(q, k_cache, v_cache, kv_lens, *,
                           use_pallas: bool = True, block_k: int = 256):
    """(B, Hq, hd) single-token decode vs (B, S, Hkv, hd) cache."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k_cache, v_cache, kv_lens)
    return decode_attention(
        q, k_cache, v_cache, kv_lens, block_k=block_k, interpret=not on_tpu()
    )


def paged_prefill_chunk_attention(q, k_pages, v_pages, block_tables, kv_lens,
                                  q_offset, *, use_pallas: bool = True,
                                  block_q: int = 128, pages_per_tile: int = 1,
                                  buffering_depth: int = 1):
    """(B, Sq, Hq, hd) chunk vs a (n_pages, ps, Hkv, hd) physical page pool
    addressed through per-sequence block tables, with causal offset.
    ``pages_per_tile`` pages are DMA-gathered into one MXU K/V tile per grid
    step (the oracle is tile-size-agnostic: indirection is data movement);
    ``buffering_depth`` gathers run ahead of the dot (1 = synchronous)."""
    if not use_pallas:
        return ref.paged_prefill_attention_ref(
            q, k_pages, v_pages, block_tables, kv_lens, q_offset)
    return paged_prefill_attention(
        q, k_pages, v_pages, block_tables, kv_lens, q_offset,
        block_q=block_q, pages_per_tile=pages_per_tile,
        buffering_depth=buffering_depth, interpret=not on_tpu(),
    )


def paged_prefill_chunk_attention_fused(q, kv_pages, block_tables, kv_lens,
                                        q_offset, *, use_pallas: bool = True,
                                        block_q: int = 128,
                                        pages_per_tile: int = 1,
                                        buffering_depth: int = 1):
    """``paged_prefill_chunk_attention`` over a fused head-interleaved pool
    ``(n_pages, ps, 2*Hkv, hd)`` — one DMA per page feeds both K and V."""
    if not use_pallas:
        return ref.paged_prefill_attention_fused_ref(
            q, kv_pages, block_tables, kv_lens, q_offset)
    return paged_prefill_attention_fused(
        q, kv_pages, block_tables, kv_lens, q_offset,
        block_q=block_q, pages_per_tile=pages_per_tile,
        buffering_depth=buffering_depth, interpret=not on_tpu(),
    )


def paged_flash_decode_attention(q, k_pages, v_pages, block_tables, kv_lens, *,
                                 use_pallas: bool = True,
                                 pages_per_tile: int = 1,
                                 buffering_depth: int = 1):
    """(B, Hq, hd) single-token decode vs a paged pool + block tables."""
    if not use_pallas:
        return ref.paged_decode_attention_ref(
            q, k_pages, v_pages, block_tables, kv_lens)
    return paged_decode_attention(
        q, k_pages, v_pages, block_tables, kv_lens,
        pages_per_tile=pages_per_tile, buffering_depth=buffering_depth,
        interpret=not on_tpu(),
    )


def paged_flash_decode_attention_fused(q, kv_pages, block_tables, kv_lens, *,
                                       use_pallas: bool = True,
                                       pages_per_tile: int = 1,
                                       buffering_depth: int = 1):
    """``paged_flash_decode_attention`` over a fused head-interleaved pool."""
    if not use_pallas:
        return ref.paged_decode_attention_fused_ref(
            q, kv_pages, block_tables, kv_lens)
    return paged_decode_attention_fused(
        q, kv_pages, block_tables, kv_lens,
        pages_per_tile=pages_per_tile, buffering_depth=buffering_depth,
        interpret=not on_tpu(),
    )


def swiglu_ffn(x, w_gate, w_up, w_down, *, use_pallas: bool = True,
               block_m: int = 256, block_f: int = 256):
    """(M, D) x (D, F) SwiGLU; fused single-HBM-pass kernel on TPU."""
    if not use_pallas:
        return ref.fused_swiglu_ref(x, w_gate, w_up, w_down)
    return fused_swiglu(
        x, w_gate, w_up, w_down,
        block_m=block_m, block_f=block_f, interpret=not on_tpu(),
    )


def gather_swap_pages(pages, ids, *, use_pallas: bool = True):
    """Collect scattered physical pages ``pages[:, ids]`` into one contiguous
    staging tensor (swap-out: the engine host-copies the result as a single
    dense DMA)."""
    return swap_gather_pages(
        pages, ids, use_pallas=use_pallas, interpret=not on_tpu()
    )


def scatter_swap_pages(pages, ids, staged, *, use_pallas: bool = True):
    """Write a staging tensor back into freshly allocated physical pages
    (swap-in restore; ``pages`` is donated and updated in place)."""
    return swap_scatter_pages(
        pages, ids, staged, use_pallas=use_pallas, interpret=not on_tpu()
    )


def gather_swap_pages_q8(pages, ids, *, use_pallas: bool = True):
    """Gather + INT8-quantize staging pages in one fused pass (host tier
    with ``host_kv_dtype="int8"``): returns ``(q, scales)``."""
    return swap_gather_pages_q8(
        pages, ids, use_pallas=use_pallas, interpret=not on_tpu()
    )


def scatter_swap_pages_q8(pages, ids, q_staged, scales, *,
                          use_pallas: bool = True):
    """Dequantize + scatter INT8 staging pages back into physical pages
    (``pages`` donated and updated in place)."""
    return swap_scatter_pages_q8(
        pages, ids, q_staged, scales, use_pallas=use_pallas,
        interpret=not on_tpu()
    )
