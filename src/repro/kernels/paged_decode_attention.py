"""Pallas TPU kernel: paged flash-decode attention (block-table K/V gather).

The physical KV cache is a pool of fixed-size pages shared by all sequences
(vLLM layout): ``k_pages/v_pages: (n_pages, page_size, Hkv, hd)``.  Each
sequence owns a *block table* — the ordered list of physical page ids backing
its logical token positions — so capacity scales with tokens actually
resident, not ``n_slots x max_context``.

Indirection rides scalar prefetch: the block table and per-sequence kv
lengths land in SMEM before the kernel body runs.  Each grid step covers one
*tile* of ``pages_per_tile`` pages: the kernel issues one async copy per page
(K and V live in compiler-placed memory, ``pltpu.ANY``), gathering the
scattered physical pages into a contiguous
``(pages_per_tile * page_size, hd)`` VMEM tile, then runs one MXU dot over
the whole tile.  At small page sizes this is the difference between feeding
the MXU 16-row slivers and feeding it full 128-row tiles — the per-tile
online-softmax (m, l, acc) scratch carries across tiles exactly as the dense
``decode_attention`` kernel carries across KV blocks.  Tiles entirely past
``kv_len`` are skipped before any DMA is issued.

Two orthogonal knobs hide the gather latency behind the MXU dot:

* ``buffering_depth`` — the VMEM tile scratch and DMA semaphores carry a
  leading ``depth`` axis; tile ``t`` lands in buffer slot ``t % depth``.  At
  tile 0 a prologue issues the copies for tiles ``0..depth-2``; every step
  then issues tile ``t+depth-1`` *before* waiting on tile ``t``'s
  semaphores, so the next gather is in flight while the current tile's dot
  runs.  ``depth=1`` degenerates to the synchronous issue-then-wait path.
  Reuse is safe because slot ``(t+depth-1) % depth`` last held tile
  ``t-1``, whose compute retired in the previous (sequential) grid step.
  Live tiles form a contiguous prefix of the table, so every issued copy is
  waited within the same inner tile loop — dead tiles still skip DMA
  entirely.
* ``fused`` — the pool carries the head-interleaved layout
  ``[K0,V0,K1,V1,...]`` (``kv_pages: (n_pages, page_size, 2*Hkv, hd)``,
  viewed kernel-side as ``(n_pages, Hkv, 2, ps, hd)``), so ONE async copy
  per page fetches both the K and V rows: half the page-table reads and
  half the DMA issue count of the split layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _tile_copies(block_tables_ref, kv_h, t, slot, rest, *,
                 page_size, pages_per_tile, fused, b):
    """Async-copy descriptors gathering tile ``t`` into buffer ``slot``.

    The same descriptors are built twice — once to ``start()`` the DMAs,
    once to ``wait()`` them (a descriptor is just (src, dst, sem))."""
    out = []
    for j in range(pages_per_tile):
        pid = block_tables_ref[b, t * pages_per_tile + j]
        if fused:
            kv_hbm, kv_tile, sem = rest
            # one copy moves the page's full (2, ps, hd) K+V block
            out.append(pltpu.make_async_copy(
                kv_hbm.at[pid, kv_h], kv_tile.at[slot, j], sem.at[slot, 0, j]
            ))
        else:
            k_hbm, v_hbm, k_tile, v_tile, sem = rest
            dst = pl.ds(j * page_size, page_size)
            out.append(pltpu.make_async_copy(
                k_hbm.at[pid, kv_h], k_tile.at[slot, dst, :], sem.at[slot, 0, j]
            ))
            out.append(pltpu.make_async_copy(
                v_hbm.at[pid, kv_h], v_tile.at[slot, dst, :], sem.at[slot, 1, j]
            ))
    return out


def _paged_decode_kernel(
    block_tables_ref,   # (B, n_tiles * pages_per_tile) scalar prefetch
    kv_len_ref,         # (B,) scalar prefetch
    q_ref,              # (group, hd)
    *refs,              # split: k_hbm, v_hbm | fused: kv_hbm; then o_ref + scratch
    page_size: int,
    pages_per_tile: int,
    sm_scale: float,
    depth: int,
    n_tiles: int,
    fused: bool,
):
    if fused:
        kv_hbm, o_ref, m_ref, l_ref, acc_ref, kv_tile, sem = refs
        dma_refs = (kv_hbm, kv_tile, sem)
    else:
        k_hbm, v_hbm, o_ref, m_ref, l_ref, acc_ref, k_tile, v_tile, sem = refs
        dma_refs = (k_hbm, v_hbm, k_tile, v_tile, sem)

    b = pl.program_id(0)
    h = pl.program_id(1)
    tile_i = pl.program_id(2)
    tile = page_size * pages_per_tile

    kv_len = kv_len_ref[b]

    def live(t):
        # whole-tile skip: tiles past the valid length issue no DMA at all
        return t * tile < kv_len

    def copies(t, slot):
        return _tile_copies(
            block_tables_ref, h, t, slot, dma_refs, page_size=page_size,
            pages_per_tile=pages_per_tile, fused=fused, b=b,
        )

    @pl.when(tile_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # prologue: put tiles 0..depth-2 in flight before the first wait
        for d in range(min(depth - 1, n_tiles)):
            @pl.when(live(d))
            def _issue_ahead(d=d):
                for c in copies(d, d % depth):
                    c.start()

    # steady state: issue tile t+depth-1 before waiting on tile t (depth=1:
    # issue tile t itself — the synchronous path)
    nxt = tile_i + (depth - 1)
    @pl.when((nxt < n_tiles) & live(nxt))
    def _issue():
        for c in copies(nxt, nxt % depth):
            c.start()

    slot = tile_i % depth

    @pl.when(live(tile_i))
    def _compute():
        for c in copies(tile_i, slot):
            c.wait()
        if fused:
            kv = kv_tile[slot]                                # (ppt, 2, ps, hd)
            hd = kv.shape[-1]
            k = kv[:, 0].reshape(tile, hd)
            v = kv[:, 1].reshape(tile, hd)
        else:
            k = k_tile[slot]                                  # (tile, hd)
            v = v_tile[slot]

        tile_start = tile_i * tile
        k_pos = tile_start + jax.lax.iota(jnp.int32, tile)
        q = q_ref[...].astype(jnp.float32) * sm_scale         # (g, hd)
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (g, tile)
        mask = k_pos[None, :] < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(tile_i == n_tiles - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def _pad_tables(block_tables, pages_per_tile):
    """Right-pad the table columns to a tile multiple.  Pad entries use page
    id 0 — any valid id works: padded logical positions lie at or past
    ``max_pages * page_size >= kv_len`` and are masked (or whole-tile
    skipped) before they can contribute."""
    B, max_pages = block_tables.shape
    n_tiles = -(-max_pages // pages_per_tile)
    pad = n_tiles * pages_per_tile - max_pages
    if pad:
        block_tables = jnp.concatenate(
            [block_tables, jnp.zeros((B, pad), block_tables.dtype)], axis=1
        )
    return block_tables, n_tiles


def _fused_kernel_view(kv_pages):
    """(n_pages, ps, 2*Hkv, hd) head-interleaved pool -> the kernel-side
    (n_pages, Hkv, 2, ps, hd) view: ``.at[pid, kv_h]`` is one page's K+V."""
    n_pages, ps, H2, hd = kv_pages.shape
    return kv_pages.reshape(n_pages, ps, H2 // 2, 2, hd).transpose(0, 2, 3, 1, 4)


def _decode_scratch(depth, tile, pages_per_tile, page_size, hd, group,
                    dtype, fused):
    base = [
        pltpu.VMEM((group,), jnp.float32),
        pltpu.VMEM((group,), jnp.float32),
        pltpu.VMEM((group, hd), jnp.float32),
    ]
    if fused:
        return base + [
            pltpu.VMEM((depth, pages_per_tile, 2, page_size, hd), dtype),
            pltpu.SemaphoreType.DMA((depth, 1, pages_per_tile)),
        ]
    return base + [
        pltpu.VMEM((depth, tile, hd), dtype),
        pltpu.VMEM((depth, tile, hd), dtype),
        pltpu.SemaphoreType.DMA((depth, 2, pages_per_tile)),
    ]


def _paged_decode_call(q, pools, block_tables, kv_lens, *, pages_per_tile,
                       buffering_depth, interpret, fused):
    B, Hq, hd = q.shape
    page_size = pools[0].shape[1]
    Hkv = pools[0].shape[2] // (2 if fused else 1)
    assert Hq % Hkv == 0, (Hq, Hkv)
    assert buffering_depth >= 1, buffering_depth
    group = Hq // Hkv

    block_tables, n_tiles = _pad_tables(
        block_tables.astype(jnp.int32), pages_per_tile
    )

    grid = (B, Hkv, n_tiles)
    kernel = functools.partial(
        _paged_decode_kernel, page_size=page_size,
        pages_per_tile=pages_per_tile, sm_scale=1.0 / math.sqrt(hd),
        depth=buffering_depth, n_tiles=n_tiles, fused=fused,
    )

    q_g = q.reshape(B, Hkv, group, hd)
    if fused:
        pool_ops = (_fused_kernel_view(pools[0]),)
    else:
        # pages laid out (n_pages, Hkv, page_size, hd): contiguous (ps, hd) tiles
        pool_ops = (pools[0].transpose(0, 2, 1, 3), pools[1].transpose(0, 2, 1, 3))

    tile = page_size * pages_per_tile
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, group, hd),
                    lambda b, h, ti, *_: (b, h, 0, 0),
                ),
                # K/V stay unblocked: the kernel gathers pages itself via
                # per-page async copies steered by the prefetched table
                *([pl.BlockSpec(memory_space=pltpu.ANY)] * len(pool_ops)),
            ],
            out_specs=pl.BlockSpec(
                (None, None, group, hd),
                lambda b, h, ti, *_: (b, h, 0, 0),
            ),
            scratch_shapes=_decode_scratch(
                buffering_depth, tile, pages_per_tile, page_size, hd, group,
                pools[0].dtype, fused,
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        interpret=interpret,
    )(block_tables, kv_lens.astype(jnp.int32), q_g, *pool_ops)

    return out.reshape(B, Hq, hd)


@functools.partial(
    jax.jit, static_argnames=("pages_per_tile", "buffering_depth", "interpret")
)
def paged_decode_attention(
    q,              # (B, Hq, hd) one token per sequence
    k_pages,        # (n_pages, page_size, Hkv, hd) physical page pool
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32 physical page ids (pad: any valid id)
    kv_lens,        # (B,) int32 valid token counts
    *,
    pages_per_tile: int = 1,
    buffering_depth: int = 1,
    interpret: bool = True,
):
    return _paged_decode_call(
        q, (k_pages, v_pages), block_tables, kv_lens,
        pages_per_tile=pages_per_tile, buffering_depth=buffering_depth,
        interpret=interpret, fused=False,
    )


@functools.partial(
    jax.jit, static_argnames=("pages_per_tile", "buffering_depth", "interpret")
)
def paged_decode_attention_fused(
    q,              # (B, Hq, hd)
    kv_pages,       # (n_pages, page_size, 2*Hkv, hd) head-interleaved pool
    block_tables,   # (B, max_pages) int32
    kv_lens,        # (B,) int32
    *,
    pages_per_tile: int = 1,
    buffering_depth: int = 1,
    interpret: bool = True,
):
    return _paged_decode_call(
        q, (kv_pages,), block_tables, kv_lens,
        pages_per_tile=pages_per_tile, buffering_depth=buffering_depth,
        interpret=interpret, fused=True,
    )
