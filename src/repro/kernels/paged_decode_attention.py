"""Pallas TPU kernel: paged flash-decode attention (block-table K/V gather).

The physical KV cache is a pool of fixed-size pages shared by all sequences
(vLLM layout): ``k_pages/v_pages: (n_pages, page_size, Hkv, hd)``.  Each
sequence owns a *block table* — the ordered list of physical page ids backing
its logical token positions — so capacity scales with tokens actually
resident, not ``n_slots x max_context``.

Indirection rides scalar prefetch: the block table and per-sequence kv
lengths land in SMEM before the kernel body runs.  Each grid step covers one
*tile* of ``pages_per_tile`` pages: the kernel issues one async copy per page
(K and V live in compiler-placed memory, ``pltpu.ANY``), gathering the
scattered physical pages into a contiguous
``(pages_per_tile * page_size, hd)`` VMEM tile, then runs one MXU dot over
the whole tile.  At small page sizes this is the difference between feeding
the MXU 16-row slivers and feeding it full 128-row tiles — the per-tile
online-softmax (m, l, acc) scratch carries across tiles exactly as the dense
``decode_attention`` kernel carries across KV blocks.  Tiles entirely past
``kv_len`` are skipped before any DMA is issued.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(
    block_tables_ref,   # (B, n_tiles * pages_per_tile) scalar prefetch
    kv_len_ref,         # (B,) scalar prefetch
    q_ref,              # (group, hd)
    k_hbm,              # (n_pages, Hkv, page_size, hd) — ANY memory space
    v_hbm,              # (n_pages, Hkv, page_size, hd)
    o_ref,              # (group, hd)
    m_ref,              # (group,) f32
    l_ref,              # (group,) f32
    acc_ref,            # (group, hd) f32
    k_tile,             # (pages_per_tile * page_size, hd) pool dtype
    v_tile,             # (pages_per_tile * page_size, hd)
    sem,                # DMA sems (2, pages_per_tile): [0]=K, [1]=V
    *,
    page_size: int,
    pages_per_tile: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    h = pl.program_id(1)
    tile_i = pl.program_id(2)
    n_tiles = pl.num_programs(2)
    tile = page_size * pages_per_tile

    @pl.when(tile_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kv_len_ref[b]
    tile_start = tile_i * tile

    # whole-tile skip: tiles past the valid length issue no DMA at all
    @pl.when(tile_start < kv_len)
    def _compute():
        for j in range(pages_per_tile):
            pid = block_tables_ref[b, tile_i * pages_per_tile + j]
            dst = pl.ds(j * page_size, page_size)
            pltpu.make_async_copy(
                k_hbm.at[pid, h], k_tile.at[dst, :], sem.at[0, j]
            ).start()
            pltpu.make_async_copy(
                v_hbm.at[pid, h], v_tile.at[dst, :], sem.at[1, j]
            ).start()
        for j in range(pages_per_tile):
            pid = block_tables_ref[b, tile_i * pages_per_tile + j]
            dst = pl.ds(j * page_size, page_size)
            pltpu.make_async_copy(
                k_hbm.at[pid, h], k_tile.at[dst, :], sem.at[0, j]
            ).wait()
            pltpu.make_async_copy(
                v_hbm.at[pid, h], v_tile.at[dst, :], sem.at[1, j]
            ).wait()

        k_pos = tile_start + jax.lax.iota(jnp.int32, tile)
        q = q_ref[...].astype(jnp.float32) * sm_scale         # (g, hd)
        k = k_tile[...].astype(jnp.float32)                   # (tile, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (g, tile)
        mask = k_pos[None, :] < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_tile[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(tile_i == n_tiles - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def _pad_tables(block_tables, pages_per_tile):
    """Right-pad the table columns to a tile multiple.  Pad entries use page
    id 0 — any valid id works: padded logical positions lie at or past
    ``max_pages * page_size >= kv_len`` and are masked (or whole-tile
    skipped) before they can contribute."""
    B, max_pages = block_tables.shape
    n_tiles = -(-max_pages // pages_per_tile)
    pad = n_tiles * pages_per_tile - max_pages
    if pad:
        block_tables = jnp.concatenate(
            [block_tables, jnp.zeros((B, pad), block_tables.dtype)], axis=1
        )
    return block_tables, n_tiles


@functools.partial(jax.jit, static_argnames=("pages_per_tile", "interpret"))
def paged_decode_attention(
    q,              # (B, Hq, hd) one token per sequence
    k_pages,        # (n_pages, page_size, Hkv, hd) physical page pool
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32 physical page ids (pad: any valid id)
    kv_lens,        # (B,) int32 valid token counts
    *,
    pages_per_tile: int = 1,
    interpret: bool = True,
):
    B, Hq, hd = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv

    block_tables, n_tiles = _pad_tables(
        block_tables.astype(jnp.int32), pages_per_tile
    )

    grid = (B, Hkv, n_tiles)
    kernel = functools.partial(
        _paged_decode_kernel, page_size=page_size,
        pages_per_tile=pages_per_tile, sm_scale=1.0 / math.sqrt(hd),
    )

    q_g = q.reshape(B, Hkv, group, hd)
    # pages laid out (n_pages, Hkv, page_size, hd): contiguous (ps, hd) tiles
    k_t = k_pages.transpose(0, 2, 1, 3)
    v_t = v_pages.transpose(0, 2, 1, 3)

    tile = page_size * pages_per_tile
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, group, hd),
                    lambda b, h, ti, *_: (b, h, 0, 0),
                ),
                # K/V stay unblocked: the kernel gathers pages itself via
                # per-page async copies steered by the prefetched table
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (None, None, group, hd),
                lambda b, h, ti, *_: (b, h, 0, 0),
            ),
            scratch_shapes=[
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
                pltpu.VMEM((tile, hd), k_pages.dtype),
                pltpu.VMEM((tile, hd), v_pages.dtype),
                pltpu.SemaphoreType.DMA((2, pages_per_tile)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        interpret=interpret,
    )(block_tables, kv_lens.astype(jnp.int32), q_g, k_t, v_t)

    return out.reshape(B, Hq, hd)
