"""Pallas TPU kernel: paged flash-decode attention (block-table K/V gather).

The physical KV cache is a pool of fixed-size pages shared by all sequences
(vLLM layout): ``k_pages/v_pages: (n_pages, page_size, Hkv, hd)``.  Each
sequence owns a *block table* — the ordered list of physical page ids backing
its logical token positions — so capacity scales with tokens actually
resident, not ``n_slots x max_context``.

Indirection rides scalar prefetch: the block table and per-sequence kv
lengths land in SMEM before the kernel body runs, and the K/V BlockSpec
index maps read ``block_tables[b, page_i]`` to steer each grid step's DMA at
the right physical page.  The kernel body is the same online-softmax
(m, l, acc) scratch structure as the dense ``decode_attention`` kernel — one
HBM pass over the *live* pages only (pages past ``kv_len`` are skipped).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(
    block_tables_ref,   # (B, max_pages) scalar prefetch (steers K/V index maps)
    kv_len_ref,         # (B,) scalar prefetch
    q_ref,              # (group, hd)
    k_ref,              # (page_size, hd) — one physical page of this KV head
    v_ref,              # (page_size, hd)
    o_ref,              # (group, hd)
    m_ref,              # (group,) f32
    l_ref,              # (group,) f32
    acc_ref,            # (group, hd) f32
    *,
    page_size: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    page_i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(page_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kv_len_ref[b]
    k_pos = page_i * page_size + jax.lax.iota(jnp.int32, page_size)

    # whole-page skip: logical pages past the valid length cost nothing
    @pl.when(k_pos[0] < kv_len)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * sm_scale         # (g, hd)
        k = k_ref[...].astype(jnp.float32)                    # (ps, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (g, ps)
        mask = k_pos[None, :] < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(page_i == n_pages - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q,              # (B, Hq, hd) one token per sequence
    k_pages,        # (n_pages, page_size, Hkv, hd) physical page pool
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32 physical page ids (pad: any valid id)
    kv_lens,        # (B,) int32 valid token counts
    *,
    interpret: bool = True,
):
    B, Hq, hd = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    max_pages = block_tables.shape[1]

    grid = (B, Hkv, max_pages)
    kernel = functools.partial(
        _paged_decode_kernel, page_size=page_size,
        sm_scale=1.0 / math.sqrt(hd),
    )

    q_g = q.reshape(B, Hkv, group, hd)
    # pages laid out (n_pages, Hkv, page_size, hd): contiguous (ps, hd) tiles
    k_t = k_pages.transpose(0, 2, 1, 3)
    v_t = v_pages.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, group, hd),
                    lambda b, h, pi, *_: (b, h, 0, 0),
                ),
                # the physical page index comes from the prefetched table
                pl.BlockSpec(
                    (None, None, page_size, hd),
                    lambda b, h, pi, bt, kl: (bt[b, pi], h, 0, 0),
                ),
                pl.BlockSpec(
                    (None, None, page_size, hd),
                    lambda b, h, pi, bt, kl: (bt[b, pi], h, 0, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (None, None, group, hd),
                lambda b, h, pi, *_: (b, h, 0, 0),
            ),
            scratch_shapes=[
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_lens.astype(jnp.int32), q_g, k_t, v_t)

    return out.reshape(B, Hq, hd)
