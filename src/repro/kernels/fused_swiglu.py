"""Pallas TPU kernel: fused SwiGLU FFN — silu(x@Wg) * (x@Wu) @ Wd in one
HBM pass over the weights.

Every dense arch's FLOPs are d_ff-dominated; the unfused form writes the
(M, F) gate/up activations to HBM twice (2*M*F*2 bytes each way).  Fusing
keeps the (blk_m, blk_f) hidden tile in VMEM and accumulates the down
projection into a (blk_m, D) f32 scratch across the F grid dimension.

Tiling:
  grid = (M/blk_m, F/blk_f), F innermost
  per step: x_tile (blk_m, D) @ wg/wu tiles (D, blk_f) -> hidden (blk_m, blk_f)
            hidden @ wd tile (blk_f, D) accumulated into (blk_m, D) scratch
  VMEM: blk_m*D*2 (x) + 2*D*blk_f*2 (wg,wu) + blk_f*D*2 (wd) + blk_m*D*4 (acc)
  defaults blk_m=256, blk_f=512, D<=8192 -> ~28 MB? no: weights tiles
  dominate; for D=4096, blk_f=256: 3*4096*256*2 = 6.3 MB + acc 4 MB. OK.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_F = 256


def _swiglu_kernel(
    x_ref,        # (blk_m, D)
    wg_ref,       # (D, blk_f)
    wu_ref,       # (D, blk_f)
    wd_ref,       # (blk_f, D)
    o_ref,        # (blk_m, D)
    acc_ref,      # (blk_m, D) f32
):
    f_i = pl.program_id(1)
    n_f = pl.num_programs(1)

    @pl.when(f_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jax.lax.dot_general(
        x, wg_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    u = jax.lax.dot_general(
        x, wu_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h = (g * jax.lax.logistic(g) * u).astype(x.dtype)     # silu(g) * u
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(f_i == n_f - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_f", "interpret"))
def fused_swiglu(
    x,            # (M, D)
    w_gate,       # (D, F)
    w_up,         # (D, F)
    w_down,       # (F, D)
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_f: int = DEFAULT_BLOCK_F,
    interpret: bool = True,
):
    M, D = x.shape
    F = w_gate.shape[1]
    block_m = min(block_m, M)
    block_f = min(block_f, F)
    assert M % block_m == 0, (M, block_m)
    assert F % block_f == 0, (F, block_f)

    grid = (M // block_m, F // block_f)

    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((D, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((D, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((block_f, D), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, D), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, D), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
