"""Pallas TPU kernel: paged chunked-prefill attention.

The chunked-prefill engine's hot op against a *paged* KV cache: a chunk of Q
tokens (one scheduling round) attends to its sequence's prefix KV plus its
own keys with a causal offset, where K/V live in a shared physical page pool
``(n_pages, page_size, Hkv, hd)`` addressed through a per-sequence block
table (same layout as ``paged_decode_attention``).

Grid: ``(B, Hq, Sq // block_q, n_tiles)`` — the innermost dimension walks the
sequence's block table one *tile* of ``pages_per_tile`` pages at a time.  The
prefetched table steers per-page async copies (K/V live in compiler-placed
memory, ``pltpu.ANY``) that gather the scattered physical pages into one
contiguous ``(pages_per_tile * page_size, hd)`` VMEM tile, so the MXU sees
wide K/V operands even at small page sizes; the online-softmax (m, l, acc)
scratch carries across tiles exactly as the dense kernel carries across KV
blocks.  Tiles entirely above the causal diagonal or past ``kv_len`` are
skipped before any DMA is issued, so work stays ~O(prefix + chunk^2/2) per
sequence regardless of pool size.

``buffering_depth`` and the fused head-interleaved layout work exactly as in
``paged_decode_attention`` (see its module docstring): tile ``t`` computes
out of buffer slot ``t % depth`` while tile ``t+depth-1``'s gather is
already in flight, and the fused pool needs only ONE async copy per page to
feed both K and V.  Live tiles form a contiguous prefix (the causal bound
``tile_start <= q_pos[-1]`` and the length bound ``tile_start < kv_len`` are
both monotone in the tile index), so every issued copy is waited within the
same inner tile loop.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.paged_decode_attention import (
    _fused_kernel_view,
    _pad_tables,
    _tile_copies,
)

DEFAULT_BLOCK_Q = 128

NEG_INF = -1e30


def _paged_prefill_kernel(
    # prefetched scalars
    block_tables_ref,   # (B, n_tiles * pages_per_tile)
    kv_len_ref,         # (B,) valid kv length (prefix + chunk)
    q_offset_ref,       # (B,) absolute position of q[:, 0]
    # blocked operands
    q_ref,              # (blk_q, hd)
    *refs,              # split: k_hbm, v_hbm | fused: kv_hbm; then o_ref + scratch
    block_q: int,
    page_size: int,
    pages_per_tile: int,
    group: int,
    sm_scale: float,
    depth: int,
    n_tiles: int,
    fused: bool,
):
    if fused:
        kv_hbm, o_ref, m_ref, l_ref, acc_ref, kv_tile, sem = refs
        dma_refs = (kv_hbm, kv_tile, sem)
    else:
        k_hbm, v_hbm, o_ref, m_ref, l_ref, acc_ref, k_tile, v_tile, sem = refs
        dma_refs = (k_hbm, v_hbm, k_tile, v_tile, sem)

    b = pl.program_id(0)
    h = pl.program_id(1)
    tile_i = pl.program_id(3)
    tile = page_size * pages_per_tile

    kv_len = kv_len_ref[b]
    q_off = q_offset_ref[b]

    q_i = pl.program_id(2)
    q_pos = q_off + q_i * block_q + jax.lax.iota(jnp.int32, block_q)

    def live(t):
        # whole-tile skip: above the causal diagonal or past the valid
        # length — dead tiles issue no DMA
        return (t * tile <= q_pos[-1]) & (t * tile < kv_len)

    kv_h = h // group

    def copies(t, slot):
        return _tile_copies(
            block_tables_ref, kv_h, t, slot, dma_refs, page_size=page_size,
            pages_per_tile=pages_per_tile, fused=fused, b=b,
        )

    @pl.when(tile_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # prologue: put tiles 0..depth-2 in flight before the first wait
        for d in range(min(depth - 1, n_tiles)):
            @pl.when(live(d))
            def _issue_ahead(d=d):
                for c in copies(d, d % depth):
                    c.start()

    # steady state: issue tile t+depth-1 before waiting on tile t (depth=1:
    # issue tile t itself — the synchronous path)
    nxt = tile_i + (depth - 1)
    @pl.when((nxt < n_tiles) & live(nxt))
    def _issue():
        for c in copies(nxt, nxt % depth):
            c.start()

    slot = tile_i % depth

    @pl.when(live(tile_i))
    def _compute():
        for c in copies(tile_i, slot):
            c.wait()
        if fused:
            kv = kv_tile[slot]                                # (ppt, 2, ps, hd)
            hd = kv.shape[-1]
            k = kv[:, 0].reshape(tile, hd)
            v = kv[:, 1].reshape(tile, hd)
        else:
            k = k_tile[slot]                                  # (tile, hd)
            v = v_tile[slot]

        tile_start = tile_i * tile
        k_pos = tile_start + jax.lax.iota(jnp.int32, tile)
        q = q_ref[...].astype(jnp.float32) * sm_scale
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # (blk_q, tile)
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(tile_i == n_tiles - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def _prefill_scratch(depth, tile, pages_per_tile, page_size, hd, block_q,
                     dtype, fused):
    base = [
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q, hd), jnp.float32),
    ]
    if fused:
        return base + [
            pltpu.VMEM((depth, pages_per_tile, 2, page_size, hd), dtype),
            pltpu.SemaphoreType.DMA((depth, 1, pages_per_tile)),
        ]
    return base + [
        pltpu.VMEM((depth, tile, hd), dtype),
        pltpu.VMEM((depth, tile, hd), dtype),
        pltpu.SemaphoreType.DMA((depth, 2, pages_per_tile)),
    ]


def _paged_prefill_call(q, pools, block_tables, kv_lens, q_offset, *,
                        block_q, pages_per_tile, buffering_depth, interpret,
                        fused):
    B, Sq, Hq, hd = q.shape
    page_size = pools[0].shape[1]
    Hkv = pools[0].shape[2] // (2 if fused else 1)
    assert Hq % Hkv == 0, (Hq, Hkv)
    assert buffering_depth >= 1, buffering_depth
    group = Hq // Hkv

    block_q = min(block_q, Sq)
    assert Sq % block_q == 0, (Sq, block_q)

    block_tables, n_tiles = _pad_tables(
        block_tables.astype(jnp.int32), pages_per_tile
    )

    grid = (B, Hq, Sq // block_q, n_tiles)
    kernel = functools.partial(
        _paged_prefill_kernel, block_q=block_q, page_size=page_size,
        pages_per_tile=pages_per_tile, group=group,
        sm_scale=1.0 / math.sqrt(hd), depth=buffering_depth, n_tiles=n_tiles,
        fused=fused,
    )

    q_t = q.transpose(0, 2, 1, 3)          # (B, Hq, Sq, hd)
    if fused:
        pool_ops = (_fused_kernel_view(pools[0]),)
    else:
        pool_ops = (pools[0].transpose(0, 2, 1, 3), pools[1].transpose(0, 2, 1, 3))

    tile = page_size * pages_per_tile
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, block_q, hd),
                    lambda b, h, qi, ti, *_: (b, h, qi, 0),
                ),
                # K/V stay unblocked: the kernel gathers pages itself via
                # per-page async copies steered by the prefetched table
                *([pl.BlockSpec(memory_space=pltpu.ANY)] * len(pool_ops)),
            ],
            out_specs=pl.BlockSpec(
                (None, None, block_q, hd),
                lambda b, h, qi, ti, *_: (b, h, qi, 0),
            ),
            scratch_shapes=_prefill_scratch(
                buffering_depth, tile, pages_per_tile, page_size, hd, block_q,
                pools[0].dtype, fused,
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        interpret=interpret,
    )(
        block_tables, kv_lens.astype(jnp.int32), q_offset.astype(jnp.int32),
        q_t, *pool_ops,
    )

    return out.transpose(0, 2, 1, 3)       # (B, Sq, Hq, hd)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "pages_per_tile", "buffering_depth", "interpret"),
)
def paged_prefill_attention(
    q,              # (B, Sq, Hq, hd) the prefill chunk's queries
    k_pages,        # (n_pages, page_size, Hkv, hd) physical page pool
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32 physical page ids
    kv_lens,        # (B,) int32 valid KV length (prefix + chunk)
    q_offset,       # (B,) int32 absolute position of q[:, 0]
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    pages_per_tile: int = 1,
    buffering_depth: int = 1,
    interpret: bool = True,
):
    return _paged_prefill_call(
        q, (k_pages, v_pages), block_tables, kv_lens, q_offset,
        block_q=block_q, pages_per_tile=pages_per_tile,
        buffering_depth=buffering_depth, interpret=interpret, fused=False,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "pages_per_tile", "buffering_depth", "interpret"),
)
def paged_prefill_attention_fused(
    q,              # (B, Sq, Hq, hd)
    kv_pages,       # (n_pages, page_size, 2*Hkv, hd) head-interleaved pool
    block_tables,   # (B, max_pages) int32
    kv_lens,        # (B,) int32
    q_offset,       # (B,) int32
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    pages_per_tile: int = 1,
    buffering_depth: int = 1,
    interpret: bool = True,
):
    return _paged_prefill_call(
        q, (kv_pages,), block_tables, kv_lens, q_offset,
        block_q=block_q, pages_per_tile=pages_per_tile,
        buffering_depth=buffering_depth, interpret=interpret, fused=True,
    )
