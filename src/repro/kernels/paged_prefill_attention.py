"""Pallas TPU kernel: paged chunked-prefill attention.

The chunked-prefill engine's hot op against a *paged* KV cache: a chunk of Q
tokens (one scheduling round) attends to its sequence's prefix KV plus its
own keys with a causal offset, where K/V live in a shared physical page pool
``(n_pages, page_size, Hkv, hd)`` addressed through a per-sequence block
table (same layout as ``paged_decode_attention``).

Grid: ``(B, Hq, Sq // block_q, n_tiles)`` — the innermost dimension walks the
sequence's block table one *tile* of ``pages_per_tile`` pages at a time.  The
prefetched table steers per-page async copies (K/V live in compiler-placed
memory, ``pltpu.ANY``) that gather the scattered physical pages into one
contiguous ``(pages_per_tile * page_size, hd)`` VMEM tile, so the MXU sees
wide K/V operands even at small page sizes; the online-softmax (m, l, acc)
scratch carries across tiles exactly as the dense kernel carries across KV
blocks.  Tiles entirely above the causal diagonal or past ``kv_len`` are
skipped before any DMA is issued, so work stays ~O(prefix + chunk^2/2) per
sequence regardless of pool size.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.paged_decode_attention import _pad_tables

DEFAULT_BLOCK_Q = 128

NEG_INF = -1e30


def _paged_prefill_kernel(
    # prefetched scalars
    block_tables_ref,   # (B, n_tiles * pages_per_tile)
    kv_len_ref,         # (B,) valid kv length (prefix + chunk)
    q_offset_ref,       # (B,) absolute position of q[:, 0]
    # blocked operands
    q_ref,              # (blk_q, hd)
    k_hbm,              # (n_pages, Hkv, page_size, hd) — ANY memory space
    v_hbm,              # (n_pages, Hkv, page_size, hd)
    # blocked output
    o_ref,              # (blk_q, hd)
    # scratch
    m_ref,              # (blk_q,) f32
    l_ref,              # (blk_q,) f32
    acc_ref,            # (blk_q, hd) f32
    k_tile,             # (pages_per_tile * page_size, hd) pool dtype
    v_tile,             # (pages_per_tile * page_size, hd)
    sem,                # DMA sems (2, pages_per_tile): [0]=K, [1]=V
    *,
    block_q: int,
    page_size: int,
    pages_per_tile: int,
    group: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    h = pl.program_id(1)
    tile_i = pl.program_id(3)
    n_tiles = pl.num_programs(3)
    tile = page_size * pages_per_tile

    @pl.when(tile_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kv_len_ref[b]
    q_off = q_offset_ref[b]

    q_i = pl.program_id(2)
    q_pos = q_off + q_i * block_q + jax.lax.iota(jnp.int32, block_q)
    tile_start = tile_i * tile

    # whole-tile skip: above the causal diagonal or past the valid length —
    # dead tiles issue no DMA
    tile_live = (tile_start <= q_pos[-1]) & (tile_start < kv_len)

    @pl.when(tile_live)
    def _compute():
        kv_h = h // group
        for j in range(pages_per_tile):
            pid = block_tables_ref[b, tile_i * pages_per_tile + j]
            dst = pl.ds(j * page_size, page_size)
            pltpu.make_async_copy(
                k_hbm.at[pid, kv_h], k_tile.at[dst, :], sem.at[0, j]
            ).start()
            pltpu.make_async_copy(
                v_hbm.at[pid, kv_h], v_tile.at[dst, :], sem.at[1, j]
            ).start()
        for j in range(pages_per_tile):
            pid = block_tables_ref[b, tile_i * pages_per_tile + j]
            dst = pl.ds(j * page_size, page_size)
            pltpu.make_async_copy(
                k_hbm.at[pid, kv_h], k_tile.at[dst, :], sem.at[0, j]
            ).wait()
            pltpu.make_async_copy(
                v_hbm.at[pid, kv_h], v_tile.at[dst, :], sem.at[1, j]
            ).wait()

        k_pos = tile_start + jax.lax.iota(jnp.int32, tile)
        q = q_ref[...].astype(jnp.float32) * sm_scale
        k = k_tile[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # (blk_q, tile)
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_tile[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(tile_i == n_tiles - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "pages_per_tile", "interpret")
)
def paged_prefill_attention(
    q,              # (B, Sq, Hq, hd) the prefill chunk's queries
    k_pages,        # (n_pages, page_size, Hkv, hd) physical page pool
    v_pages,        # (n_pages, page_size, Hkv, hd)
    block_tables,   # (B, max_pages) int32 physical page ids
    kv_lens,        # (B,) int32 valid KV length (prefix + chunk)
    q_offset,       # (B,) int32 absolute position of q[:, 0]
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    pages_per_tile: int = 1,
    interpret: bool = True,
):
    B, Sq, Hq, hd = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv

    block_q = min(block_q, Sq)
    assert Sq % block_q == 0, (Sq, block_q)

    block_tables, n_tiles = _pad_tables(
        block_tables.astype(jnp.int32), pages_per_tile
    )

    grid = (B, Hq, Sq // block_q, n_tiles)
    kernel = functools.partial(
        _paged_prefill_kernel, block_q=block_q, page_size=page_size,
        pages_per_tile=pages_per_tile, group=group,
        sm_scale=1.0 / math.sqrt(hd),
    )

    q_t = q.transpose(0, 2, 1, 3)          # (B, Hq, Sq, hd)
    k_t = k_pages.transpose(0, 2, 1, 3)    # (n_pages, Hkv, ps, hd)
    v_t = v_pages.transpose(0, 2, 1, 3)

    tile = page_size * pages_per_tile
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, block_q, hd),
                    lambda b, h, qi, ti, *_: (b, h, qi, 0),
                ),
                # K/V stay unblocked: the kernel gathers pages itself via
                # per-page async copies steered by the prefetched table
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (None, None, block_q, hd),
                lambda b, h, qi, ti, *_: (b, h, qi, 0),
            ),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, hd), jnp.float32),
                pltpu.VMEM((tile, hd), k_pages.dtype),
                pltpu.VMEM((tile, hd), v_pages.dtype),
                pltpu.SemaphoreType.DMA((2, pages_per_tile)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        interpret=interpret,
    )(
        block_tables, kv_lens.astype(jnp.int32), q_offset.astype(jnp.int32),
        q_t, k_t, v_t,
    )

    return out.transpose(0, 2, 1, 3)       # (B, Sq, Hq, hd)
