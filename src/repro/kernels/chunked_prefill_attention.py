"""Pallas TPU kernel: chunked-prefill attention (the hot op of the paper's
serving engine).

A chunk of Q tokens (one scheduling round's prefill chunk) attends to the
prefix KV cache plus its own keys with a causal offset — exactly the
computation a chunked-prefill engine issues per round (Sarathi-style).

TPU adaptation (vs the GPU flash kernels the paper's engines use):
  * Q tile x KV tile 128 — MXU-aligned (128x128 systolic array).
  * Online softmax: running (m, l, acc) carried in f32 VMEM scratch across
    the KV grid dimension (innermost), one HBM pass over K/V.
  * GQA: grid iterates query heads; the KV block index maps h -> h // group
    so each KV head's cache tile is streamed once per query-head group.
  * Per-batch q_offset and kv_len arrive via scalar prefetch (SMEM): tiles
    entirely above the causal diagonal or past kv_len skip their matmuls
    (`tile_live`), keeping work ~O(prefix + chunk^2/2), not O(Skv * chunk).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = -1e30


def _attn_kernel(
    # prefetched scalars
    q_offset_ref,   # (B,) absolute position of q[:, 0]
    kv_len_ref,     # (B,) valid kv length
    # blocked operands
    q_ref,          # (blk_q, hd)
    k_ref,          # (blk_k, hd)
    v_ref,          # (blk_k, hd)
    # blocked output
    o_ref,          # (blk_q, hd)
    # scratch
    m_ref,          # (blk_q,) f32 running max
    l_ref,          # (blk_q,) f32 running sum
    acc_ref,        # (blk_q, hd) f32 accumulator
    *,
    block_q: int,
    block_k: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    kv_i = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_off = q_offset_ref[b]
    kv_len = kv_len_ref[b]

    q_i = pl.program_id(2)
    q_pos = q_off + q_i * block_q + jax.lax.iota(jnp.int32, block_q)   # (blk_q,)
    k_pos = kv_i * block_k + jax.lax.iota(jnp.int32, block_k)          # (blk_k,)

    # whole-tile skip: first key pos vs the highest query pos in this tile
    tile_live = (k_pos[0] <= q_pos[-1]) & (k_pos[0] < kv_len)

    @pl.when(tile_live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * sm_scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # (blk_q, blk_k)
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "interpret"),
)
def chunked_prefill_attention(
    q,            # (B, Sq, Hq, hd)
    k_cache,      # (B, Skv, Hkv, hd)
    v_cache,      # (B, Skv, Hkv, hd)
    kv_lens,      # (B,) int32
    q_offset,     # (B,) int32
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0, (Sq, block_q)
    assert Skv % block_k == 0, (Skv, block_k)

    grid = (B, Hq, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_k=block_k,
        sm_scale=1.0 / math.sqrt(hd),
    )

    # layouts: head dim before seq for contiguous (seq, hd) tiles
    q_t = q.transpose(0, 2, 1, 3)          # (B, Hq, Sq, hd)
    k_t = k_cache.transpose(0, 2, 1, 3)    # (B, Hkv, Skv, hd)
    v_t = v_cache.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, None, block_q, hd),
                    lambda b, h, qi, ki, *_: (b, h, qi, 0),
                ),
                pl.BlockSpec(
                    (None, None, block_k, hd),
                    lambda b, h, qi, ki, *_, g=group: (b, h // g, ki, 0),
                ),
                pl.BlockSpec(
                    (None, None, block_k, hd),
                    lambda b, h, qi, ki, *_, g=group: (b, h // g, ki, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (None, None, block_q, hd),
                lambda b, h, qi, ki, *_: (b, h, qi, 0),
            ),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        interpret=interpret,
    )(q_offset.astype(jnp.int32), kv_lens.astype(jnp.int32), q_t, k_t, v_t)

    return out.transpose(0, 2, 1, 3)       # (B, Sq, Hq, hd)
