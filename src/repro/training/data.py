"""Deterministic, resumable synthetic data pipeline for LM training.

Production shape: shard-aware iteration (each DP shard reads its slice),
deterministic from (seed, step) so a restore at step k regenerates the exact
stream — the checkpoint only needs to record the step.  Swap `synthetic_lm`
for a tokenized-file reader in a real deployment; the iterator contract
(shape, dtype, determinism, resume) is what the trainer depends on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # fraction of tokens masked out of the loss (simulates padding/doc joins)
    mask_fraction: float = 0.05


class SyntheticLM:
    """Zipf-distributed token stream with next-token labels."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        B, S = cfg.global_batch, cfg.seq_len
        # zipf-ish: heavy head, long tail, clipped to vocab
        raw = rng.zipf(1.3, size=(B, S + 1))
        tokens = np.clip(raw, 1, cfg.vocab_size - 1).astype(np.int32)
        mask = (rng.random((B, S)) > cfg.mask_fraction).astype(np.float32)
        return {
            "tokens": tokens[:, :S],
            "labels": tokens[:, 1:],
            "loss_mask": mask,
        }

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
