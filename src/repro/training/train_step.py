"""Sharded training step: loss -> grad -> AdamW, with microbatch gradient
accumulation (``lax.scan``), remat-on-scan-body (set inside the models), and
configurable accumulator/moment dtypes (the practical memory lever for the
100B+ configs on 16 GB HBM chips).

The step is pure and jit-friendly; ``launch/train.py`` and ``launch/dryrun.py``
wrap it in ``jax.jit`` with in/out shardings from ``distributed.sharding``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig(lr=3e-4, weight_decay=0.1, grad_clip_norm=1.0)
    n_microbatches: int = 1
    accum_dtype: str = "float32"     # grad accumulator ("bfloat16" = compressed)
    moment_dtype: str = "float32"    # AdamW m/v ("bfloat16" for 100B+ configs)
    remat: bool = True


def init_train_state(model: Model, rng, cfg: TrainConfig) -> Tuple[Any, AdamWState]:
    params = model.init(rng)
    opt = adamw_init(params, moment_dtype=jnp.dtype(cfg.moment_dtype))
    return params, opt


def init_train_state_shape(model: Model, cfg: TrainConfig):
    """ShapeDtypeStructs of (params, opt_state) without allocation (dry-run)."""
    return jax.eval_shape(lambda r: init_train_state(model, r, cfg), jax.random.PRNGKey(0))


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    """(B, ...) -> (n, B//n, ...) for every batch leaf."""
    def r(x):
        B = x.shape[0]
        assert B % n == 0, f"microbatches {n} must divide global batch {B}"
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def loss_and_grad(model: Model, params, batch, cfg: TrainConfig):
    """Microbatched value_and_grad; grads averaged in ``accum_dtype``."""
    acc_dt = jnp.dtype(cfg.accum_dtype)

    def one(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, mb, remat=cfg.remat), has_aux=True
        )(params)
        return loss, metrics, grads

    if cfg.n_microbatches <= 1:
        loss, metrics, grads = one(params, batch)
        return loss, metrics, jax.tree.map(lambda g: g.astype(acc_dt), grads)

    n = cfg.n_microbatches
    mbs = _split_microbatches(batch, n)

    def body(acc, mb):
        loss_acc, grad_acc = acc
        loss, _, grads = one(params, mb)
        grad_acc = jax.tree.map(
            lambda a, g: a + g.astype(acc_dt) / n, grad_acc, grads
        )
        return (loss_acc + loss / n, grad_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), mbs)
    return loss, {"loss": loss}, grads


def train_step(model: Model, cfg: TrainConfig, params, opt_state: AdamWState, batch):
    """One optimizer step. Returns (params, opt_state, metrics)."""
    loss, metrics, grads = loss_and_grad(model, params, batch, cfg)
    params, opt_state, opt_metrics = adamw_update(cfg.optimizer, grads, opt_state, params)
    return params, opt_state, {**metrics, **opt_metrics}


def make_train_step(model: Model, cfg: TrainConfig):
    """Closure suitable for jax.jit(..., in_shardings=..., out_shardings=...)."""
    return partial(train_step, model, cfg)


def default_train_config(param_count: int, *, batch_shards: int, global_batch: int) -> TrainConfig:
    """Heuristic: more microbatches + compressed moments for bigger models.

    ``batch_shards`` = product of mesh axes the batch is sharded over; the
    microbatch count must keep each microbatch divisible by it.
    """
    per_shard = max(1, global_batch // batch_shards)
    if param_count < 5e9:
        n_micro = 1
    elif param_count < 60e9:
        n_micro = min(4, per_shard)
    else:
        n_micro = min(16, per_shard)
    big = param_count >= 60e9
    return TrainConfig(
        n_microbatches=max(1, n_micro),
        moment_dtype="bfloat16" if big else "float32",
        accum_dtype="float32",
        remat=True,
    )
