"""AdamW in pure JAX (no optax in this environment).

State and update are pytree-shaped like the params; used by both the model
training step (bf16/f32 params, f32 moments) and the LPRS latency predictor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float = 0.0     # 0 = off
    # optional linear warmup + cosine decay
    warmup_steps: int = 0
    total_steps: int = 0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def _schedule(cfg: AdamWConfig, step):
    lr = jnp.float32(cfg.lr)
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    if cfg.total_steps:
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
        )
        lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return lr


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    # moments may live in bf16 (memory lever for 100B+ configs); math in f32
    mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32)
                      + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
        state.mu, grads,
    )
    nu = jax.tree.map(
        lambda v, g: (b2 * v.astype(jnp.float32)
                      + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
        state.nu, grads,
    )
    mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
    nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))
    lr = _schedule(cfg, step.astype(jnp.float32))

    def upd(p, m, v):
        m, v = m.astype(jnp.float32), v.astype(jnp.float32)
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {"grad_norm": gnorm, "lr": lr}
