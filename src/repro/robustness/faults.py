"""Deterministic, seeded fault injection for the serving fleet.

A chaos run is a *plan*, not a dice roll: ``FaultPlan`` pins every fault to a
named site and an invocation ordinal, so the same plan replays the same
failure scenario bit-for-bit — which is what lets CI gate on exact recovery
behavior (zero lost requests, exact retry counts, survivor-output identity)
instead of "it usually survives".

Sites are threaded through the stack at the narrow waists where real
failures strike:

    replica_step_crash   ReplicaServer.step raises before touching the round
    slow_round_ms        a replica's step stalls (straggler / contended host)
    handoff_drop         the cross-replica KV transfer fails; payload lost
    handoff_stall        the staged record is never adopted (TTL must reap it)
    swap_gather_fail     the export gather cannot launch; decode colocates
    nan_logits           a request's device KV goes non-finite mid-decode
    host_oom             the host-side handoff store refuses the payload

Each site is counted per scope (globally, and per replica / per request),
and a spec fires when its scope's count reaches ``nth`` — so "crash
prefill0's 3rd step" and "drop request 7's handoff" are both one line.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

FAULT_SITES: Tuple[str, ...] = (
    "replica_step_crash",
    "slow_round_ms",
    "handoff_drop",
    "handoff_stall",
    "swap_gather_fail",
    "nan_logits",
    "host_oom",
)


class InjectedFault(RuntimeError):
    """Raised by a fault site standing in for a real infrastructure failure."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}" + (f": {detail}" if detail else ""))
        self.site = site


@dataclass(frozen=True)
class FaultSpec:
    """One planned fault: fire at the ``nth`` matching invocation of ``site``.

    ``replica``/``req_id`` narrow the scope (None matches anything); ``nth``
    counts invocations *within that scope*.  ``repeat`` keeps firing on every
    invocation at or past ``nth`` (a persistent failure rather than a blip).
    ``value`` carries the site parameter (ms for ``slow_round_ms``).
    """

    site: str
    nth: int = 1
    replica: Optional[str] = None
    req_id: Optional[int] = None
    value: float = 0.0
    repeat: bool = False

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.nth < 1:
            raise ValueError("nth is 1-based")


@dataclass(frozen=True)
class FaultPlan:
    specs: Tuple[FaultSpec, ...] = ()

    @classmethod
    def fuzz(
        cls,
        seed: int,
        *,
        n_faults: int = 3,
        sites: Tuple[str, ...] = FAULT_SITES,
        max_nth: int = 30,
        replicas: Tuple[str, ...] = (),
    ) -> "FaultPlan":
        """Deterministic fuzzer: the seed fully determines the plan."""
        rng = random.Random(seed)
        specs = []
        for _ in range(n_faults):
            site = rng.choice(sites)
            specs.append(FaultSpec(
                site=site,
                nth=rng.randint(1, max_nth),
                replica=(rng.choice(replicas)
                         if replicas and rng.random() < 0.5 else None),
                value=float(rng.randint(1, 20)) if site == "slow_round_ms" else 0.0,
                repeat=rng.random() < 0.25,
            ))
        return cls(specs=tuple(specs))


@dataclass
class FiredFault:
    site: str
    spec: FaultSpec
    count: int
    replica: Optional[str] = None
    req_id: Optional[int] = None


class FaultInjector:
    """Matches live invocations of fault sites against a plan.

    ``fire(site, ...)`` increments the site's counters and returns the spec
    that fires (at most one per invocation), recording it in ``self.fired``
    so tests and reports can reconcile injected vs survived faults.
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self._counts: Dict[Tuple, int] = {}
        self._consumed: set = set()
        self.fired: List[FiredFault] = []

    def _bump(self, key: Tuple) -> int:
        n = self._counts.get(key, 0) + 1
        self._counts[key] = n
        return n

    def fire(self, site: str, *, replica: Optional[str] = None,
             req_id: Optional[int] = None) -> Optional[FaultSpec]:
        n_global = self._bump((site, None, None))
        n_replica = self._bump((site, replica, None)) if replica is not None else 0
        n_req = self._bump((site, None, req_id)) if req_id is not None else 0
        for i, spec in enumerate(self.plan.specs):
            if spec.site != site or i in self._consumed:
                continue
            if spec.replica is not None and spec.replica != replica:
                continue
            if spec.req_id is not None and spec.req_id != req_id:
                continue
            if spec.req_id is not None:
                n = n_req
            elif spec.replica is not None:
                n = n_replica
            else:
                n = n_global
            if n == spec.nth or (spec.repeat and n >= spec.nth):
                if not spec.repeat:
                    self._consumed.add(i)
                self.fired.append(FiredFault(site, spec, n, replica, req_id))
                return spec
        return None

    def maybe_raise(self, site: str, **scope) -> None:
        spec = self.fire(site, **scope)
        if spec is not None:
            raise InjectedFault(site)

    def count(self, site: Optional[str] = None) -> int:
        if site is None:
            return len(self.fired)
        return sum(1 for f in self.fired if f.site == site)


@dataclass
class FailoverStats:
    """Mutable fleet-wide fault-tolerance counters (summarized into
    ``metrics.RobustnessReport`` at the end of a run)."""

    replicas_died: int = 0
    failovers: int = 0            # requests evacuated off dead replicas
    recovered_resumable: int = 0  # re-placed decode-resumable (zero re-prefill)
    requeued_reprefill: int = 0   # re-enqueued through the preempt() fold
    retries: int = 0              # total re-placement attempts
    shed_replica_failure: int = 0
    quarantined: int = 0          # non-finite requests terminated
    expired_handoffs: int = 0
    crash_unwinds: int = 0        # mid-round exceptions survived
    colocated_fallbacks: int = 0  # handoffs degraded to colocated decode
    events: List[str] = field(default_factory=list)

    def note(self, msg: str) -> None:
        self.events.append(msg)
