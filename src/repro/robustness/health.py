"""Replica health state machine: HEALTHY → SUSPECT → DEAD, with probation.

Driven entirely from the ``ReplicaServer.step`` status protocol — the same
strings the serve loops already use for quiesce detection — so health needs
no side channel: ``"error"`` (a caught step exception) counts against the
replica, any productive status (``"round"``/``"drained"``/``"finalized"``)
counts toward recovery, and a replica that keeps reporting ``"starved"``
while holding work is treated as missing progress.

Transitions:

    HEALTHY --[suspect_after consecutive errors]--> SUSPECT
    SUSPECT --[probation consecutive clean rounds]--> HEALTHY
    SUSPECT --[dead_after total consecutive errors]--> DEAD   (terminal)

SUSPECT replicas keep serving what they own but receive no new placements;
DEAD triggers router failover and is never revisited.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import List, Optional, Tuple

_PROGRESS = ("round", "drained", "finalized")


class HealthState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass(frozen=True)
class HealthConfig:
    suspect_after: int = 1   # consecutive step errors before SUSPECT
    dead_after: int = 3      # consecutive step errors before DEAD
    probation: int = 2       # consecutive clean productive steps to recover
    stall_after: int = 0     # consecutive starved-while-busy steps counted as
    #                          one error (0 disables missed-progress detection)

    def __post_init__(self):
        if not (1 <= self.suspect_after <= self.dead_after):
            raise ValueError("need 1 <= suspect_after <= dead_after")
        if self.probation < 1:
            raise ValueError("probation must be >= 1")


class ReplicaHealth:
    def __init__(self, cfg: Optional[HealthConfig] = None, name: str = "?"):
        self.cfg = cfg or HealthConfig()
        self.name = name
        self.state = HealthState.HEALTHY
        self.consecutive_errors = 0
        self.clean_streak = 0
        self.starved_streak = 0
        self.errors_total = 0
        self.transitions: List[Tuple[HealthState, HealthState]] = []
        self.last_error: Optional[BaseException] = None

    # -- observations --------------------------------------------------------
    def observe(self, status: str, *, busy: bool = False,
                error: Optional[BaseException] = None) -> HealthState:
        """Feed one step's status; returns the (possibly new) state."""
        if self.state is HealthState.DEAD:
            return self.state
        if status == "error":
            self.last_error = error
            self._on_error()
            return self.state
        if status in _PROGRESS:
            self.starved_streak = 0
            self._on_clean()
        elif status == "starved" and busy and self.cfg.stall_after > 0:
            self.starved_streak += 1
            if self.starved_streak >= self.cfg.stall_after:
                self.starved_streak = 0
                self._on_error()
        # "idle" is neutral: an empty replica is neither failing nor recovering
        return self.state

    def _on_error(self) -> None:
        self.errors_total += 1
        self.consecutive_errors += 1
        self.clean_streak = 0
        if self.consecutive_errors >= self.cfg.dead_after:
            self._transition(HealthState.DEAD)
        elif self.consecutive_errors >= self.cfg.suspect_after:
            self._transition(HealthState.SUSPECT)

    def _on_clean(self) -> None:
        self.consecutive_errors = 0
        if self.state is HealthState.SUSPECT:
            self.clean_streak += 1
            if self.clean_streak >= self.cfg.probation:
                self._transition(HealthState.HEALTHY)
        else:
            self.clean_streak = 0

    def _transition(self, to: HealthState) -> None:
        if to is self.state:
            return
        self.transitions.append((self.state, to))
        self.state = to
        self.clean_streak = 0

    # -- queries -------------------------------------------------------------
    @property
    def is_dead(self) -> bool:
        return self.state is HealthState.DEAD

    @property
    def accepts_work(self) -> bool:
        """Only HEALTHY replicas receive new placements; SUSPECT ones drain."""
        return self.state is HealthState.HEALTHY
