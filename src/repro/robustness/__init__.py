"""Fault tolerance for the serving fleet: seeded chaos + failover policy.

``RobustnessConfig`` is the single opt-in switch threaded through
``DisaggConfig.robustness`` and ``serve(..., robustness=...)``; with it left
``None`` every serve path is bit-identical to the fault-oblivious code.
"""
from dataclasses import dataclass, field
from typing import Optional

from repro.robustness.faults import (
    FAULT_SITES,
    FailoverStats,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    FiredFault,
    InjectedFault,
)
from repro.robustness.health import HealthConfig, HealthState, ReplicaHealth

__all__ = [
    "FAULT_SITES",
    "FailoverStats",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "FiredFault",
    "HealthConfig",
    "HealthState",
    "InjectedFault",
    "ReplicaHealth",
    "RobustnessConfig",
]


@dataclass
class RobustnessConfig:
    """Fault-tolerance policy for a fleet (or a single fault-tolerant server).

    ``max_retries`` bounds per-request re-placements after failures; past it
    the request sheds terminally with ``shed_reason="replica_failure"``.
    ``backoff_base_s`` delays the k-th retry by ``base * 2**(k-1)`` (0 means
    immediate re-placement, which keeps tiny test runs round-deterministic).
    ``handoff_ttl_s`` reaps staged-but-never-adopted handoff records.
    ``slo_capacity`` inflates the SLO tier's learned round cost on replica
    death so infeasible deadlines shed early instead of jittering.
    """

    health: HealthConfig = field(default_factory=HealthConfig)
    max_retries: int = 3
    backoff_base_s: float = 0.0
    handoff_ttl_s: Optional[float] = None
    slo_capacity: bool = True
    injector: Optional[FaultInjector] = None

    def make_injector(self) -> FaultInjector:
        if self.injector is None:
            self.injector = FaultInjector()
        return self.injector
