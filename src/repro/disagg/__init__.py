"""Disaggregated prefill/decode serving with cross-replica KV handoff.

``DisaggregatedRouter`` fronts a prefill pool and a decode pool of full
(scheduler, engine, KV pool) replicas; at prefill completion a request's KV
migrates through the host-side ``KVHandoffStore`` into a decode replica's
pool and resumes decode-only — zero re-prefilled tokens.  See
``repro.disagg.router`` for the lifecycle.
"""
from repro.disagg.handoff import (
    AlwaysHandoff,
    HandoffCostConfig,
    HandoffCostModel,
    HandoffStats,
    KVHandoffStore,
)
from repro.disagg.router import (
    DisaggConfig,
    DisaggResult,
    DisaggregatedRouter,
    build_disagg,
    serve_disagg,
)

__all__ = [
    "AlwaysHandoff",
    "DisaggConfig",
    "DisaggResult",
    "DisaggregatedRouter",
    "HandoffCostConfig",
    "HandoffCostModel",
    "HandoffStats",
    "KVHandoffStore",
    "build_disagg",
    "serve_disagg",
]
