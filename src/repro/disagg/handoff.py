"""Cross-replica KV handoff staging and the handoff/colocate cost model.

A disaggregated deployment splits prefill and decode onto separate engine
replicas (separate schedulers, slots, and KV pools).  The migration unit is
the host-side swap staging record the swap-preemption subsystem already
produces: at prefill completion the source engine gathers the request's pages
into a contiguous staging tensor (``JAXEngine.swap_out``), the async copy
drains on the pipelined one-round-late path, and the SWAPPED_OUT record —
payload, block-table shape, tenant, prompt hashes — is detached from the
source pool (``export_swap``) into the ``KVHandoffStore`` here, then adopted
by the chosen decode pool (``import_swap``).  The decode scheduler restores
it through the ordinary swap-in path, so the request resumes DECODE-ONLY:
zero prefill tokens are ever scheduled on the decode side.

While staged here, a request's KV lives in exactly ONE place: not the source
pool (export popped it), not the destination (import has not run).  The
store is therefore a first-class location in the exactly-one-location
invariant the property tests check.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass
class HandoffStats:
    staged: int = 0            # records entered the store
    delivered: int = 0         # records adopted by a decode pool
    dropped: int = 0           # killed mid-handoff (late stop): staging discarded
    expired: int = 0           # TTL reaped (never adopted within handoff_ttl_s)
    colocated: int = 0         # prefill-completions the cost model kept local
    bytes_moved: int = 0       # Σ payload bytes delivered across the link
    prefetched: int = 0        # records adopted while the source gather was
                               # still in flight (DisaggConfig.prefetch)
    # byte-exact staging ledger: put - take - drop - expire == resident
    put_bytes: int = 0
    taken_bytes: int = 0
    dropped_bytes: int = 0
    expired_bytes: int = 0
    resident_bytes: int = 0


@dataclass
class _Entry:
    rec: object
    reg: object
    src: str
    nbytes: int
    t_put: float


class KVHandoffStore:
    """Host-side staging ground for in-flight cross-replica handoffs.

    Entries are keyed by req_id and hold the exported ``(_SwapRecord,
    _Registration)`` pair plus the source replica's name.  The store owns the
    record between ``export_swap`` on the source pool and ``import_swap`` on
    the destination — the only window in which neither pool accounts for the
    request's KV.

    A record adopted by nobody (destination dead or stalled) would pin its
    host bytes forever; ``ttl_s`` bounds that: ``expire(now)`` reaps records
    older than the TTL and the byte ledger keeps ``put - take - drop -
    expire == resident`` exact at every step.

    With a ``host_tier`` attached (the managed host byte budget the KV pools
    stage against), every entry charges the SAME tier the pools do — a
    record in flight between replicas occupies host memory exactly once:
    ``export_swap`` releases the source pool's charge, ``put`` re-charges it
    here (net zero on a shared tier), ``take`` releases it for the
    destination's ``import_swap`` reservation.  Callers gate oversized puts
    with ``can_stage`` (colocate instead); ``charge`` itself asserts fit.
    """

    def __init__(self, ttl_s: Optional[float] = None, host_tier=None):
        self.ttl_s = ttl_s
        self.host = host_tier
        self._entries: Dict[int, _Entry] = {}
        self.stats = HandoffStats()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, req_id: int) -> bool:
        return req_id in self._entries

    def req_ids(self) -> List[int]:
        return list(self._entries)

    def src_of(self, req_id: int) -> Optional[str]:
        e = self._entries.get(req_id)
        return e.src if e is not None else None

    @staticmethod
    def record_bytes(rec, bytes_per_token: int = 0) -> int:
        """Host bytes a record occupies: the pool's exact stage-time charge
        when present (INT8 staging halves it), else the caller's full-width
        estimate (accounting-only records carry ``nbytes == 0``)."""
        nb = getattr(rec, "nbytes", 0)
        return nb if nb else rec.tokens * max(bytes_per_token, 0)

    def can_stage(self, nbytes: int) -> bool:
        """True when the host tier (if any) can take ``nbytes`` more — the
        router's colocate-fallback gate for oversized handoffs."""
        return self.host is None or self.host.can_fit(nbytes)

    def put(self, req_id: int, rec, reg, *, src: str = "?",
            bytes_per_token: int = 0, now: float = 0.0) -> None:
        assert req_id not in self._entries, f"req {req_id} already staged"
        nbytes = self.record_bytes(rec, bytes_per_token)
        if self.host is not None:
            self.host.charge(nbytes)   # asserts fit: callers gate can_stage
        self._entries[req_id] = _Entry(rec, reg, src, nbytes, now)
        self.stats.staged += 1
        self.stats.bytes_moved += nbytes
        self.stats.put_bytes += nbytes
        self.stats.resident_bytes += nbytes

    def take(self, req_id: int) -> Tuple[object, object]:
        """Hand the staged record to a destination pool (delivery)."""
        e = self._entries.pop(req_id)
        if self.host is not None:
            self.host.release(e.nbytes)
        self.stats.delivered += 1
        self.stats.taken_bytes += e.nbytes
        self.stats.resident_bytes -= e.nbytes
        return e.rec, e.reg

    def drop(self, req_id: int) -> None:
        """Discard a staged record whose request died mid-handoff."""
        e = self._entries.pop(req_id, None)
        if e is not None:
            if self.host is not None:
                self.host.release(e.nbytes)
            self.stats.dropped += 1
            self.stats.dropped_bytes += e.nbytes
            self.stats.resident_bytes -= e.nbytes

    def expire(self, now: float, ttl_s: Optional[float] = None) -> List[int]:
        """Reap records staged longer than the TTL; returns the reaped ids so
        the router can re-route their (no longer decode-resumable) requests."""
        ttl = self.ttl_s if ttl_s is None else ttl_s
        if ttl is None:
            return []
        reaped = [rid for rid, e in self._entries.items()
                  if now - e.t_put > ttl]
        for rid in reaped:
            e = self._entries.pop(rid)
            if self.host is not None:
                self.host.release(e.nbytes)
            self.stats.expired += 1
            self.stats.expired_bytes += e.nbytes
            self.stats.resident_bytes -= e.nbytes
        return reaped

    def staged_tokens(self, req_id: int) -> int:
        e = self._entries.get(req_id)
        return e.rec.tokens if e is not None else 0

    def check_invariants(self) -> None:
        """At quiesce the store must be empty (every exported record was
        delivered, dropped, or expired) and the byte ledger must balance."""
        s = self.stats
        assert (s.put_bytes - s.taken_bytes - s.dropped_bytes
                - s.expired_bytes == s.resident_bytes), (
            f"handoff byte ledger off: put={s.put_bytes} taken={s.taken_bytes}"
            f" dropped={s.dropped_bytes} expired={s.expired_bytes}"
            f" resident={s.resident_bytes}")
        assert s.resident_bytes == sum(e.nbytes for e in self._entries.values())
        assert not self._entries, (
            f"handoff store leaked staged records: {sorted(self._entries)}"
        )


@dataclass(frozen=True)
class HandoffCostConfig:
    """Deterministic per-request handoff-vs-colocate pricing.

    Handing off pays the KV transfer twice over the host link (source gather
    →host, host→destination scatter) plus fixed launch costs; staying
    colocated pays chunked-prefill interference on every remaining decode
    token — on a prefill-pool replica each decode round shares its batch with
    prefill chunks, the contention disaggregation exists to remove (the
    c_mix term of the serving cost model).
    """

    link_ms_per_mb: float = 0.05      # ~20 GB/s effective host link
    link_fixed_ms: float = 0.2        # per transfer launch (paid twice)
    # expected extra latency per decode token executed on a prefill-busy
    # replica: c_mix_ms x typical prefill tokens co-batched per round
    contention_ms_per_token: float = 0.004


class HandoffCostModel:
    """Decides, per prefill completion, whether exporting the KV beats
    keeping the decode colocated with the prefill pool."""

    def __init__(self, cfg: Optional[HandoffCostConfig] = None,
                 *, min_handoff_tokens: int = 0):
        self.cfg = cfg or HandoffCostConfig()
        self.min_handoff_tokens = min_handoff_tokens

    def handoff_cost_ms(self, kv_tokens: int, bytes_per_token: int) -> float:
        mb = kv_tokens * max(bytes_per_token, 0) / 2**20
        return 2 * (self.cfg.link_fixed_ms + self.cfg.link_ms_per_mb * mb)

    def colocated_cost_ms(self, remaining_decode_tokens: int) -> float:
        return self.cfg.contention_ms_per_token * max(remaining_decode_tokens, 0)

    def should_handoff(self, kv_tokens: int, remaining_decode_tokens: int,
                       bytes_per_token: int) -> bool:
        """Short prompts with short decodes stay colocated (moving their KV
        costs more than the contention it avoids); everything past the floor
        moves when the transfer amortizes over the remaining decode."""
        if kv_tokens < self.min_handoff_tokens:
            return False
        return (
            self.handoff_cost_ms(kv_tokens, bytes_per_token)
            <= self.colocated_cost_ms(remaining_decode_tokens)
        )


class AlwaysHandoff:
    """Degenerate policy: every prefill completion migrates (subject only to
    the token floor).  The parity tests use it so each request exercises the
    full export/import path."""

    def __init__(self, min_handoff_tokens: int = 0):
        self.min_handoff_tokens = min_handoff_tokens

    def should_handoff(self, kv_tokens: int, remaining_decode_tokens: int,
                       bytes_per_token: int) -> bool:
        return kv_tokens >= self.min_handoff_tokens
