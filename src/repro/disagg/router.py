"""Disaggregated prefill/decode serving: pool-of-replicas router with
cross-replica KV handoff.

Topology: ``n_prefill`` replicas admit and chunk-prefill new requests;
``n_decode`` replicas run steady-state decode.  Each replica is a full
(scheduler, engine, pool) stack driven as a ``ReplicaServer`` inside ONE
host loop — the router interleaves ``step()`` calls, so a single process
serves the whole fleet deterministically (the real deployment would run one
process per replica; nothing here depends on co-residency except the test
harness's determinism).

Handoff lifecycle (all on the pipelined one-round-late path):
  1. a request completes its prefill on a prefill replica; the round's
     ``on_prefill_complete`` hook asks the cost policy handoff-vs-colocate
  2. handoff: the source engine gathers the KV into a staging tensor
     (async device→host copy), the scheduler forgets the request
     (``export_request``), and the request parks WAITING/swapped
  3. when the copy drains (source drain finalizes it — the same drain that
     patches the request's first REAL token, so the decode side never stages
     a placeholder), the record leaves the source pool (``export_swap``)
     through the ``KVHandoffStore`` into the chosen decode pool
     (``import_swap``)
  4. the decode scheduler restores it via the ordinary swap-in path —
     decode-resumable, ``needs_replay`` staging the delivered first token —
     so ZERO prefill tokens are ever scheduled on the decode side
Placement is KV-locality- and load-aware: prefer the decode replica already
holding the longest shared prefix (``probe_prefix``), tie-break by
per-tenant then total outstanding work.  With fairness configured all
replicas share ONE VirtualTokenCounter, so a tenant's service aggregates
across the fleet — fanning out buys no extra share (anti-laundering).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.disagg.handoff import (
    AlwaysHandoff, HandoffCostConfig, HandoffCostModel, KVHandoffStore,
)
from repro.engine.engine import (
    EngineConfig, JAXEngine, ReplicaServer, compress_idle_gap,
)
from repro.engine.kv_cache import pool_for_model
from repro.engine.metrics import (
    LatencyReport, MemoryReport, SLOReport, summarize, summarize_memory,
    summarize_slo,
)


@dataclass
class DisaggConfig:
    n_prefill: int = 1
    n_decode: int = 1
    # prompts whose KV is shorter than this never migrate (floor under any
    # cost policy — moving a tiny prefix is pure overhead)
    min_handoff_tokens: int = 0
    # None: every completion past the floor migrates (AlwaysHandoff).  A
    # HandoffCostConfig prices transfer bytes against colocated contention
    # per request, keeping short-prompt/short-decode requests local.
    cost: Optional[HandoffCostConfig] = None
    # Prefetch: start the decode-side import while the source gather is still
    # draining — the record (still SWAPPING) moves source pool → store →
    # decode pool in the SAME pump that observed the prefill completion,
    # instead of parking in ``_pending`` until ``swap_ready``.  The decode
    # restore stays correct because ``_try_restore`` gates on ``swap_ready``,
    # which only flips once the source drain finalizes the (shared) record.
    # Late stops are unwound through ``ReplicaServer.on_stopped``.
    prefetch: bool = True


@dataclass
class DisaggResult:
    report: LatencyReport
    requests: List[Request]
    rounds: int                         # Σ scheduling rounds over the fleet
    wall_s: float
    outputs: Dict[int, List[int]]
    replica_rounds: List[int]           # per replica (prefill pool first)
    handoffs: int                       # records delivered across the link
    dropped_handoffs: int               # killed mid-handoff
    colocated: int                      # completions the cost policy kept local
    bytes_moved: int
    memory: Optional[List[MemoryReport]] = None
    slo: Optional[SLOReport] = None     # fleet-wide per-tenant attainment


class DisaggregatedRouter:
    """Fronts a prefill pool and a decode pool of ``ReplicaServer``s.

    Admission goes to the least-loaded prefill replica; handoffs drain
    through ``pump()``; ``serve_disagg`` drives the whole fleet.
    """

    def __init__(
        self,
        prefill: List[ReplicaServer],
        decode: List[ReplicaServer],
        cfg: Optional[DisaggConfig] = None,
        store: Optional[KVHandoffStore] = None,
    ):
        assert prefill, "need at least one prefill replica"
        assert decode, "need at least one decode replica"
        self.cfg = cfg or DisaggConfig()
        self.prefill = list(prefill)
        self.decode = list(decode)
        self.store = store if store is not None else KVHandoffStore()
        if self.cfg.cost is not None:
            self.policy = HandoffCostModel(
                self.cfg.cost, min_handoff_tokens=self.cfg.min_handoff_tokens)
        else:
            self.policy = AlwaysHandoff(self.cfg.min_handoff_tokens)
        # (request, source replica): exported, gather not yet host-resident
        self._pending: List[Tuple[Request, ReplicaServer]] = []
        for rs in self.prefill:
            rs.on_prefill_complete = self._maybe_handoff
            rs.on_stopped = self._on_source_stop

    @property
    def replicas(self) -> List[ReplicaServer]:
        return self.prefill + self.decode

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Admit to the least-loaded prefill replica (outstanding prefill +
        decode tokens; replica index breaks ties deterministically)."""
        best = min(
            range(len(self.prefill)),
            key=lambda i: (self.prefill[i].outstanding_work(), i),
        )
        self.prefill[best].submit(req)

    # -- handoff: source side --------------------------------------------------
    def _maybe_handoff(self, server: ReplicaServer, req: Request) -> None:
        """Prefill just completed on ``server``.  Export the KV unless the
        cost policy keeps the decode colocated."""
        kv_tokens = server.kv_pool.lens.get(req.req_id, 0)
        remaining = req.max_new_tokens - req.generated
        if not self.policy.should_handoff(
                kv_tokens, remaining, server.kv_pool.cfg.bytes_per_token):
            self.store.stats.colocated += 1
            return
        # gather + async device→host copy + slot release + SWAPPING record —
        # the engine still holds the slot here, so swap_out must precede the
        # scheduler export (which only drops bookkeeping, never pool state)
        server.engine.swap_out(req)
        server.sched.export_request(req)
        req.handoff()
        self._pending.append((req, server))

    # -- handoff: delivery -----------------------------------------------------
    def pump(self) -> int:
        """Move handoffs: source pool → store → chosen decode pool.

        Without prefetch a record waits in ``_pending`` until the source
        gather has drained (``swap_ready``); with prefetch it is exported
        immediately (``allow_inflight``) and adopted while still SWAPPING —
        the decode scheduler cannot restore it early because ``_try_restore``
        gates on ``swap_ready``, and the source drain finalizes the shared
        record in place wherever it lives.  A request that died while its
        copy was in flight (a value-dependent stop applied at the source
        drain — which already dropped the staging record via ``on_stop``) is
        discarded without touching any pool.  Returns handoffs delivered."""
        moved = 0
        still: List[Tuple[Request, ReplicaServer]] = []
        for req, src in self._pending:
            if req.state == RequestState.FINISHED:
                # killed mid-handoff: on_stop cleaned the source pool; make
                # the cleanup idempotent here in case the stop landed through
                # a path that did not (nothing may leak)
                src.kv_pool.drop_swap(req.req_id)
                src.kv_pool.release(req.req_id)
                self.store.stats.dropped += 1
                continue
            ready = src.kv_pool.swap_ready(req.req_id)
            if not ready and not self.cfg.prefetch:
                still.append((req, src))      # gather still in flight
                continue
            rec, reg = src.kv_pool.export_swap(
                req.req_id, allow_inflight=not ready)
            self.store.put(req.req_id, rec, reg, src=src.name,
                           bytes_per_token=src.kv_pool.cfg.bytes_per_token)
            dst = self._place(req)
            dst.adopt_handoff(req, *self.store.take(req.req_id))
            if not ready:
                self.store.stats.prefetched += 1
            moved += 1
        self._pending = still
        return moved

    def _on_source_stop(self, server: ReplicaServer, req: Request) -> None:
        """A late (value-dependent) stop landed at the source drain for a
        request whose staged KV may already have been PREFETCHED onward.
        ``on_stop`` cleaned the source pool; this hook chases the record to
        wherever the pump moved it.  A delivered-then-dropped record counts
        as dropped, not delivered, so ``delivered + dropped`` still equals
        the number of handoffs attempted."""
        rid = req.req_id
        if any(r.req_id == rid for r, _ in self._pending):
            return                    # not exported yet: pump() cleans it up
        if rid in self.store:
            self.store.drop(rid)      # exported, not yet adopted
            return
        for rs in self.decode:
            # adopted but not restored: staged record, no live block table
            if (rs.kv_pool.swap_state(rid) is not None
                    and not rs.kv_pool.tables.get(rid)):
                rs.sched.retract_handoff(req)
                self.store.stats.delivered -= 1
                self.store.stats.dropped += 1
                return

    def _place(self, req: Request) -> ReplicaServer:
        """Decode placement: longest resident shared prefix first (restoring
        next to cached KV makes future prefix hits free and keeps one
        tenant's conversation tree on one replica), then per-tenant
        outstanding work (spread a heavy tenant's decodes), then total load,
        then replica index."""
        def key(i: int):
            rs = self.decode[i]
            locality = rs.kv_pool.probe_prefix(req.prompt_tokens)
            return (-locality, rs.tenant_outstanding(req.tenant),
                    rs.outstanding_work(), i)
        return self.decode[min(range(len(self.decode)), key=key)]

    # -- invariants ------------------------------------------------------------
    def kv_locations(self, req_id: int) -> int:
        """How many places account for this request's KV right now: replica
        pools (live table or staged swap record) plus the handoff store.
        Live requests must always total exactly one."""
        n = 0
        for rs in self.replicas:
            pool = rs.kv_pool
            if pool.tables.get(req_id) or pool.swap_state(req_id) is not None:
                n += 1
        if req_id in self.store:
            n += 1
        return n

    def check_invariants(self) -> None:
        for rs in self.replicas:
            rs.kv_pool.check_invariants()
        self.store.check_invariants()


def build_disagg(
    model_cfg,
    *,
    cfg: Optional[DisaggConfig] = None,
    engine_cfg: Optional[EngineConfig] = None,
    sched_cfg: Optional[SchedulerConfig] = None,
    n_blocks: int = 512,
    block_size: int = 16,
    prefix_cache: bool = True,
    warmup: bool = False,
) -> DisaggregatedRouter:
    """Construct a whole fleet: per-replica engines (sharing ONE set of
    parameters — every replica must hold identical weights for a handoff to
    be exact), pools, and schedulers.  With fairness configured, one shared
    VirtualTokenCounter spans all schedulers (VTC anti-laundering)."""
    cfg = cfg or DisaggConfig()
    engine_cfg = engine_cfg or EngineConfig()
    sched_cfg = sched_cfg or SchedulerConfig()
    shared_vtc = None
    if sched_cfg.fairness is not None:
        from repro.tenancy import make_shared_vtc

        shared_vtc = make_shared_vtc(sched_cfg.fairness)
    params = None
    replicas: List[ReplicaServer] = []
    for i in range(cfg.n_prefill + cfg.n_decode):
        role = "prefill" if i < cfg.n_prefill else "decode"
        engine = JAXEngine(model_cfg, engine_cfg, params=params)
        params = engine.params             # replicas share one weight set
        pool = pool_for_model(
            model_cfg, n_blocks=n_blocks, block_size=block_size,
            enable_prefix_cache=prefix_cache,
        )
        sched = ChunkedPrefillScheduler(sched_cfg, kv_pool=pool,
                                        shared_vtc=shared_vtc)
        rs = ReplicaServer(sched, engine, kv_pool=pool,
                           name=f"{role}{i if role == 'prefill' else i - cfg.n_prefill}")
        if warmup:
            # handoff moves KV through the swap gather/scatter kernels on
            # every replica regardless of preemption mode — prewarm them
            engine.warmup(include_swap=True)
        replicas.append(rs)
    return DisaggregatedRouter(
        replicas[: cfg.n_prefill], replicas[cfg.n_prefill:], cfg,
    )


def serve_disagg(
    requests: List[Request],
    router: DisaggregatedRouter,
    *,
    max_rounds: int = 200_000,
) -> DisaggResult:
    """Drive the fleet to completion: admit arrivals to the prefill pool,
    round-robin one ``step()`` per replica per sweep, pump handoffs, and
    compress idle gaps exactly like single-replica ``serve`` (one shared
    clock across the fleet keeps aging/VTC comparable between replicas)."""
    pending = sorted(requests, key=lambda r: r.arrival_time)
    for r in pending:
        assert r.prompt_tokens is not None, "attach_prompt_tokens() first"
    next_i = 0
    t_start = time.perf_counter()
    for rs in router.replicas:
        rs.start(t_start)
    now = 0.0
    sweeps = 0
    while sweeps < max_rounds:
        sweeps += 1
        now = time.perf_counter() - t_start
        while next_i < len(pending) and pending[next_i].arrival_time <= now:
            router.submit(pending[next_i])
            next_i += 1
        statuses = [rs.step(now) for rs in router.replicas]
        moved = router.pump()
        progress = moved > 0 or any(
            s in ("round", "drained", "finalized") for s in statuses)
        # quiesce is judged AFTER the pump, against live replica state — a
        # status computed before the pump is stale the moment a handoff
        # lands: the delivering sweep read the decode replica as "idle", yet
        # it now holds restorable work
        if (not progress and not router._pending
                and not any(rs.busy() for rs in router.replicas)):
            if next_i >= len(pending):
                break
            compress_idle_gap(pending, next_i, now)
        elif not progress:
            time.sleep(0.0005)    # starved fleet: blocked on device/copies
    for rs in router.replicas:
        rs.finish()
    router.pump()                 # a finish() drain can land a final gather
    now = time.perf_counter() - t_start

    outputs: Dict[int, List[int]] = {}
    # prefill replicas first so a handed-off request's decode-side (complete)
    # output wins over the source's prefill-era placeholder entry
    for rs in router.prefill + router.decode:
        outputs.update(rs.outputs)
    stats = router.store.stats
    return DisaggResult(
        report=summarize(requests, makespan=now),
        requests=requests,
        rounds=sum(rs.rounds for rs in router.replicas),
        wall_s=now,
        outputs=outputs,
        replica_rounds=[rs.rounds for rs in router.replicas],
        handoffs=stats.delivered,
        dropped_handoffs=stats.dropped,
        colocated=stats.colocated,
        bytes_moved=stats.bytes_moved,
        memory=[
            summarize_memory(rs.kv_pool, rs.sched.stats)
            for rs in router.replicas
        ],
        # attainment is a property of the request set, not a replica: one
        # fleet-wide report against the prefill pool's registry (all replicas
        # share the tenant specs via the common FairnessConfig)
        slo=(
            summarize_slo(requests, router.prefill[0].sched.fairness.registry)
            if router.prefill and router.prefill[0].sched.fairness is not None
            else None
        ),
    )
