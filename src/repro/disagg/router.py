"""Disaggregated prefill/decode serving: pool-of-replicas router with
cross-replica KV handoff.

Topology: ``n_prefill`` replicas admit and chunk-prefill new requests;
``n_decode`` replicas run steady-state decode.  Each replica is a full
(scheduler, engine, pool) stack driven as a ``ReplicaServer`` inside ONE
host loop — the router interleaves ``step()`` calls, so a single process
serves the whole fleet deterministically (the real deployment would run one
process per replica; nothing here depends on co-residency except the test
harness's determinism).

Handoff lifecycle (all on the pipelined one-round-late path):
  1. a request completes its prefill on a prefill replica; the round's
     ``on_prefill_complete`` hook asks the cost policy handoff-vs-colocate
  2. handoff: the source engine gathers the KV into a staging tensor
     (async device→host copy), the scheduler forgets the request
     (``export_request``), and the request parks WAITING/swapped
  3. when the copy drains (source drain finalizes it — the same drain that
     patches the request's first REAL token, so the decode side never stages
     a placeholder), the record leaves the source pool (``export_swap``)
     through the ``KVHandoffStore`` into the chosen decode pool
     (``import_swap``)
  4. the decode scheduler restores it via the ordinary swap-in path —
     decode-resumable, ``needs_replay`` staging the delivered first token —
     so ZERO prefill tokens are ever scheduled on the decode side
Placement is KV-locality- and load-aware: prefer the decode replica already
holding the longest shared prefix (``probe_prefix``), tie-break by
per-tenant then total outstanding work.  With fairness configured all
replicas share ONE VirtualTokenCounter, so a tenant's service aggregates
across the fleet — fanning out buys no extra share (anti-laundering).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.request import Request, RequestState
from repro.core.scheduler import ChunkedPrefillScheduler, SchedulerConfig
from repro.disagg.handoff import (
    AlwaysHandoff, HandoffCostConfig, HandoffCostModel, KVHandoffStore,
)
from repro.engine.engine import (
    EngineConfig, JAXEngine, ReplicaServer, compress_idle_gap,
)
from repro.engine.kv_cache import pool_for_model
from repro.engine.metrics import (
    LatencyReport, MemoryReport, RobustnessReport, SLOReport, summarize,
    summarize_memory, summarize_robustness, summarize_slo,
)
from repro.robustness import FailoverStats, ReplicaHealth, RobustnessConfig


@dataclass
class DisaggConfig:
    n_prefill: int = 1
    n_decode: int = 1
    # prompts whose KV is shorter than this never migrate (floor under any
    # cost policy — moving a tiny prefix is pure overhead)
    min_handoff_tokens: int = 0
    # None: every completion past the floor migrates (AlwaysHandoff).  A
    # HandoffCostConfig prices transfer bytes against colocated contention
    # per request, keeping short-prompt/short-decode requests local.
    cost: Optional[HandoffCostConfig] = None
    # Prefetch: start the decode-side import while the source gather is still
    # draining — the record (still SWAPPING) moves source pool → store →
    # decode pool in the SAME pump that observed the prefill completion,
    # instead of parking in ``_pending`` until ``swap_ready``.  The decode
    # restore stays correct because ``_try_restore`` gates on ``swap_ready``,
    # which only flips once the source drain finalizes the (shared) record.
    # Late stops are unwound through ``ReplicaServer.on_stopped``.
    prefetch: bool = True
    # Fault tolerance: None (default) leaves every path bit-identical to the
    # fault-oblivious router.  Set, it wires replica health tracking, crash
    # unwinding, failover re-placement with bounded retries, handoff TTLs,
    # and (optionally) a seeded chaos injector into the fleet.
    robustness: Optional[RobustnessConfig] = None


@dataclass
class DisaggResult:
    report: LatencyReport
    requests: List[Request]
    rounds: int                         # Σ scheduling rounds over the fleet
    wall_s: float
    outputs: Dict[int, List[int]]
    replica_rounds: List[int]           # per replica (prefill pool first)
    handoffs: int                       # records delivered across the link
    dropped_handoffs: int               # killed mid-handoff
    colocated: int                      # completions the cost policy kept local
    bytes_moved: int
    memory: Optional[List[MemoryReport]] = None
    slo: Optional[SLOReport] = None     # fleet-wide per-tenant attainment
    robustness: Optional[RobustnessReport] = None   # failover/chaos summary


class DisaggregatedRouter:
    """Fronts a prefill pool and a decode pool of ``ReplicaServer``s.

    Admission goes to the least-loaded prefill replica; handoffs drain
    through ``pump()``; ``serve_disagg`` drives the whole fleet.
    """

    def __init__(
        self,
        prefill: List[ReplicaServer],
        decode: List[ReplicaServer],
        cfg: Optional[DisaggConfig] = None,
        store: Optional[KVHandoffStore] = None,
    ):
        assert prefill, "need at least one prefill replica"
        assert decode, "need at least one decode replica"
        self.cfg = cfg or DisaggConfig()
        self.prefill = list(prefill)
        self.decode = list(decode)
        self.store = store if store is not None else KVHandoffStore()
        if self.cfg.cost is not None:
            self.policy = HandoffCostModel(
                self.cfg.cost, min_handoff_tokens=self.cfg.min_handoff_tokens)
        else:
            self.policy = AlwaysHandoff(self.cfg.min_handoff_tokens)
        # (request, source replica): exported, gather not yet host-resident
        self._pending: List[Tuple[Request, ReplicaServer]] = []
        for rs in self.prefill:
            rs.on_prefill_complete = self._maybe_handoff
            rs.on_stopped = self._on_source_stop

        # -- fault tolerance (cfg.robustness) ---------------------------------
        rcfg = self.cfg.robustness
        self.rstats = FailoverStats()
        self.health: Dict[str, ReplicaHealth] = {}
        self.dead: set = set()                    # replica names declared DEAD
        self._retries: Dict[int, int] = {}        # req_id -> failover retries
        self._retry_queue: List[Tuple[float, Request]] = []   # (ready_at, req)
        self._stalled: Dict[int, Request] = {}    # staged-in-store, stalled
        self._handoff_src: Dict[int, str] = {}    # rid -> source of a prefetch
        self.injector = None
        if rcfg is not None:
            self.injector = rcfg.make_injector()
            for rs in self.replicas:
                rs.injector = self.injector
                rs.fault_tolerant = True
                rs.max_crash_retries = rcfg.max_retries
                self.health[rs.name] = ReplicaHealth(rcfg.health, rs.name)
            if rcfg.handoff_ttl_s is not None and self.store.ttl_s is None:
                self.store.ttl_s = rcfg.handoff_ttl_s

    @property
    def replicas(self) -> List[ReplicaServer]:
        return self.prefill + self.decode

    @property
    def live_prefill(self) -> List[ReplicaServer]:
        return [rs for rs in self.prefill if rs.name not in self.dead]

    @property
    def live_decode(self) -> List[ReplicaServer]:
        return [rs for rs in self.decode if rs.name not in self.dead]

    @property
    def live_replicas(self) -> List[ReplicaServer]:
        return [rs for rs in self.replicas if rs.name not in self.dead]

    def pending_work(self) -> bool:
        """Router-held work a quiesce check must wait on: in-flight exports,
        stalled store entries (their TTL will reap them), delayed retries."""
        return bool(self._pending or self._stalled or self._retry_queue)

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Admit to the least-loaded LIVE prefill replica (outstanding
        prefill + decode tokens; replica index breaks ties
        deterministically).  Graceful degradation: with the prefill pool
        emptied by failures, new work colocates on the decode pool; with the
        whole fleet dead it sheds terminally."""
        pool = self.live_prefill
        if not pool:
            pool = self.live_decode
            if not pool:
                self._shed_failed(req)
                return
            self.rstats.colocated_fallbacks += 1
        best = min(range(len(pool)),
                   key=lambda i: (pool[i].outstanding_work(), i))
        pool[best].submit(req)

    # -- handoff: source side --------------------------------------------------
    def _maybe_handoff(self, server: ReplicaServer, req: Request) -> None:
        """Prefill just completed on ``server``.  Export the KV unless the
        cost policy keeps the decode colocated."""
        kv_tokens = server.kv_pool.lens.get(req.req_id, 0)
        remaining = req.max_new_tokens - req.generated
        if not self.policy.should_handoff(
                kv_tokens, remaining, server.kv_pool.cfg.bytes_per_token):
            self.store.stats.colocated += 1
            return
        if not self.live_decode:
            # graceful degradation: the decode pool is gone — keep the decode
            # colocated on the prefill replica instead of exporting into a
            # store nobody can adopt from
            self.store.stats.colocated += 1
            self.rstats.colocated_fallbacks += 1
            return
        if (self.injector is not None and self.injector.fire(
                "swap_gather_fail", replica=server.name,
                req_id=req.req_id) is not None):
            # the gather failed BEFORE any pool state moved: cleanest
            # possible fallback — the request simply decodes colocated
            self.store.stats.colocated += 1
            self.rstats.colocated_fallbacks += 1
            self.rstats.note(f"swap_gather_fail req {req.req_id}: colocated")
            return
        if (server.kv_pool.swap_state(req.req_id) is not None
                or not server.kv_pool.host_can_stage(kv_tokens)
                or not self._store_can_stage(server, kv_tokens)):
            # no host budget for the transfer (the tier is pinned by bytes
            # this pool cannot evict) or a stale staging record: decode
            # colocated — no tier reservation may ever assert
            self.store.stats.colocated += 1
            self.rstats.colocated_fallbacks += 1
            return
        # gather + async device→host copy + slot release + SWAPPING record —
        # the engine still holds the slot here, so swap_out must precede the
        # scheduler export (which only drops bookkeeping, never pool state)
        server.engine.swap_out(req)
        server.sched.export_request(req)
        req.handoff()
        self._pending.append((req, server))

    def _store_can_stage(self, src: ReplicaServer, kv_tokens: int) -> bool:
        """True when the handoff store can charge this record's bytes.  On a
        tier SHARED with the source pool the move is net zero (``export``
        releases exactly what ``put`` charges), so only a store with its own
        budget needs the headroom check."""
        if self.store.host is None or self.store.host is src.kv_pool.host:
            return True
        return self.store.can_stage(src.kv_pool.host_bytes_for(kv_tokens))

    # -- handoff: delivery -----------------------------------------------------
    def pump(self, now: float = 0.0) -> int:
        """Move handoffs: source pool → store → chosen decode pool.

        Without prefetch a record waits in ``_pending`` until the source
        gather has drained (``swap_ready``); with prefetch it is exported
        immediately (``allow_inflight``) and adopted while still SWAPPING —
        the decode scheduler cannot restore it early because ``_try_restore``
        gates on ``swap_ready``, and the source drain finalizes the shared
        record in place wherever it lives.  A request that died while its
        copy was in flight (a value-dependent stop applied at the source
        drain — which already dropped the staging record via ``on_stop``) is
        discarded without touching any pool.

        With robustness configured the pump also drains the failover retry
        queue (backoff expiry), reaps TTL-expired store entries (stalled
        handoffs fall back to re-prefill), and fires the in-transfer chaos
        sites: ``handoff_drop`` (payload lost → re-prefill), ``handoff_stall``
        (record parks in the store until the TTL reaps it), and ``host_oom``
        (no staging memory → the decode stays colocated on the source).
        Returns handoffs delivered."""
        # delayed failover retries whose backoff elapsed re-enter the fleet
        if self._retry_queue:
            due = [r for t, r in self._retry_queue if t <= now]
            self._retry_queue = [(t, r) for t, r in self._retry_queue
                                 if t > now]
            for req in due:
                self._submit_requeued(req)
        # TTL: staged-but-never-adopted records are reaped; their requests
        # lose decode-resumability and retry through the re-prefill path
        for rid in self.store.expire(now):
            self.rstats.expired_handoffs += 1
            req = self._stalled.pop(rid, None)
            if req is not None and req.state != RequestState.FINISHED:
                self.rstats.note(f"handoff of req {rid} expired: re-prefill")
                self._requeue(req, now)
        if self._stalled and self.store.ttl_s is None:
            # no TTL configured to ever reap a stalled record: fail fast to
            # re-prefill instead of wedging the fleet behind it
            for rid, req in list(self._stalled.items()):
                del self._stalled[rid]
                self.store.drop(rid)
                self._requeue(req, now)

        moved = 0
        still: List[Tuple[Request, ReplicaServer]] = []
        for req, src in self._pending:
            if req.state == RequestState.FINISHED:
                # killed mid-handoff: on_stop cleaned the source pool; make
                # the cleanup idempotent here in case the stop landed through
                # a path that did not (nothing may leak)
                src.kv_pool.drop_swap(req.req_id)
                src.kv_pool.release(req.req_id)
                self.store.stats.dropped += 1
                continue
            if src.kv_pool.swap_state(req.req_id) is None:
                # the host tier demoted the record while the handoff was
                # pending: its KV is gone from every tier — re-prefill on a
                # survivor (a recompute, never a leak)
                self.store.stats.dropped += 1
                self.rstats.note(
                    f"handoff req {req.req_id} host-demoted: re-prefill")
                self._requeue(req, now)
                continue
            ready = src.kv_pool.swap_ready(req.req_id)
            if not ready and not self.cfg.prefetch:
                still.append((req, src))      # gather still in flight
                continue
            if (self.injector is not None and self.injector.fire(
                    "handoff_drop", replica=src.name,
                    req_id=req.req_id) is not None):
                # the staged payload was lost in transfer: discard it and
                # fall back to re-prefill on a survivor (bounded retries)
                src.kv_pool.drop_swap(req.req_id)
                src.kv_pool.release(req.req_id)
                self.store.stats.dropped += 1
                self.rstats.note(f"handoff_drop req {req.req_id}: re-prefill")
                self._requeue(req, now)
                continue
            if (self.injector is not None and self.injector.fire(
                    "host_oom", replica=src.name,
                    req_id=req.req_id) is not None):
                # no host staging memory for the transfer: the record stays
                # in the source pool and the request decodes colocated —
                # still decode-resumable, zero re-prefill
                req.handoffs -= 1            # never left the replica
                src.sched.submit_handoff(req)
                self.store.stats.colocated += 1
                self.rstats.colocated_fallbacks += 1
                self.rstats.note(f"host_oom req {req.req_id}: colocated")
                continue
            if not self._store_can_stage(
                    src, src.kv_pool.swap_tokens(req.req_id)):
                # the store's private budget filled while the gather drained:
                # keep the decode colocated — still decode-resumable from
                # the source pool's record
                req.handoffs -= 1
                src.sched.submit_handoff(req)
                self.store.stats.colocated += 1
                self.rstats.colocated_fallbacks += 1
                continue
            rec, reg = src.kv_pool.export_swap(
                req.req_id, allow_inflight=not ready)
            self.store.put(req.req_id, rec, reg, src=src.name,
                           bytes_per_token=src.kv_pool.cfg.bytes_per_token,
                           now=now)
            if (self.injector is not None and self.injector.fire(
                    "handoff_stall", replica=src.name,
                    req_id=req.req_id) is not None):
                # the transfer wedged mid-flight: the record sits in the
                # store until the TTL reaps it (or the run quiesces it)
                self._stalled[req.req_id] = req
                self.rstats.note(f"handoff_stall req {req.req_id}: parked")
                continue
            dst = self._place(req)
            if not ready:
                self.store.stats.prefetched += 1
                # a prefetched record's payload still lives on the source
                # engine: remember the dependency so source death retracts it
                self._handoff_src[req.req_id] = src.name
            dst.adopt_handoff(req, *self.store.take(req.req_id))
            moved += 1
        self._pending = still
        return moved

    def _on_source_stop(self, server: ReplicaServer, req: Request) -> None:
        """A late (value-dependent) stop landed at the source drain for a
        request whose staged KV may already have been PREFETCHED onward.
        ``on_stop`` cleaned the source pool; this hook chases the record to
        wherever the pump moved it.  A delivered-then-dropped record counts
        as dropped, not delivered, so ``delivered + dropped`` still equals
        the number of handoffs attempted."""
        rid = req.req_id
        if any(r.req_id == rid for r, _ in self._pending):
            return                    # not exported yet: pump() cleans it up
        if rid in self.store:
            self.store.drop(rid)      # exported, not yet adopted
            return
        for rs in self.decode:
            # adopted but not restored: staged record, no live block table
            if (rs.kv_pool.swap_state(rid) is not None
                    and not rs.kv_pool.tables.get(rid)):
                rs.sched.retract_handoff(req)
                self.store.stats.delivered -= 1
                self.store.stats.dropped += 1
                return

    def _place(self, req: Request,
               candidates: Optional[List[ReplicaServer]] = None
               ) -> ReplicaServer:
        """Decode placement: longest resident shared prefix first (restoring
        next to cached KV makes future prefix hits free and keeps one
        tenant's conversation tree on one replica), then per-tenant
        outstanding work (spread a heavy tenant's decodes), then total load,
        then replica index.  Only LIVE replicas are ever candidates."""
        pool = candidates if candidates is not None else self.live_decode
        assert pool, "placement over an empty replica pool"

        def key(i: int):
            rs = pool[i]
            locality = rs.kv_pool.probe_prefix(req.prompt_tokens)
            return (-locality, rs.tenant_outstanding(req.tenant),
                    rs.outstanding_work(), i)
        return pool[min(range(len(pool)), key=key)]

    # -- fault tolerance -------------------------------------------------------
    def after_step(self, rs: ReplicaServer, status: str, now: float) -> None:
        """Feed one step's status into the replica's health machine; a
        HEALTHY/SUSPECT → DEAD transition triggers failover immediately."""
        h = self.health.get(rs.name)
        if h is None or h.is_dead:
            return
        err = rs.last_error if status == "error" else None
        h.observe(status, busy=rs.busy(), error=err)
        if h.is_dead:
            self.fail_replica(rs, now)

    def fail_replica(self, rs: ReplicaServer, now: float) -> None:
        """Replica death: evacuate everything it owns onto survivors.

        Durability model: death means the replica's device/serve loop is
        gone, NOT the host's memory — host-resident staging payloads
        (``swap_ready`` records) survive and re-place decode-resumable with
        ZERO re-prefilled tokens.  A still-SWAPPING record's payload needed
        the dead engine's drain to materialize, so it is lost: its request
        retries through the ``preempt()`` re-prefill fold (at-most-once
        delivery — tokens already streamed are folded, never re-emitted).
        Every retry is bounded by ``max_retries``; past it the request sheds
        terminally with ``shed_reason="replica_failure"``."""
        if rs.name in self.dead:
            return
        alive_before = len(self.live_replicas)
        self.dead.add(rs.name)
        self.rstats.replicas_died += 1
        h = self.health.get(rs.name)
        self.rstats.note(
            f"{rs.name} declared dead"
            + (f" ({h.last_error!r})" if h is not None and h.last_error else "")
        )

        # 1. unwind any torn round the dead replica still holds (rounds
        # dispatched or mid-drain when health gave up on it)
        if (rs.inflight is not None or rs._draining is not None
                or rs._pending_batch is not None):
            rs._crash_cleanup()

        pool = rs.kv_pool
        bpt = pool.cfg.bytes_per_token

        # 2. in-flight exports sourced at the dead replica
        still: List[Tuple[Request, ReplicaServer]] = []
        for req, src in self._pending:
            if src is not rs:
                still.append((req, src))
                continue
            if req.state == RequestState.FINISHED:
                pool.drop_swap(req.req_id)
                pool.release(req.req_id)
                self.store.stats.dropped += 1
                continue
            if pool.swap_ready(req.req_id):
                rec, reg = pool.export_swap(req.req_id)
                self._replace_staged(req, rec, reg, now, bpt)
            else:
                pool.drop_swap(req.req_id)
                pool.release(req.req_id)
                self.store.stats.dropped += 1
                self._requeue(req, now)
        self._pending = still

        # 3. every request the dead scheduler still owns: staged-and-ready
        # records re-place decode-resumable; everything else re-prefills
        owned = list(rs.sched.queue.requests()) + list(
            rs.sched._decoding.values())
        for req in owned:
            if req.state == RequestState.FINISHED:
                continue
            if pool.swap_ready(req.req_id):
                rs.sched.export_request(req)
                rec, reg = pool.export_swap(req.req_id)
                self._replace_staged(req, rec, reg, now, bpt)
            else:
                rs.sched.evict_request(req)
                self._requeue(req, now)

        # 4. live replicas holding PREFETCHED records whose payload needed
        # the dead source engine's drain: the gather will never finalize, so
        # retract the adoption and re-prefill
        for dec in self.live_replicas:
            for rid, src_name in list(self._handoff_src.items()):
                if src_name != rs.name:
                    continue
                if (dec.kv_pool.swap_state(rid) is None
                        or dec.kv_pool.swap_ready(rid)
                        or dec.kv_pool.tables.get(rid)):
                    continue
                victim = next((r for r in dec.sched.queue.requests()
                               if r.req_id == rid), None)
                if victim is None:
                    continue
                dec.sched.retract_handoff(victim)
                self._handoff_src.pop(rid, None)
                self.store.stats.delivered -= 1
                self.store.stats.dropped += 1
                self._requeue(victim, now)

        # 5. capacity loss: surviving schedulers' SLO trackers learn the
        # slower per-round cost NOW instead of over the EWMA window
        rcfg = self.cfg.robustness
        alive_after = max(len(self.live_replicas), 1)
        if rcfg is not None and rcfg.slo_capacity and alive_after:
            factor = alive_before / alive_after
            for live in self.live_replicas:
                if live.sched.slo is not None:
                    live.sched.slo.scale_round_cost(factor)

    def _replace_staged(self, req: Request, rec, reg, now: float,
                        bpt: int) -> None:
        """Re-place a recovered (host-resident) staging record on a
        survivor: the request resumes decode-resumable — zero re-prefilled
        tokens — through the ordinary handoff adopt/restore path."""
        self.store.put(req.req_id, rec, reg, src="failover",
                       bytes_per_token=bpt, now=now)
        if req.remaining_prefill > 0:
            candidates = self.live_prefill or self.live_decode
        else:
            candidates = self.live_decode or self.live_prefill
        if not candidates:
            self.store.drop(req.req_id)
            self._shed_failed(req)
            return
        dst = self._place(req, candidates)
        dst.adopt_handoff(req, *self.store.take(req.req_id))
        self.rstats.failovers += 1
        self.rstats.recovered_resumable += 1

    def _requeue(self, req: Request, now: float) -> None:
        """Re-prefill retry path: fold delivered tokens into the prompt
        (at-most-once delivery — greedy recompute regenerates the identical
        continuation) and retry on a survivor, bounded by ``max_retries``
        with exponential backoff."""
        rcfg = self.cfg.robustness
        k = self._retries.get(req.req_id, 0) + 1
        self._retries[req.req_id] = k
        self.rstats.retries += 1
        if rcfg is not None and k > rcfg.max_retries:
            self._shed_failed(req)
            return
        req.preempt()
        self.rstats.requeued_reprefill += 1
        base = rcfg.backoff_base_s if rcfg is not None else 0.0
        if base > 0:
            self._retry_queue.append((now + base * (2 ** (k - 1)), req))
        else:
            self._submit_requeued(req)

    def _submit_requeued(self, req: Request) -> None:
        """Route a retry to the least-loaded live prefill replica (falling
        back to the decode pool under degradation).  Admission is NOT re-run
        — the request was admitted once; a failure must not double-charge
        its tenant's token bucket."""
        targets = self.live_prefill
        if not targets:
            targets = self.live_decode
            if not targets:
                self._shed_failed(req)
                return
            self.rstats.colocated_fallbacks += 1
        best = min(targets, key=lambda rs: (rs.outstanding_work(), rs.name))
        best.kv_pool.register_request(
            req.req_id, tenant=req.tenant,
            prompt_tokens=req.prompt_tokens, prompt_len=req.prompt_len,
        )
        best.sched.requeue_failed(req)
        self.rstats.failovers += 1

    def _shed_failed(self, req: Request) -> None:
        """Terminal shed after retries (or the whole fleet) are exhausted:
        the request ends FINISHED with ``shed_reason="replica_failure"`` —
        counted, never silently lost."""
        req.shed_reason = "replica_failure"
        req.state = RequestState.FINISHED
        req.swapped = False
        self.rstats.shed_replica_failure += 1
        self.rstats.note(f"req {req.req_id} shed after replica failures")

    # -- invariants ------------------------------------------------------------
    def kv_locations(self, req_id: int) -> int:
        """How many places account for this request's KV right now: replica
        pools (live table or staged swap record) plus the handoff store.
        Live requests must always total exactly one."""
        n = 0
        for rs in self.replicas:
            pool = rs.kv_pool
            if pool.tables.get(req_id) or pool.swap_state(req_id) is not None:
                n += 1
        if req_id in self.store:
            n += 1
        return n

    def check_invariants(self) -> None:
        for rs in self.replicas:
            rs.kv_pool.check_invariants()
        self.store.check_invariants()


def build_disagg(
    model_cfg,
    *,
    cfg: Optional[DisaggConfig] = None,
    engine_cfg: Optional[EngineConfig] = None,
    sched_cfg: Optional[SchedulerConfig] = None,
    n_blocks: int = 512,
    block_size: int = 16,
    prefix_cache: bool = True,
    warmup: bool = False,
    host_max_bytes: Optional[int] = None,
    host_kv_dtype: str = "auto",
) -> DisaggregatedRouter:
    """Construct a whole fleet: per-replica engines (sharing ONE set of
    parameters — every replica must hold identical weights for a handoff to
    be exact), pools, and schedulers.  With fairness configured, one shared
    VirtualTokenCounter spans all schedulers (VTC anti-laundering).

    ``host_max_bytes`` caps ONE host tier shared by every replica pool AND
    the handoff store — in-flight records charge the same budget staged
    ones do, so the fleet's host footprint is bounded end to end.
    ``host_kv_dtype="int8"`` stages quantized pages everywhere (handoffs
    ride the fused quantizing gather / dequantizing scatter)."""
    cfg = cfg or DisaggConfig()
    engine_cfg = engine_cfg or EngineConfig()
    sched_cfg = sched_cfg or SchedulerConfig()
    shared_vtc = None
    if sched_cfg.fairness is not None:
        from repro.tenancy import make_shared_vtc

        shared_vtc = make_shared_vtc(sched_cfg.fairness)
    tier = None
    if host_max_bytes is not None:
        from repro.engine.kv_cache import HostTier

        tier = HostTier(host_max_bytes)
    params = None
    replicas: List[ReplicaServer] = []
    for i in range(cfg.n_prefill + cfg.n_decode):
        role = "prefill" if i < cfg.n_prefill else "decode"
        engine = JAXEngine(model_cfg, engine_cfg, params=params)
        params = engine.params             # replicas share one weight set
        pool = pool_for_model(
            model_cfg, n_blocks=n_blocks, block_size=block_size,
            enable_prefix_cache=prefix_cache, host_kv_dtype=host_kv_dtype,
        )
        if tier is not None:
            pool.attach_host_tier(tier)
        sched = ChunkedPrefillScheduler(sched_cfg, kv_pool=pool,
                                        shared_vtc=shared_vtc)
        rs = ReplicaServer(sched, engine, kv_pool=pool,
                           name=f"{role}{i if role == 'prefill' else i - cfg.n_prefill}")
        if warmup:
            # handoff moves KV through the swap gather/scatter kernels on
            # every replica regardless of preemption mode — prewarm them
            engine.warmup(include_swap=True)
        replicas.append(rs)
    return DisaggregatedRouter(
        replicas[: cfg.n_prefill], replicas[cfg.n_prefill:], cfg,
        store=KVHandoffStore(host_tier=tier) if tier is not None else None,
    )


def serve_disagg(
    requests: List[Request],
    router: DisaggregatedRouter,
    *,
    max_rounds: int = 200_000,
) -> DisaggResult:
    """Drive the fleet to completion: admit arrivals to the prefill pool,
    round-robin one ``step()`` per replica per sweep, pump handoffs, and
    compress idle gaps exactly like single-replica ``serve`` (one shared
    clock across the fleet keeps aging/VTC comparable between replicas)."""
    pending = sorted(requests, key=lambda r: r.arrival_time)
    for r in pending:
        assert r.prompt_tokens is not None, "attach_prompt_tokens() first"
    next_i = 0
    t_start = time.perf_counter()
    for rs in router.replicas:
        rs.start(t_start)
    now = 0.0
    sweeps = 0
    while sweeps < max_rounds:
        sweeps += 1
        now = time.perf_counter() - t_start
        while next_i < len(pending) and pending[next_i].arrival_time <= now:
            router.submit(pending[next_i])
            next_i += 1
        statuses = []
        for rs in router.replicas:
            if rs.name in router.dead:
                continue
            status = rs.step(now)
            statuses.append(status)
            router.after_step(rs, status, now)
        moved = router.pump(now)
        # "error" counts as progress: the crash cleanup / failover just
        # requeued work that the next sweep will schedule
        progress = moved > 0 or any(
            s in ("round", "drained", "finalized", "error") for s in statuses)
        # quiesce is judged AFTER the pump, against live replica state — a
        # status computed before the pump is stale the moment a handoff
        # lands: the delivering sweep read the decode replica as "idle", yet
        # it now holds restorable work
        if (not progress and not router.pending_work()
                and not any(rs.busy() for rs in router.live_replicas)):
            if next_i >= len(pending):
                break
            compress_idle_gap(pending, next_i, now)
        elif not progress:
            time.sleep(0.0005)    # starved fleet: blocked on device/copies
    for rs in router.live_replicas:
        rs.finish()
    router.pump(now)              # a finish() drain can land a final gather
    now = time.perf_counter() - t_start

    outputs: Dict[int, List[int]] = {}
    # prefill replicas first so a handed-off request's decode-side (complete)
    # output wins over the source's prefill-era placeholder entry
    for rs in router.prefill + router.decode:
        outputs.update(rs.outputs)
    if router.cfg.robustness is not None:
        # under failover a request may retry on ANY replica, so pool order no
        # longer encodes freshness — the Request object is the authority (its
        # delivered tokens survive preempt folds and replica moves)
        for r in requests:
            if r.output_tokens:
                outputs[r.req_id] = list(r.output_tokens)
    stats = router.store.stats
    return DisaggResult(
        report=summarize(requests, makespan=now),
        requests=requests,
        rounds=sum(rs.rounds for rs in router.replicas),
        wall_s=now,
        outputs=outputs,
        replica_rounds=[rs.rounds for rs in router.replicas],
        handoffs=stats.delivered,
        dropped_handoffs=stats.dropped,
        colocated=stats.colocated,
        bytes_moved=stats.bytes_moved,
        memory=[
            summarize_memory(rs.kv_pool, rs.sched.stats)
            for rs in router.replicas
        ],
        # attainment is a property of the request set, not a replica: one
        # fleet-wide report against the prefill pool's registry (all replicas
        # share the tenant specs via the common FairnessConfig)
        slo=(
            summarize_slo(requests, router.prefill[0].sched.fairness.registry)
            if router.prefill and router.prefill[0].sched.fairness is not None
            else None
        ),
        robustness=(
            summarize_robustness(
                router.rstats,
                injector=router.injector,
                quarantined=sum(len(rs.quarantined) for rs in router.replicas),
                crash_unwinds=sum(rs.crash_unwinds for rs in router.replicas),
                crash_shed=sum(len(rs.crash_shed) for rs in router.replicas),
            )
            if router.cfg.robustness is not None
            else None
        ),
    )
