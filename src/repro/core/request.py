"""Request model and lifecycle for chunked-prefill serving.

State machine:  WAITING -> PREFILLING -> DECODING -> FINISHED
A request may bounce between WAITING and PREFILLING across rounds (it returns
to the prefill queue with updated priority after each chunk, per §3.1.3).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional

_req_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclass
class Request:
    prompt_len: int
    max_new_tokens: int
    arrival_time: float = 0.0
    req_id: int = field(default_factory=lambda: next(_req_counter))
    tenant: str = "default"
    prompt_tokens: Optional[List[int]] = None      # real-engine mode

    # progress
    state: RequestState = RequestState.WAITING
    prefill_done: int = 0
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)

    # timestamps (set by the engine/simulator clock)
    prefill_end_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # scheduling accounting
    rounds_scheduled: int = 0
    chunks: List[int] = field(default_factory=list)
    preemptions: int = 0
    folded_tokens: int = 0      # generated tokens folded into the prompt by preempt()
    # token id the executor sampled this round, delivered by the next
    # receive_token (real engine sets it; the simulator leaves 0 — it has no
    # token values).  Matters beyond reporting: preempt() folds delivered
    # tokens into the prompt, so recompute must re-prefill the REAL ids.
    next_token: int = 0
    # swap-out preemption: the victim's KV was staged host-side instead of
    # discarded, so it re-enters the queue decode-resumable (progress kept)
    swapped: bool = False
    swap_preemptions: int = 0
    # cross-replica disaggregation: prefill-complete handoffs taken (the KV
    # left one replica's pool through the handoff store and re-entered
    # another's; not a preemption — nothing is recomputed or discarded)
    handoffs: int = 0
    # optional EOS id: generation terminates when the sampled token equals
    # it (value-dependent stop; None = length-capped only).  The simulator
    # has no token values, so it must leave this None.
    stop_token: Optional[int] = None
    stopped: bool = False       # finished via stop_token, not the length cap
    # host-visibility timestamps of each delivered token (serve loops stamp
    # these at drain time); consecutive gaps are the inter-token latencies
    token_times: List[float] = field(default_factory=list)
    # set by resume(): the engine's device-resident last_token lane was lost
    # with the old slot, so the first post-restore decode round must stage
    # the last delivered token id from the host instead of consuming it
    needs_replay: bool = False
    # SLO load shedding: the request was retired WITHOUT service completion
    # because its deadline was projected infeasible ("admission" at submit,
    # "deadline" from the queue).  Shed requests are FINISHED with
    # finish_time None — they count in the shed attainment bucket, never as
    # violations.
    shed_reason: Optional[str] = None

    @property
    def remaining_prefill(self) -> int:
        return self.prompt_len - self.prefill_done

    @property
    def context_len(self) -> int:
        # folded tokens live inside prefill_done after a preemption recompute;
        # subtracting them keeps the physical KV length exact
        return self.prefill_done + self.generated - self.folded_tokens

    @property
    def is_prefill(self) -> bool:
        return self.state in (RequestState.WAITING, RequestState.PREFILLING)

    def receive_chunk(self, c: int) -> None:
        assert 0 < c <= self.remaining_prefill, (c, self.remaining_prefill)
        self.prefill_done += c
        self.chunks.append(c)
        self.rounds_scheduled += 1
        self.state = (
            RequestState.DECODING if self.remaining_prefill == 0 else RequestState.PREFILLING
        )

    def preempt(self) -> None:
        """Evicted under KV pressure: the request's blocks were freed, so its
        context must be recomputed from scratch.  Tokens already generated
        were delivered (streamed) and are folded into the prompt — recompute
        re-prefills prompt + generated tokens (vLLM recompute semantics), so
        decode resumes conditioned on the full delivered context."""
        assert self.state in (
            RequestState.WAITING, RequestState.PREFILLING, RequestState.DECODING,
        ), self.state
        unfolded = self.generated - self.folded_tokens
        if unfolded > 0:
            self.prompt_len += unfolded
            if self.prompt_tokens is not None:
                self.prompt_tokens = (
                    list(self.prompt_tokens) + list(self.output_tokens[self.folded_tokens:])
                )
            self.folded_tokens = self.generated
        self.state = RequestState.WAITING
        self.prefill_done = 0
        self.preemptions += 1
        # a recompute rebuilds everything, including the last token's KV —
        # the prefill-completing round samples normally, nothing to replay
        self.swapped = False
        self.needs_replay = False

    def swap_preempt(self) -> None:
        """Evicted under KV pressure with its KV *staged host-side* instead
        of discarded: progress (``prefill_done``/``generated``) is kept and
        nothing is folded into the prompt — the request re-enters the queue
        decode-resumable, costing one restore round rather than a full
        recompute prefill."""
        assert self.state in (
            RequestState.WAITING, RequestState.PREFILLING, RequestState.DECODING,
        ), self.state
        self.state = RequestState.WAITING
        self.swapped = True
        self.preemptions += 1
        self.swap_preemptions += 1

    def handoff(self) -> None:
        """Prefill completed on one replica and the KV is being exported for
        a decode replica to import: same decode-resumable bookkeeping as
        ``swap_preempt`` (progress kept, nothing folded), but counted as a
        handoff — migrating at the prefill/decode boundary is a placement
        decision, not a preemption."""
        assert self.state == RequestState.DECODING, self.state
        assert self.remaining_prefill <= 0, "handoff before prefill completed"
        self.state = RequestState.WAITING
        self.swapped = True
        self.handoffs += 1

    def resume(self) -> None:
        """Swap-in completed: the staged KV is device-resident again.  A
        fully-prefilled victim rejoins the decode set (its next decode round
        must replay the last delivered token id — the device-resident
        ``last_token`` lane died with the old slot); a mid-prefill victim
        stays WAITING and simply continues chunking over the restored KV."""
        assert self.swapped, "resume() of a request that was never swapped"
        self.swapped = False
        if self.remaining_prefill <= 0:
            self.state = RequestState.DECODING
            self.needs_replay = True

    def patch_token(self, i: int, tok: int) -> None:
        """Pipelined engines deliver token VALUES one round late: the round's
        bookkeeping (``receive_token``) runs against a placeholder while the
        device round executes, and the real id is patched in here once the
        async host copy drains.  If a preemption already folded the
        placeholder into the prompt (recompute semantics), the folded copy is
        fixed too — folded token ``i`` lives at prompt position
        ``original_prompt_len + i`` and the fold always happens before the
        re-prefill of that position is staged."""
        self.output_tokens[i] = tok
        if i < self.folded_tokens and self.prompt_tokens is not None:
            self.prompt_tokens[self.prompt_len - self.folded_tokens + i] = tok

    def finish_stopped(self, now: float = 0.0) -> None:
        """Value-dependent termination: the last delivered token matched
        ``stop_token``.  Serve loops call this when the real id becomes
        host-visible — which in a pipelined engine is one round AFTER the
        length bookkeeping ran (the request may even have been preempted,
        swapped out, or scheduled again in between)."""
        assert self.state != RequestState.FINISHED
        self.state = RequestState.FINISHED
        self.stopped = True
        self.swapped = False
        self.needs_replay = False
        self.finish_time = now

    def rollback_undrained(self, n: int = 1) -> int:
        """Crash/quarantine unwind: discard the last ``n`` UNDRAINED output
        tokens — placeholders a pipelined round booked via ``receive_token``
        whose values never became host-visible (the round crashed before its
        drain, or the drain read non-finite garbage).  Only undrained tokens
        may be rolled back: delivered tokens are streamed and irrevocable
        (at-most-once delivery); the caller re-executes the rolled-back
        positions via greedy recompute, which regenerates identical values.
        Reverts a same-round length-cap finish.  Returns how many tokens were
        actually popped."""
        assert n >= 0
        popped = 0
        for _ in range(n):
            if self.generated <= self.folded_tokens:
                break  # everything left was folded (delivered + re-prefilled)
            self.output_tokens.pop()
            self.generated -= 1
            popped += 1
        if popped and self.state == RequestState.FINISHED and not self.stopped:
            self.state = RequestState.DECODING
            self.finish_time = None
        if popped and self.generated == 0:
            self.first_token_time = None
        # token_times stay untouched: stamps exist only for DRAINED tokens,
        # and rollback by construction touches only undrained ones
        return popped

    def receive_token(self, tok: int = 0, now: float = 0.0) -> None:
        assert self.state == RequestState.DECODING
        self.generated += 1
        self.output_tokens.append(tok)
        if self.first_token_time is None:
            self.first_token_time = now
        if self.generated >= self.max_new_tokens:
            self.state = RequestState.FINISHED
            self.finish_time = now

    # metrics -----------------------------------------------------------------
    def e2e_latency(self) -> Optional[float]:
        return None if self.finish_time is None else self.finish_time - self.arrival_time

    def ttft(self) -> Optional[float]:
        return (
            None
            if self.first_token_time is None
            else self.first_token_time - self.arrival_time
        )

    def prefill_e2e(self) -> Optional[float]:
        return (
            None
            if self.prefill_end_time is None
            else self.prefill_end_time - self.arrival_time
        )
