"""The LPRS offline latency predictor (§3.2.1, Tables 7/8).

Three-layer MLP (128, 64, 32), ReLU, dropout 0.1, trained with AdamW under a
bucket-weighted asymmetric Huber loss: underestimating latency is penalized
harder than overestimating (underestimates cause budget overflow online).

Pure JAX; features are standardized with training-set statistics; data is
bucketed by scheduled_tokens and overrepresented full-chunk buckets are
downsampled (§3.2.1 step 3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import N_FEATURES
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class PredictorConfig:
    hidden_sizes: Tuple[int, ...] = (128, 64, 32)
    dropout: float = 0.1
    epochs: int = 300
    lr: float = 2e-3
    weight_decay: float = 1e-3
    batch_size: int = 256
    # asymmetric Huber (Eq. 5)
    huber_delta: float = 5.0        # ms (or log-units * 100 when log_target)
    under_weight: float = 2.0       # penalty multiplier when y_hat < y
    over_weight: float = 1.0
    # optional: regress log-latency (False = paper-exact).  With the linear
    # cost structure the direct target trains better; log helps only when
    # the latency function is multiplicative.
    log_target: bool = False
    seed: int = 0


def init_mlp(rng, cfg: PredictorConfig, n_in: int = N_FEATURES) -> Dict:
    sizes = (n_in,) + tuple(cfg.hidden_sizes) + (1,)
    params = {}
    ks = jax.random.split(rng, len(sizes) - 1)
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"w{i}"] = jax.random.normal(ks[i], (a, b), jnp.float32) * np.sqrt(2.0 / a)
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def mlp_apply(params: Dict, x, *, dropout: float = 0.0, rng=None):
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
            if dropout > 0.0 and rng is not None:
                keep = jax.random.bernoulli(jax.random.fold_in(rng, i), 1.0 - dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    return h[..., 0]


def asymmetric_huber(y, y_hat, delta: float, w_under: float, w_over: float):
    """Huber base with heavier penalty on underestimation (y_hat < y)."""
    err = y_hat - y
    a = jnp.abs(err)
    base = jnp.where(a <= delta, 0.5 * a * a, delta * (a - 0.5 * delta))
    side = jnp.where(err < 0, w_under, w_over)
    return side * base


class LatencyPredictor:
    """Trained predictor with feature standardization baked in."""

    def __init__(self, cfg: Optional[PredictorConfig] = None):
        self.cfg = cfg or PredictorConfig()
        self.params: Optional[Dict] = None
        self.mean = np.zeros(N_FEATURES)
        self.std = np.ones(N_FEATURES)
        self.y_scale = 1.0
        self._apply = jax.jit(lambda p, x: mlp_apply(p, x))

    # -- inference ----------------------------------------------------------
    def predict(self, features: np.ndarray) -> np.ndarray:
        """features: (16,) or (n, 16) -> predicted latency (ms), same leading
        shape."""
        assert self.params is not None, "predictor not trained/loaded"
        x = np.atleast_2d(np.asarray(features, np.float64))
        xs = (x - self.mean) / self.std
        out = np.asarray(self._apply(self.params, jnp.asarray(xs, jnp.float32)),
                         np.float64)
        out = out * self.y_scale
        if self.cfg.log_target:
            out = np.expm1(np.clip(out, -30.0, 30.0))
        return out if features.ndim > 1 else float(out[0])

    # -- training ------------------------------------------------------------
    def fit(
        self,
        features: np.ndarray,      # (N, 16)
        latencies: np.ndarray,     # (N,) ms
        *,
        sample_weights: Optional[np.ndarray] = None,
        epochs: Optional[int] = None,
        verbose: bool = False,
    ) -> Dict[str, float]:
        cfg = self.cfg
        N = features.shape[0]
        self.mean = features.mean(axis=0)
        self.std = features.std(axis=0) + 1e-9
        targets = np.log1p(latencies) if cfg.log_target else latencies
        self.y_scale = float(np.std(targets) + 1e-9)
        x = ((features - self.mean) / self.std).astype(np.float32)
        y = (targets / self.y_scale).astype(np.float32)
        w = (sample_weights if sample_weights is not None else np.ones(N)).astype(np.float32)

        rng = jax.random.PRNGKey(cfg.seed)
        params = init_mlp(rng, cfg)
        opt_cfg = AdamWConfig(lr=cfg.lr, weight_decay=cfg.weight_decay)
        opt = adamw_init(params)
        delta = (cfg.huber_delta / 100.0 if cfg.log_target
                 else cfg.huber_delta) / self.y_scale

        @jax.jit
        def step(params, opt, xb, yb, wb, drng):
            def loss_fn(p):
                pred = mlp_apply(p, xb, dropout=cfg.dropout, rng=drng)
                l = asymmetric_huber(yb, pred, delta, cfg.under_weight, cfg.over_weight)
                return jnp.sum(wb * l) / jnp.maximum(jnp.sum(wb), 1e-9)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
            return params, opt, loss

        n_epochs = epochs or cfg.epochs
        bs = min(cfg.batch_size, N)
        rng_np = np.random.default_rng(cfg.seed)
        last = 0.0
        for ep in range(n_epochs):
            perm = rng_np.permutation(N)
            for s in range(0, N - bs + 1, bs):
                idx = perm[s:s + bs]
                params, opt, last = step(
                    params, opt, x[idx], y[idx], w[idx],
                    jax.random.fold_in(rng, ep * 100_000 + s),
                )
            if verbose and (ep % 50 == 0 or ep == n_epochs - 1):
                print(f"  epoch {ep:4d} loss={float(last):.5f}")
        self.params = params
        return {"final_loss": float(last)}

    # -- evaluation (Table 8 metrics) -------------------------------------------
    def evaluate(self, features: np.ndarray, latencies: np.ndarray) -> Dict[str, float]:
        pred = self.predict(features)
        err = pred - latencies
        abs_err = np.abs(err)
        mape = float(np.mean(np.abs(err / np.maximum(np.abs(latencies), 1e-9)))) * 100
        return {
            "mae_ms": float(abs_err.mean()),
            "rmse_ms": float(np.sqrt((err ** 2).mean())),
            "mape_pct": mape,
            "p50_ms": float(np.percentile(abs_err, 50)),
            "p90_ms": float(np.percentile(abs_err, 90)),
            "p95_ms": float(np.percentile(abs_err, 95)),
            "p99_ms": float(np.percentile(abs_err, 99)),
            "within_5ms_pct": float((abs_err <= 5.0).mean() * 100),
            "within_10ms_pct": float((abs_err <= 10.0).mean() * 100),
        }

    # -- persistence -----------------------------------------------------------
    def state_dict(self) -> Dict:
        return {
            "params": jax.tree.map(np.asarray, self.params),
            "mean": self.mean,
            "std": self.std,
            "y_scale": self.y_scale,
            "cfg": dataclasses.asdict(self.cfg),
        }

    @classmethod
    def from_state(cls, state: Dict) -> "LatencyPredictor":
        cfg = PredictorConfig(**{
            k: tuple(v) if k == "hidden_sizes" else v for k, v in state["cfg"].items()
        })
        p = cls(cfg)
        p.params = jax.tree.map(jnp.asarray, state["params"])
        p.mean = np.asarray(state["mean"])
        p.std = np.asarray(state["std"])
        p.y_scale = float(state["y_scale"])
        return p


class AnalyticPredictor:
    """Closed-form fallback/oracle predictor (linear cost model).  Used for
    tests and as the simulator's ground truth generator."""

    def __init__(self, c0=2.0, c_prefill=0.04, c_decode=0.06, c_ctx=2e-5, c_batch=0.05):
        self.c = (c0, c_prefill, c_decode, c_ctx, c_batch)

    def predict(self, features: np.ndarray) -> np.ndarray:
        f = np.atleast_2d(np.asarray(features, np.float64))
        c0, cp, cd, cc, cb = self.c
        out = c0 + cp * f[..., 0] + cd * f[..., 1] + cc * f[..., 3] + cb * f[..., 2]
        return out if np.asarray(features).ndim > 1 else float(out[0])


def bucket_and_downsample(
    scheduled_tokens: np.ndarray,
    *,
    n_buckets: int = 16,
    max_bucket_frac: float = 0.25,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """§3.2.1 step 3: bucket samples by total scheduled tokens, downsample
    overrepresented (full-chunk) buckets.  Returns (keep_idx, weights)."""
    st = np.asarray(scheduled_tokens, np.float64)
    N = len(st)
    edges = np.quantile(st, np.linspace(0, 1, n_buckets + 1))
    edges[-1] += 1
    bucket = np.clip(np.searchsorted(edges, st, side="right") - 1, 0, n_buckets - 1)
    rng = np.random.default_rng(seed)
    keep = np.ones(N, bool)
    cap = int(max_bucket_frac * N)
    for b in range(n_buckets):
        idx = np.where(bucket == b)[0]
        if len(idx) > cap:
            drop = rng.choice(idx, size=len(idx) - cap, replace=False)
            keep[drop] = False
    kept = np.where(keep)[0]
    # bucket-aware weights: inverse sqrt frequency of the kept distribution
    kb = bucket[kept]
    counts = np.bincount(kb, minlength=n_buckets).astype(np.float64)
    wts = 1.0 / np.sqrt(np.maximum(counts[kb], 1.0))
    wts *= len(kept) / wts.sum()
    return kept, wts
