"""Prefill-ordering policies: FCFS, shortest-prefill-first, and the paper's
Aging weighted-fair policy (§3.1).

Aging priority:  P_i(n) = alpha * (t - a_i) + beta * r_i(n),  alpha>0, beta<0.
Since alpha*t is round-constant, ordering is maintained with the static key
K_i(n) = -alpha * a_i + beta * r_i(n)  (Eq. 4) in a max-heap; an update after
a chunk touches only that request:  O(k log n) per round (§3.1.4).

All policies share the heap implementation (FCFS: K = -a_i; SJF: K = -r_i),
differing only in the key function — which makes the O(k log n) overhead
claim directly measurable against a naive full-recompute implementation
(benchmarks/bench_overhead.py).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Iterable, List, Optional

from repro.core.request import Request


class PrefillQueue:
    """Max-heap over a request key; supports O(log n) add / pop / update.

    Entries are (-key, tiebreak, req).  Updates use lazy invalidation: a dict
    req_id -> live entry; stale heap entries are skipped on pop.
    """

    def __init__(self, key_fn: Callable[[Request], float]):
        self._key_fn = key_fn
        self._heap: List[list] = []
        self._live = {}
        self._tie = itertools.count()

    def __len__(self) -> int:
        return len(self._live)

    def __contains__(self, req: Request) -> bool:
        return req.req_id in self._live

    def add(self, req: Request) -> None:
        entry = [-self._key_fn(req), next(self._tie), req]
        self._live[req.req_id] = entry
        heapq.heappush(self._heap, entry)

    def update(self, req: Request) -> None:
        """Re-key one request (after it received a chunk): O(log n)."""
        old = self._live.pop(req.req_id, None)
        if old is not None:
            old[2] = None  # invalidate in place
        self.add(req)

    def remove(self, req: Request) -> None:
        old = self._live.pop(req.req_id, None)
        if old is not None:
            old[2] = None

    def pop(self) -> Optional[Request]:
        while self._heap:
            entry = heapq.heappop(self._heap)
            req = entry[2]
            if req is not None and req.req_id in self._live:
                del self._live[req.req_id]
                return req
        return None

    def peek(self) -> Optional[Request]:
        while self._heap:
            entry = self._heap[0]
            if entry[2] is not None and entry[2].req_id in self._live:
                return entry[2]
            heapq.heappop(self._heap)
        return None

    def drain_sorted(self) -> List[Request]:
        out = []
        while True:
            r = self.pop()
            if r is None:
                return out
            out.append(r)

    def requests(self) -> Iterable[Request]:
        return [e[2] for e in self._live.values()]


# ---------------------------------------------------------------------------
# policy factories
# ---------------------------------------------------------------------------


def make_policy(
    name: str,
    *,
    alpha: float = 1.0,
    beta: float = -0.01,
    credit_fn: Optional[Callable[[Request], float]] = None,
) -> PrefillQueue:
    """FCFS / SJF / Aging as ordering keys over the shared heap.

    ``credit_fn`` (optional, any policy) adds a cache-awareness term to the
    ordering key: requests whose KV is already materialized — resident
    prefix-cache blocks, or a host-staged swap record one restore round from
    runnable — rank ahead of equal-priority cold requests, so aging never
    starves near-free work behind full recomputes.  The credit is evaluated
    when a request is (re-)keyed (add/update — i.e. every queue bounce), the
    same refresh granularity the aging key itself has.
    """
    name = name.lower()
    if name == "fcfs":
        base = lambda r: -r.arrival_time
    elif name in ("sjf", "shortest"):
        base = lambda r: -float(r.remaining_prefill)
    elif name == "aging":
        if alpha <= 0 or beta >= 0:
            raise ValueError("aging requires alpha > 0 and beta < 0 (Eq. 1)")
        base = lambda r: -alpha * r.arrival_time + beta * float(r.remaining_prefill)
    else:
        raise ValueError(f"unknown policy {name!r}")
    if credit_fn is None:
        return PrefillQueue(base)
    return PrefillQueue(lambda r: base(r) + credit_fn(r))


def aging_priority(req: Request, now: float, alpha: float, beta: float) -> float:
    """Eq. 1 — P_i(n) = alpha (t - a_i) + beta r_i(n); for tests/analysis."""
    return alpha * (now - req.arrival_time) + beta * float(req.remaining_prefill)


class NaiveAgingQueue:
    """O(n log n)-per-round reference: recomputes all priorities each pop
    sequence (what §3.1.4 argues against).  Used to validate heap equivalence
    and to measure the overhead gap."""

    def __init__(self, alpha: float, beta: float):
        self.alpha, self.beta = alpha, beta
        self._reqs: List[Request] = []

    def __len__(self):
        return len(self._reqs)

    def add(self, req: Request) -> None:
        if all(r.req_id != req.req_id for r in self._reqs):
            self._reqs.append(req)

    update = add  # naive: everything is recomputed on pop anyway

    def remove(self, req: Request) -> None:
        self._reqs = [r for r in self._reqs if r.req_id != req.req_id]

    def pop(self, now: float = 0.0) -> Optional[Request]:
        if not self._reqs:
            return None
        best = max(
            self._reqs,
            key=lambda r: (aging_priority(r, now, self.alpha, self.beta), -r.req_id),
        )
        self._reqs.remove(best)
        return best
