"""SLO tier — per-request deadlines, feasibility projection, and urgency.

Closes the loop on ``TenantSpec.ttft_slo_s`` / ``TenantSpec.e2e_slo_s``:
instead of reporting-only gauges, the tenant's latency targets drive

  * **deadline-aware LPRS** — ``round_target_ms`` turns the *tightest
    admitted deadline* into the per-round latency target T* fed to
    ``select_chunk`` (slack divided over the rounds the request still
    needs, via ``predicted_resume_rounds``);
  * **SLO-weighted victim selection** — ``victim_class`` ranks preemption
    victims so a request already violating (or infeasible) sheds first
    and a protected, deadline-feasible request sheds last;
  * **APC protection** — ``urgent`` marks requests whose slack is within
    ``urgency_factor`` of the minimum feasible service time; the scheduler
    lets their prefill chunk bypass the activity cap / min-chunk gates so
    a protected tenant is never blocked below the deadline-feasible chunk;
  * **load shedding** — ``feasible`` is the admission/queue gate: a
    request whose deadline cannot be met even at max priority is shed
    (``AdmissionDecision.shed`` / ``Request.shed_reason``) instead of
    burning budget to miss it anyway.

All projections price a scheduling round with an EWMA of observed round
wall time (``begin_round``), seeded from ``round_ms_init`` — the same
"learn the round cost online" approach the LPRS predictor takes for
chunk sizing, but coarse enough to stay O(1) per request.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Tuple

from repro.core.lprs import predicted_resume_rounds
from repro.core.request import Request

# victim classes, ranked: higher sheds first
VICTIM_PROTECTED = 0   # has an SLO and can still make it — shed last
VICTIM_NO_SLO = 1      # best-effort traffic
VICTIM_VIOLATING = 2   # deadline already missed or infeasible — shed first


@dataclass(frozen=True)
class SLOConfig:
    """Feature flags + projection knobs for the SLO serving tier.

    Every flag defaults on; with ALL flags off the scheduler is
    bit-identical to running without a tracker (tested by
    ``tests/test_slo.py::test_slo_off_bit_identical``).
    """

    deadline_lprs: bool = True     # tightest-deadline round target for LPRS
    queue_urgency: bool = True     # deadline-urgent tenants jump the VTC order
    victim_weighting: bool = True  # SLO-attainment-weighted victim ranking
    apc_protect: bool = True       # urgent prefills bypass APC cap/min-chunk
    shed: bool = True              # infeasible deadlines shed at admission/queue

    round_ms_init: float = 50.0    # prior for the per-round wall time
    round_ms_ewma: float = 0.2     # EWMA weight for observed round times
    min_target_ms: float = 5.0     # floor for the derived LPRS target
    slack_safety: float = 1.0      # required slack = rounds * round_ms * safety
    urgency_factor: float = 2.0    # urgent when slack <= required * factor


class SLOTracker:
    """Projects deadlines/feasibility for requests of SLO-configured tenants.

    Owned by the scheduler (``SchedulerConfig.slo``); shared with the
    fairness subsystem via ``FairnessState.attach_slo`` (admission gate +
    fair-queue urgency).  Stateless per request — everything derives from
    the request's live fields, so preemption/swap/restore need no hooks.
    """

    def __init__(self, cfg: SLOConfig, registry, *, token_budget: int):
        self.cfg = cfg
        self.registry = registry          # duck-typed: .get(name) -> TenantSpec
        self.token_budget = max(int(token_budget), 1)
        self.round_ms = float(cfg.round_ms_init)
        self._last_now: Optional[float] = None

    # -- online round-cost estimate ------------------------------------------
    def begin_round(self, now: float, prev_busy: bool) -> None:
        """Fold the elapsed wall time since the previous ``schedule()`` call
        into the EWMA round cost — only when the previous round actually
        executed work (idle gaps between arrivals are not round cost)."""
        if prev_busy and self._last_now is not None and now > self._last_now:
            dt_ms = (now - self._last_now) * 1e3
            a = self.cfg.round_ms_ewma
            self.round_ms += a * (dt_ms - self.round_ms)
        self._last_now = now

    def scale_round_cost(self, factor: float) -> None:
        """Step-change the learned round cost (replica failover: the fleet
        just lost capacity, so every surviving replica's rounds get slower by
        roughly the capacity ratio).  The EWMA would learn this eventually;
        jumping it immediately makes infeasible deadlines shed NOW instead of
        burning budget during the convergence window."""
        self.round_ms *= max(float(factor), 1e-6)

    # -- deadline projection --------------------------------------------------
    def projection(self, req: Request) -> Tuple[Optional[float], int]:
        """(absolute deadline [s], minimum rounds of service still needed)
        for the request's *binding* SLO, or ``(None, 0)`` when its tenant
        has no latency target.

        Pre-first-token the TTFT target binds (falling back to E2E): the
        rounds needed are the chunked-prefill round count from
        ``predicted_resume_rounds`` — one restore round for a swap victim,
        ``ceil(remaining/budget)`` otherwise.  Post-first-token only the
        E2E target can bind and the worst case is one round per remaining
        token (stop tokens can only finish earlier).
        """
        spec = self.registry.get(req.tenant)
        pre_ttft = req.first_token_time is None
        if pre_ttft and spec.ttft_slo_s is not None:
            rounds = predicted_resume_rounds(
                req.remaining_prefill, self.token_budget, swapped=req.swapped
            )
            return req.arrival_time + spec.ttft_slo_s, rounds
        if spec.e2e_slo_s is not None:
            rounds = max(req.max_new_tokens - req.generated, 1)
            if pre_ttft:
                # prefill rounds first; the prefill-completing round already
                # delivers the first token, hence the -1 overlap
                rounds += predicted_resume_rounds(
                    req.remaining_prefill, self.token_budget, swapped=req.swapped
                ) - 1
            elif req.swapped:
                rounds += 1  # one host->device restore round before decode resumes
            return req.arrival_time + spec.e2e_slo_s, rounds
        return None, 0

    def required_s(self, rounds: int) -> float:
        return rounds * (self.round_ms / 1e3) * self.cfg.slack_safety

    def slack_s(self, req: Request, now: float) -> Optional[float]:
        """Remaining wall-clock budget before the binding deadline (signed)."""
        deadline, _ = self.projection(req)
        return None if deadline is None else deadline - now

    def feasible(self, req: Request, now: float) -> bool:
        """Can the deadline still be met at max priority?  (Admission /
        queue shed gate — requests without an SLO are always feasible.)"""
        deadline, rounds = self.projection(req)
        if deadline is None:
            return True
        return (deadline - now) >= self.required_s(rounds)

    def urgent(self, req: Optional[Request], now: float) -> bool:
        """Feasible-but-tight: the request must be served *now* (within
        ``urgency_factor`` round-budgets of the deadline) to keep its SLO.
        Drives fair-queue priority and the APC bypass."""
        if req is None:
            return False
        deadline, rounds = self.projection(req)
        if deadline is None:
            return False
        return (deadline - now) <= self.required_s(rounds) * self.cfg.urgency_factor

    def victim_class(self, req: Request, now: float) -> int:
        """Preemption ranking: violating/infeasible requests shed first,
        best-effort next, protected deadline-feasible requests last."""
        deadline, rounds = self.projection(req)
        if deadline is None:
            return VICTIM_NO_SLO
        if (deadline - now) < self.required_s(rounds):
            return VICTIM_VIOLATING
        return VICTIM_PROTECTED

    def round_target_ms(
        self, requests: Iterable[Request], now: float, base_target_ms: float
    ) -> float:
        """Deadline-aware LPRS target: the tightest per-round budget over
        every admitted deadline-bearing request — its remaining slack
        spread across the rounds it still needs — clamped to
        ``[min_target_ms, base_target_ms]`` so an SLO can only *tighten*
        the static T*, never relax it."""
        target = float(base_target_ms)
        for req in requests:
            deadline, rounds = self.projection(req)
            if deadline is None:
                continue
            per_round = (deadline - now) * 1e3 / max(rounds, 1)
            if per_round < target:
                target = per_round
        return max(target, self.cfg.min_target_ms)
