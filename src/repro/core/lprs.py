"""LPRS — Latency-Prediction-Based Request Scheduling (§3.2, Algorithm 1).

Replaces "fill to the token budget" with "hit the target round latency T*":
a discrete candidate search over chunk sizes, each scored by an asymmetric
deviation of the *predicted* batch latency from T* (overflow penalized by
lambda_o > lambda_u underfill).

Preemption interaction: a *swap-out* victim re-enters the queue
decode-resumable — its comeback is ONE restore round, not a re-prefill, so
the round-count predictor (``predicted_resume_rounds``) and the chunk search
both treat it as already-prefilled work (``select_chunk`` is never consulted
for a zero-remaining-prefill resume).  A *recompute* victim pays the full
``ceil(context / budget)`` rounds of chunked re-prefill — the asymmetry the
scheduler's swap-vs-recompute cost decision weighs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.features import BatchState


@dataclass(frozen=True)
class LPRSConfig:
    target_latency_ms: float = 105.0   # T* (paper's §4.4 setting)
    search_delta: int = 128            # candidate granularity Δ
    lambda_under: float = 1.0          # λ_u
    lambda_over: float = 3.0           # λ_o  (> λ_u, Eq. 10)


def predicted_resume_rounds(
    remaining_prefill: int, token_budget: int, *, swapped: bool
) -> int:
    """Scheduling-round count until a preemption victim can decode again:
    a swapped-out victim restores in ONE round (its prefill progress
    survived host-side); a recompute victim re-prefills its whole context
    chunk-by-chunk under the round token budget."""
    if swapped or remaining_prefill <= 0:
        return 1
    return max(1, math.ceil(remaining_prefill / max(token_budget, 1)))


def candidate_set(h_i: int, delta: int) -> np.ndarray:
    """Eq. 8 — C_i = {1, h_i} ∪ {kΔ | 1 <= kΔ <= h_i}, sorted ascending."""
    if h_i < 1:
        return np.array([], dtype=np.int64)
    cands = {1, h_i}
    cands.update(range(delta, h_i + 1, delta))
    return np.array(sorted(cands), dtype=np.int64)


def score(pred_ms: np.ndarray, target: float, lam_u: float, lam_o: float) -> np.ndarray:
    """Eq. 10 — asymmetric deviation from the target latency budget."""
    pred_ms = np.asarray(pred_ms, np.float64)
    under = lam_u * (target - pred_ms)
    over = lam_o * (pred_ms - target)
    return np.where(pred_ms <= target, under, over)


def select_chunk(
    *,
    remaining: int,                 # r_i
    committed: int,                 # U_t
    token_budget: int,              # B_max
    batch_state: BatchState,        # current round state (without candidate)
    processed: int,                 # request's historical prefill progress
    predictor,                      # .predict((n,16)) -> (n,) ms
    cfg: LPRSConfig,
    target_ms: Optional[float] = None,  # deadline-derived T* override (SLO tier)
) -> int:
    """Algorithm 1 — returns c_i^* (0 = skip this round).

    ``target_ms`` lets the SLO tier substitute the *tightest admitted
    deadline's* per-round budget for the static ``cfg.target_latency_ms``.
    """
    h_i = min(remaining, token_budget - committed)
    if h_i <= 0:
        return 0

    cands = candidate_set(h_i, cfg.search_delta)
    # Build all candidate feature vectors in one batched predictor call.
    feats = np.stack(
        [batch_state.with_extra_prefill(int(c), processed).features() for c in cands]
    )
    preds = np.asarray(predictor.predict(feats), np.float64).reshape(-1)
    target = cfg.target_latency_ms if target_ms is None else float(target_ms)
    scores = score(preds, target, cfg.lambda_under, cfg.lambda_over)

    # arg-min; ties broken toward the larger chunk (Algorithm 1 lines 16-21)
    best = 0
    best_score = np.inf
    for c, s in zip(cands, scores):
        if s < best_score or (s == best_score and c > best):
            best_score = s
            best = int(c)

    # starvation guard for an empty batch (Algorithm 1 lines 23-26)
    if best == 0 and committed == 0 and h_i >= 1:
        return 1
    return best
